//! Reproduce **Fig 5** — execution-time comparison: SC-MII integration
//! variants vs the edge-only input-integration baseline, under the
//! testbed latency model (Jetson-class edge factor, RTX-4090-class
//! server factor, 1 Gbps LAN).
//!
//! ```bash
//! make artifacts && cargo run --release --example exec_time -- --frames 16
//! ```

use anyhow::Result;
use scmii::cli::Args;
use scmii::config::{default_paths, LatencyConfig};
use scmii::latency::harness::{print_exec_time, run_exec_time};

fn main() -> Result<()> {
    scmii::utils::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.usize_or("frames", 16)?;
    let mut cfg = LatencyConfig::default();
    cfg.edge_factor = args.f64_or("edge-factor", cfg.edge_factor)?;
    cfg.server_factor = args.f64_or("server-factor", cfg.server_factor)?;
    cfg.bandwidth_bps = args.f64_or("bandwidth-gbps", cfg.bandwidth_bps / 1e9)? * 1e9;

    let paths = default_paths();
    if !scmii::config::artifacts_present(&paths) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let methods = run_exec_time(&paths, n, &cfg)?;
    print_exec_time(&methods);
    Ok(())
}
