//! End-to-end distributed demo — the full SC-MII deployment on real TCP
//! sockets: an edge server (tail model), one worker per LiDAR (head
//! models), a 1 Gbps bandwidth shaper on each uplink, and a subscriber
//! measuring end-to-end latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_split -- --frames 32
//! ```

use anyhow::Result;
use scmii::cli::Args;
use scmii::config::{default_paths, IntegrationKind};
use scmii::coordinator::device::{run_device, DeviceConfig};
use scmii::coordinator::server::{run_server, ServerConfig};
use scmii::net::{read_msg, write_msg, Msg, DEFAULT_SESSION};
use scmii::utils::stats;
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    scmii::utils::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let frames_n = args.usize_or("frames", 32)?;
    let port = args.usize_or("port", 7441)? as u16;
    let hz = args.f64_or("hz", 10.0)?;
    let variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    let backend = scmii::runtime::BackendKind::parse(
        &args.str_or("backend", scmii::runtime::BackendKind::default_kind().name()),
    )?;
    let backend_threads = args.usize_or("backend-threads", 2)?;

    let paths = default_paths();
    if !scmii::config::artifacts_present(&paths) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let frames = scmii::sim::dataset::load_split(&paths.data.join("val"))?;
    let frames: Vec<_> = frames.into_iter().take(frames_n).collect();
    let n_dev = frames[0].clouds.len();
    println!(
        "serving {} frames at {:.0} Hz across {} devices + 1 edge server \
         (variant {}, backend {} x{} threads)",
        frames.len(),
        hz,
        n_dev,
        variant.name(),
        backend.name(),
        backend_threads
    );

    // Edge server: a multi-threaded backend pool, so tails of
    // back-to-back frames overlap instead of queueing on one engine.
    let server_paths = paths.clone();
    let server_cfg = ServerConfig {
        port,
        variant,
        deadline: Duration::from_millis(400),
        max_frames: Some(frames.len() as u64),
        backend,
        backend_threads,
        ..Default::default()
    };
    let server = std::thread::spawn(move || run_server(&server_paths, &server_cfg));
    std::thread::sleep(Duration::from_millis(2500)); // let the tail compile

    // Subscriber: receives final detections, timestamps completion.
    let sub = TcpStream::connect(("127.0.0.1", port))?;
    let mut sub_w = sub.try_clone()?;
    write_msg(&mut sub_w, &Msg::Subscribe { session: DEFAULT_SESSION.into() })?;
    let n_expect = frames.len();
    let subscriber =
        std::thread::spawn(move || -> Result<Vec<(u64, Instant, usize, u64)>> {
            let mut reader = std::io::BufReader::new(sub);
            let mut out = Vec::new();
            while out.len() < n_expect {
                match read_msg(&mut reader)? {
                    Msg::Result { frame_id, detections, server_micros, .. } => {
                        out.push((frame_id, Instant::now(), detections.len(), server_micros));
                    }
                    Msg::Bye => break,
                    _ => {}
                }
            }
            Ok(out)
        });

    // Device workers (each owns its engine; head compile happens inside).
    let t_start = Instant::now();
    let mut device_threads = Vec::new();
    for dev in 0..n_dev {
        let clouds: Vec<_> = frames.iter().map(|f| f.clouds[dev].clone()).collect();
        let paths = paths.clone();
        let cfg = DeviceConfig {
            device_id: dev,
            server: format!("127.0.0.1:{port}"),
            session: DEFAULT_SESSION.into(),
            variant,
            period: if hz > 0.0 { Some(Duration::from_secs_f64(1.0 / hz)) } else { None },
            bandwidth_bps: Some(1e9),
            max_frames: frames.len(),
            quantize: false,
            backend,
            ..DeviceConfig::default()
        };
        device_threads.push(std::thread::spawn(move || run_device(&paths, &cfg, &clouds)));
    }

    let mut send_times: Vec<Vec<(f64, f64)>> = Vec::new();
    for t in device_threads {
        send_times.push(t.join().expect("device thread panicked")?.frame_times);
    }
    let results = subscriber.join().expect("subscriber panicked")?;
    let registry = server.join().expect("server panicked")?;
    let session = registry.get(DEFAULT_SESSION).expect("default session");
    let wall = t_start.elapsed().as_secs_f64();

    // Report.
    let det_counts: Vec<f64> = results.iter().map(|r| r.2 as f64).collect();
    let server_us: Vec<f64> = results.iter().map(|r| r.3 as f64 / 1e3).collect();
    println!("\n=== serve_split results ===");
    println!("frames completed : {}", results.len());
    println!(
        "wall time        : {wall:.2} s  ({:.1} frames/s)",
        results.len() as f64 / wall
    );
    println!(
        "server tail exec : mean {:.1} ms, p99 {:.1} ms",
        stats::mean(&server_us),
        stats::percentile(&server_us, 99.0)
    );
    for (dev, times) in send_times.iter().enumerate() {
        let heads: Vec<f64> = times.iter().map(|t| t.0 * 1e3).collect();
        let txs: Vec<f64> = times.iter().map(|t| t.1 * 1e3).collect();
        println!(
            "device {dev}         : head mean {:.1} ms, tx mean {:.1} ms (1 Gbps shaped)",
            stats::mean(&heads),
            stats::mean(&txs)
        );
    }
    println!("detections/frame : mean {:.1}", stats::mean(&det_counts));
    let sync = session.sync_stats();
    println!(
        "frame sync       : {} complete, {} timed out, {} late, {} dup",
        sync.complete, sync.timed_out, sync.late_arrivals, sync.duplicates
    );
    println!("\nserver metrics (session {DEFAULT_SESSION:?}):\n{}", session.metrics().report());
    Ok(())
}
