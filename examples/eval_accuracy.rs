//! Reproduce **Table III** — overall accuracy (mAP at BEV IoU 0.3 / 0.5)
//! of every sensor configuration and integration method.
//!
//! ```bash
//! make artifacts && cargo run --release --example eval_accuracy -- --frames 80
//! ```

use anyhow::Result;
use scmii::cli::Args;
use scmii::config::default_paths;
use scmii::eval::harness::{print_accuracy, run_accuracy};

fn main() -> Result<()> {
    scmii::utils::logging::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.usize_or("frames", 80)?;
    let paths = default_paths();
    if !scmii::config::artifacts_present(&paths) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rows = run_accuracy(&paths, n)?;
    print_accuracy(&rows);
    Ok(())
}
