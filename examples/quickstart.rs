//! Quickstart: load the AOT artifacts, run the SC-MII split pipeline on
//! one validation frame, print detections.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use scmii::config::{default_paths, IntegrationKind};
use scmii::coordinator::pipeline::ScMiiPipeline;

fn main() -> Result<()> {
    scmii::utils::logging::init();
    let paths = default_paths();
    if !scmii::config::artifacts_present(&paths) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Load the paper's best variant: concat + conv3d kernel size 3.
    let pipeline = ScMiiPipeline::load(&paths, IntegrationKind::ConvK3)?;
    println!(
        "loaded SC-MII pipeline: {} devices, grid {:?}, intermediate output {} KiB/device, backend {}",
        pipeline.meta.num_devices,
        pipeline.meta.grid.dims,
        pipeline.meta.grid.feature_bytes() / 1024,
        pipeline.backend().backend_name()
    );

    let frames = scmii::sim::dataset::load_split(&paths.data.join("val"))?;
    let frame = &frames[0];
    let (dets, timing) = pipeline.infer(&frame.clouds)?;

    println!(
        "\nframe 0 — {} ground-truth objects, {} detections:",
        frame.labels.len(),
        dets.len()
    );
    for d in dets.iter().take(12) {
        println!(
            "  {:<11} score {:.2}  at ({:6.1}, {:6.1}, {:5.1})  size ({:.1} x {:.1} x {:.1})  yaw {:5.2}",
            pipeline.meta.classes[d.class_id],
            d.score,
            d.bbox.center.x,
            d.bbox.center.y,
            d.bbox.center.z,
            d.bbox.size.x,
            d.bbox.size.y,
            d.bbox.size.z,
            d.bbox.yaw
        );
    }
    println!(
        "\ntiming (this machine): heads {:?} ms, tail {:.1} ms, post {:.2} ms",
        timing.head_secs.iter().map(|s| (s * 1e4).round() / 10.0).collect::<Vec<_>>(),
        timing.tail_secs * 1e3,
        timing.post_secs * 1e3
    );
    Ok(())
}
