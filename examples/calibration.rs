//! Setup-phase demo (paper Fig 4): simulate dense calibration scans from
//! both infrastructure LiDARs, run NDT scan matching, and compare the
//! estimated rigid transform against the simulator's ground truth.
//!
//! Needs no artifacts — everything is generated in-process.
//!
//! ```bash
//! cargo run --release --example calibration
//! ```

use anyhow::Result;
use scmii::ndt::{calibrate, score_pose, NdtParams};
use scmii::sim::{self, SimConfig};
use std::time::Instant;

fn main() -> Result<()> {
    scmii::utils::logging::init();
    let cfg = SimConfig::default();
    println!("simulating dense calibration scans ({} pts/sensor)...", cfg.calib_points);
    let scans = sim::dataset::calibration_scans(&cfg);
    let rig = sim::dataset::sensor_rig();

    for (i, lidar) in rig.iter().enumerate() {
        println!(
            "  sensor {i}: {} ({} beams) at world ({:.1}, {:.1}, {:.1})",
            lidar.spec.name, lidar.spec.beams, lidar.pose.trans.x, lidar.pose.trans.y,
            lidar.pose.trans.z
        );
    }

    let truth = sim::dataset::true_device_transform(&rig, 1);
    let t0 = Instant::now();
    let result = calibrate(&scans[0], &scans[1], &NdtParams::default());
    let secs = t0.elapsed().as_secs_f64();

    let (rot_err, trans_err) = result.pose.error_to(&truth);
    println!("\n=== NDT scan matching (device 1 -> device 0) ===");
    println!("time              : {secs:.2} s ({} gradient iterations)", result.iterations);
    println!("final NDT score   : {:.4}", result.score);
    println!(
        "score at truth    : {:.4}",
        score_pose(&scans[0], &scans[1], &truth, 2.0)
    );
    println!(
        "estimated         : t = ({:7.3}, {:7.3}, {:6.3}) m",
        result.pose.trans.x, result.pose.trans.y, result.pose.trans.z
    );
    println!(
        "ground truth      : t = ({:7.3}, {:7.3}, {:6.3}) m",
        truth.trans.x, truth.trans.y, truth.trans.z
    );
    println!("rotation error    : {:.4} rad ({:.3}°)", rot_err, rot_err.to_degrees());
    println!("translation error : {:.3} m  ({:.2} voxels)", trans_err, trans_err / 0.8);
    println!(
        "\nverdict: {}",
        if trans_err < 0.8 && rot_err < 0.04 {
            "PASS — within one detection voxel; features will align"
        } else {
            "FAIL — rerun with more calibration points"
        }
    );
    Ok(())
}
