"""Model shape/behaviour tests: head/tail/full graphs, split-point
semantics, integration variants, encode/decode conventions."""

import math

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as m
from compile.configs import CFG
from compile.targets import assign_frame, encode_box

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def rand_points(n=512):
    return jnp.asarray(
        np.stack(
            [
                RNG.uniform(-15, 30, n),
                RNG.uniform(-15, 30, n),
                RNG.uniform(-5.5, -0.5, n),
                RNG.uniform(0, 1, n),
            ],
            axis=-1,
        ).astype(np.float32)
    )


def test_head_output_shape():
    params = m.init_head_params(KEY)
    out = m.head_fn(params, rand_points())
    g = CFG.grid
    assert out.shape == (g.D, g.H, g.W, g.c_head)
    # ReLU at the split point
    assert float(out.min()) >= 0.0


def test_head_is_local_to_points():
    """The head must not smear information beyond one conv3d receptive
    field — a point cluster far from a voxel leaves it zero."""
    params = m.init_head_params(KEY)
    pts = rand_points(64)
    out = np.asarray(m.head_fn(params, pts))
    # A corner of the grid with no points within ~2 voxels must be zero.
    assert np.all(out[:, :2, :2, :] == 0.0) or np.all(out[:, -2:, -2:, :] == 0.0)


def test_tail_variants_shapes():
    g = CFG.grid
    maps = [None, jnp.arange(g.n_voxels(), dtype=jnp.int32)]
    feats = [
        jnp.asarray(RNG.standard_normal((g.D, g.H, g.W, g.c_head)).astype(np.float32))
        for _ in range(2)
    ]
    for variant in ("max", "conv_k1", "conv_k3"):
        params = m.init_variant_params(KEY, variant)
        cls, box = m.tail_fn(params, feats, variant, maps)
        assert cls.shape == tuple(CFG.bev_dims) + (CFG.n_anchors,)
        assert box.shape == tuple(CFG.bev_dims) + (CFG.n_anchors, 8)


def test_scmii_equals_head_plus_tail():
    """Split-computing invariant: running head then tail equals the
    end-to-end graph (same params, same integration)."""
    g = CFG.grid
    maps = [None, jnp.arange(g.n_voxels(), dtype=jnp.int32)]
    params = m.init_variant_params(KEY, "conv_k1")
    pts = [rand_points(256), rand_points(256)]
    cls_a, box_a = m.scmii_fn(params, pts, "conv_k1", maps)
    feats = [m.head_fn(hp, p) for hp, p in zip(params["heads"], pts)]
    cls_b, box_b = m.tail_fn(params, feats, "conv_k1", maps)
    np.testing.assert_allclose(np.asarray(cls_a), np.asarray(cls_b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(box_a), np.asarray(box_b), atol=1e-5)


def test_kernel_and_ref_paths_agree():
    """use_kernels=True (serving) vs False (training) must match."""
    g = CFG.grid
    maps = [None, jnp.arange(g.n_voxels(), dtype=jnp.int32)]
    feats = [
        jnp.asarray(RNG.standard_normal((g.D, g.H, g.W, g.c_head)).astype(np.float32))
        for _ in range(2)
    ]
    for variant in ("max", "conv_k1", "conv_k3"):
        params = m.init_variant_params(KEY, variant)
        a = m.integrate_fn(params.get("integration", {}), feats, variant, maps,
                           CFG, use_kernels=True)
        b = m.integrate_fn(params.get("integration", {}), feats, variant, maps,
                           CFG, use_kernels=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_max_integration_dominates_single():
    """Max integration of f with zeros returns relu-like f (paper's
    max-selection semantics: absent device = no evidence)."""
    g = CFG.grid
    maps = [None, None]
    f = jnp.abs(
        jnp.asarray(RNG.standard_normal((g.D, g.H, g.W, g.c_head)).astype(np.float32))
    )
    z = jnp.zeros_like(f)
    out = m.integrate_fn({}, [f, z], "max", maps, CFG, use_kernels=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(f))


def test_target_assignment_basics():
    labels = np.zeros((2, 8), np.float32)
    labels[0] = [5.0, 5.0, -3.7, 4.5, 1.9, 1.6, 0.0, 0]  # car
    labels[1] = [-5.0, 8.0, -3.65, 0.8, 0.8, 1.7, 0.0, 1]  # pedestrian
    cls_t, box_t = assign_frame(labels)
    assert cls_t.shape == tuple(CFG.bev_dims) + (CFG.n_anchors,)
    assert (cls_t == 1).sum() >= 2, "both GTs must be assigned"
    # positives only on matching-class anchors
    assert (cls_t[:, :, 2] == 1).sum() >= 1  # ped anchor fired
    assert (cls_t[:, :, 0] == 1).sum() + (cls_t[:, :, 1] == 1).sum() >= 1


def test_car_anchor_orientation_preference():
    labels = np.zeros((1, 8), np.float32)
    labels[0] = [0.0, 0.0, -3.7, 4.5, 1.9, 1.6, math.pi / 2, 0]  # car at 90°
    cls_t, _ = assign_frame(labels)
    # the 90° anchor (index 1) takes it, not the 0° anchor
    assert (cls_t[:, :, 1] == 1).sum() >= 1
    assert (cls_t[:, :, 0] == 1).sum() == 0


def test_encode_box_roundtrip_convention():
    """Pin the encoding rust decodes (model::decode_raw)."""
    anchor = CFG.anchors[0]
    gt = np.array([10.3, -4.2, -3.5, 4.2, 1.8, 1.5, 0.25], np.float32)
    enc = encode_box(gt, (10.0, -4.0), anchor)
    diag = math.sqrt(anchor.size[0] ** 2 + anchor.size[1] ** 2)
    # decode manually
    x = 10.0 + enc[0] * diag
    y = -4.0 + enc[1] * diag
    z = anchor.z_center + enc[2] * anchor.size[2]
    l = anchor.size[0] * math.exp(enc[3])
    yaw = anchor.yaw + math.atan2(enc[6], enc[7])
    assert abs(x - gt[0]) < 1e-5
    assert abs(y - gt[1]) < 1e-5
    assert abs(z - gt[2]) < 1e-5
    assert abs(l - gt[3]) < 1e-4
    assert abs(yaw - gt[6]) < 1e-6
