"""jnp voxelizer semantics (mirrors rust/src/voxel/features.rs tests so
the two implementations are pinned to the same contract)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.configs import CFG, COUNT_CLIP, PAD_Z
from compile.voxelize import voxelize

GRID = CFG.grid
settings.register_profile("vox", deadline=None, max_examples=15)
settings.load_profile("vox")


def vox_center(ix, iy, iz):
    return (
        GRID.range_min[0] + (ix + 0.5) * GRID.voxel[0],
        GRID.range_min[1] + (iy + 0.5) * GRID.voxel[1],
        GRID.range_min[2] + (iz + 0.5) * GRID.voxel[2],
    )


def run(points):
    return np.asarray(voxelize(jnp.asarray(np.asarray(points, np.float32)), GRID))


def test_empty_cloud_zero_map():
    pts = np.zeros((16, 4), np.float32)
    pts[:, 2] = PAD_Z
    out = run(pts)
    assert out.shape == (GRID.D, GRID.H, GRID.W, 6)
    assert np.all(out == 0.0)


def test_single_point_stats():
    cx, cy, cz = vox_center(32, 16, 4)
    pts = np.array([[cx, cy, cz, 0.7]], np.float32)
    out = run(pts)
    v = out[4, 16, 32]
    assert abs(v[0] - 1.0 / COUNT_CLIP) < 1e-6
    assert np.all(np.abs(v[1:4]) < 1e-4)
    assert abs(v[4] - 0.7) < 1e-6
    z_norm = (cz - GRID.range_min[2]) / (GRID.range_max[2] - GRID.range_min[2])
    assert abs(v[5] - z_norm) < 1e-4
    assert (out != 0).any(axis=-1).sum() == 1


def test_offset_normalization():
    cx, cy, cz = vox_center(10, 10, 2)
    pts = np.array([[cx + 0.2, cy, cz, 0.0]], np.float32)
    out = run(pts)
    assert abs(out[2, 10, 10, 1] - 0.25) < 1e-4


def test_count_clip():
    cx, cy, cz = vox_center(5, 5, 1)
    pts = np.tile(np.array([[cx, cy, cz, 0.0]], np.float32), (40, 1))
    out = run(pts)
    assert abs(out[1, 5, 5, 0] - 1.0) < 1e-6


def test_out_of_range_dropped():
    pts = np.array(
        [[1000.0, 0.0, -3.0, 0.0], [0.0, 0.0, 100.0, 0.0], [0.0, 0.0, PAD_Z, 0.0]],
        np.float32,
    )
    out = run(pts)
    assert np.all(out == 0.0)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 512))
def test_matches_numpy_reference(seed, n):
    """Dense property check against an independent numpy implementation."""
    rng = np.random.default_rng(seed)
    pts = np.stack(
        [
            rng.uniform(-25, 40, n),
            rng.uniform(-25, 40, n),
            rng.uniform(-7, 1, n),
            rng.uniform(0, 1, n),
        ],
        axis=-1,
    ).astype(np.float32)
    out = run(pts)

    # numpy reference
    w, h, d = GRID.dims
    ref = np.zeros((d, h, w, 6), np.float32)
    acc = {}
    for x, y, z, i in pts:
        fx = (x - GRID.range_min[0]) / GRID.voxel[0]
        fy = (y - GRID.range_min[1]) / GRID.voxel[1]
        fz = (z - GRID.range_min[2]) / GRID.voxel[2]
        if fx < 0 or fy < 0 or fz < 0:
            continue
        ix, iy, iz = int(fx), int(fy), int(fz)
        if ix >= w or iy >= h or iz >= d:
            continue
        acc.setdefault((iz, iy, ix), []).append((x, y, z, i))
    for (iz, iy, ix), plist in acc.items():
        cx, cy, cz = vox_center(ix, iy, iz)
        xs = np.array(plist)
        nvox = len(plist)
        ref[iz, iy, ix, 0] = min(nvox, COUNT_CLIP) / COUNT_CLIP
        ref[iz, iy, ix, 1] = np.mean(xs[:, 0] - cx) / GRID.voxel[0]
        ref[iz, iy, ix, 2] = np.mean(xs[:, 1] - cy) / GRID.voxel[1]
        ref[iz, iy, ix, 3] = np.mean(xs[:, 2] - cz) / GRID.voxel[2]
        ref[iz, iy, ix, 4] = np.mean(xs[:, 3])
        ref[iz, iy, ix, 5] = (xs[:, 2].max() - GRID.range_min[2]) / (
            GRID.range_max[2] - GRID.range_min[2]
        )
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=2e-4)
