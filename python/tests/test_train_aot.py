"""Training-path and AOT-path unit tests (no dataset needed)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as m
from compile.configs import CFG
from compile.losses import detection_loss, sigmoid_focal_loss, smooth_l1
from compile.train import (
    adam_init,
    adam_update,
    cosine_lr,
    flatten_params,
    unflatten_params,
)
from compile.aot import to_hlo_text


def test_param_flatten_roundtrip():
    params = m.init_variant_params(jax.random.PRNGKey(0), "conv_k3")
    flat = flatten_params(params)
    back = unflatten_params(flat)
    assert isinstance(back["heads"], list) and len(back["heads"]) == 2
    for k, v in flat.items():
        node = back
        for part in k.split("."):
            node = node[int(part)] if part.isdigit() else node[part]
        np.testing.assert_array_equal(np.asarray(node), v)


def test_adam_decreases_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = adam_init(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adam_update(params, g, state, lr=0.05)
    assert float(loss(params)) < 1e-2


def test_adam_grad_clip():
    params = {"x": jnp.array([0.0])}
    state = adam_init(params)
    huge = {"x": jnp.array([1e9])}
    new_params, _ = adam_update(params, huge, state, lr=0.1, clip=1.0)
    # step magnitude bounded by lr (Adam normalizes) and clip kept it finite
    assert np.isfinite(float(new_params["x"][0]))


def test_cosine_lr_schedule():
    assert float(cosine_lr(1.0, 0, 100)) < 0.1  # warmup
    mid = float(cosine_lr(1.0, 60, 100, warmup=20))
    end = float(cosine_lr(1.0, 99, 100, warmup=20))
    assert 0.0 < end < mid < 1.0


def test_focal_loss_down_weights_easy():
    easy = float(sigmoid_focal_loss(jnp.array(8.0), jnp.array(1.0)))
    hard = float(sigmoid_focal_loss(jnp.array(-8.0), jnp.array(1.0)))
    assert hard > easy * 100


def test_smooth_l1_regimes():
    assert abs(float(smooth_l1(jnp.array(0.5), jnp.array(0.0))) - 0.125) < 1e-6
    assert abs(float(smooth_l1(jnp.array(3.0), jnp.array(0.0))) - 2.5) < 1e-6


def test_detection_loss_ignore_mask():
    cls_logits = jnp.zeros((4, 4, 3))
    box = jnp.zeros((4, 4, 3, 8))
    cls_t = -jnp.ones((4, 4, 3))  # everything ignored
    box_t = jnp.zeros((4, 4, 3, 8))
    total, cls_l, box_l = detection_loss(cls_logits, box, cls_t, box_t)
    assert float(total) == 0.0 and float(cls_l) == 0.0 and float(box_l) == 0.0


def test_detection_loss_positive_drives_gradient():
    cls_t = jnp.zeros((4, 4, 3)).at[1, 1, 0].set(1.0)
    box_t = jnp.zeros((4, 4, 3, 8)).at[1, 1, 0].set(0.5)

    def loss(logit):
        cls_logits = jnp.zeros((4, 4, 3)).at[1, 1, 0].set(logit)
        total, _, _ = detection_loss(cls_logits, jnp.zeros((4, 4, 3, 8)), cls_t, box_t)
        return total

    g = jax.grad(loss)(0.0)
    assert float(g) < 0.0, "raising the positive logit must lower the loss"


def test_hlo_text_lowering_smoke():
    """A tiny jitted fn lowers to parseable HLO text with a tuple root."""

    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "tuple" in text.lower()
