"""Alignment index-map construction (mirrors rust/src/align tests — both
implementations are pinned to the same contract, including rounding)."""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.align import build_align_map, identity_map
from compile.configs import CFG

GRID = CFG.grid
settings.register_profile("align", deadline=None, max_examples=15)
settings.load_profile("align")


def mat4(tx=0.0, ty=0.0, tz=0.0, yaw=0.0):
    c, s = math.cos(yaw), math.sin(yaw)
    return np.array(
        [[c, -s, 0, tx], [s, c, 0, ty], [0, 0, 1, tz], [0, 0, 0, 1]], np.float64
    )


def test_identity_map_is_identity():
    m = identity_map(GRID)
    np.testing.assert_array_equal(m, np.arange(GRID.n_voxels()))


def test_translation_by_one_voxel():
    m = build_align_map(GRID, mat4(tx=GRID.voxel[0]))
    w, h, _ = GRID.dims
    # output voxel (ix=1, iy=0, iz=0) sources device voxel 0
    assert m[1] == 0
    # leftmost column unmapped
    assert m[0] == -1


def test_rotation_coverage():
    m = build_align_map(GRID, mat4(tx=3.0, ty=-2.0, yaw=0.9))
    valid = (m >= 0).mean()
    assert valid > 0.3
    assert m.max() < GRID.n_voxels()


def test_stride_halves_dims():
    m = identity_map(GRID, stride=2)
    assert m.shape == ((GRID.W // 2) * (GRID.H // 2) * (GRID.D // 2),)
    np.testing.assert_array_equal(m, np.arange(len(m)))


@given(
    tx=st.floats(-8, 8),
    ty=st.floats(-8, 8),
    yaw=st.floats(-math.pi, math.pi),
    seed=st.integers(0, 2**31 - 1),
)
def test_physical_consistency(tx, ty, yaw, seed):
    """A device-frame point P maps to the common voxel containing T(P)
    (within one voxel of rounding)."""
    t = mat4(tx, ty, 0.3, yaw)
    m = build_align_map(GRID, t)
    rng = np.random.default_rng(seed)
    w, h, d = GRID.dims
    p_dev = np.array(
        [rng.uniform(-10, 25), rng.uniform(-10, 25), rng.uniform(-5.5, -0.5)]
    )
    # device voxel of p_dev
    f = (p_dev - np.array(GRID.range_min)) / np.array(GRID.voxel)
    if np.any(f < 0):
        return
    ji = f.astype(int)
    if ji[0] >= w or ji[1] >= h or ji[2] >= d:
        return
    p_common = t[:3, :3] @ p_dev + t[:3, 3]
    fc = (p_common - np.array(GRID.range_min)) / np.array(GRID.voxel)
    if np.any(fc < 0):
        return
    oc = fc.astype(int)
    if oc[0] >= w or oc[1] >= h or oc[2] >= d:
        return
    out_flat = (oc[2] * h + oc[1]) * w + oc[0]
    src = m[out_flat]
    assert src >= 0
    sz, rem = divmod(int(src), h * w)
    sy, sx = divmod(rem, w)
    assert abs(sx - ji[0]) <= 1
    assert abs(sy - ji[1]) <= 1
    assert abs(sz - ji[2]) <= 1
