"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and the gather's index distribution); every
kernel must match its ref to float32 tolerance on every drawn case.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_integrate_conv import fused_integrate_conv
from compile.kernels.gather_align import gather_align
from compile.kernels.max_integrate import max_integrate

settings.register_profile("kernels", deadline=None, max_examples=20)
settings.load_profile("kernels")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


dims = st.tuples(
    st.integers(1, 6),  # D
    st.integers(1, 12),  # H
    st.integers(1, 12),  # W
    st.integers(1, 8),  # C
)


@given(dims=dims, seed=st.integers(0, 2**31 - 1))
def test_max_integrate_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, *dims)
    b = rand(rng, *dims)
    got = max_integrate(a, b)
    want = ref.max_integrate_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@given(dims=dims, co=st.integers(1, 8), k=st.sampled_from([1, 3]),
       seed=st.integers(0, 2**31 - 1))
def test_fused_integrate_conv_matches_ref(dims, co, k, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, *dims)
    b = rand(rng, *dims)
    c = dims[-1]
    w = rand(rng, k, k, k, 2 * c, co)
    bias = rand(rng, co)
    got = fused_integrate_conv(a, b, w, bias)
    want = ref.fused_integrate_conv_ref(a, b, w, bias)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@given(
    d=st.integers(1, 4),
    h=st.integers(1, 8),
    w=st.integers(1, 8),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_align_matches_ref(d, h, w, c, seed):
    rng = np.random.default_rng(seed)
    feat = rand(rng, d, h, w, c)
    v = d * h * w
    idx = jnp.asarray(rng.integers(-1, v, size=(v,)).astype(np.int32))
    got = gather_align(feat, idx)
    want = ref.gather_align_ref(feat, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_gather_align_identity_is_noop():
    rng = np.random.default_rng(0)
    feat = rand(rng, 4, 8, 8, 6)
    idx = jnp.arange(4 * 8 * 8, dtype=jnp.int32)
    got = gather_align(feat, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(feat))


def test_gather_align_all_invalid_is_zero():
    rng = np.random.default_rng(1)
    feat = rand(rng, 2, 4, 4, 3)
    idx = jnp.full((2 * 4 * 4,), -1, dtype=jnp.int32)
    got = gather_align(feat, idx)
    assert np.all(np.asarray(got) == 0.0)


def test_max_integrate_canonical_shape():
    """The production shape (8, 64, 64, 8) runs through the kernel path."""
    rng = np.random.default_rng(2)
    a = rand(rng, 8, 64, 64, 8)
    b = rand(rng, 8, 64, 64, 8)
    got = max_integrate(a, b)
    want = ref.max_integrate_ref(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_conv_k3_z_boundary():
    """Zero padding at the z boundary (the kernel masks the halo)."""
    rng = np.random.default_rng(3)
    a = rand(rng, 2, 4, 4, 2)
    b = rand(rng, 2, 4, 4, 2)
    w = rand(rng, 3, 3, 3, 4, 2)
    bias = jnp.zeros((2,), jnp.float32)
    got = fused_integrate_conv(a, b, w, bias)
    want = ref.fused_integrate_conv_ref(a, b, w, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_fused_conv_rejects_even_kernel():
    rng = np.random.default_rng(4)
    a = rand(rng, 2, 4, 4, 2)
    w = rand(rng, 2, 2, 2, 4, 2)
    with pytest.raises(ValueError):
        fused_integrate_conv(a, a, w, jnp.zeros((2,), jnp.float32))
