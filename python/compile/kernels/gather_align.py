"""Pallas kernel: voxel-grid alignment gather (paper §III-A.2).

The coordinate transformation of intermediate outputs collapses to a
static gather (see align.py). The kernel tiles the flattened output
voxel axis; each step loads its index block and gathers the matching
rows of the (VMEM-resident) source feature map, zero-filling out-of-grid
voxels. A rigid transform of a regular grid preserves locality, so each
output tile reads a bounded source region — on real TPU the index map
would bound the HBM→VMEM window per tile; at the canonical feature-map
size the whole source fits in VMEM.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output rows gathered per grid step.
BLOCK = 2048


def _kernel(feat_ref, idx_ref, o_ref):
    idx = idx_ref[...]  # (BLOCK,)
    safe = jnp.maximum(idx, 0)
    rows = feat_ref[safe]  # (BLOCK, C)
    o_ref[...] = jnp.where((idx >= 0)[:, None], rows, 0.0)


def gather_align(feat, idx_map):
    """feat: (D, H, W, C) f32; idx_map: (V,) int32 -> aligned (D, H, W, C)."""
    d, h, w, c = feat.shape
    v = d * h * w
    assert idx_map.shape == (v,), (idx_map.shape, v)
    block = min(BLOCK, v)
    assert v % block == 0, "voxel count must divide the gather block"
    flat = feat.reshape(v, c)
    out = pl.pallas_call(
        _kernel,
        grid=(v // block,),
        in_specs=[
            pl.BlockSpec((v, c), lambda i: (0, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, c), feat.dtype),
        interpret=True,
    )(flat, idx_map.astype(jnp.int32))
    return out.reshape(d, h, w, c)
