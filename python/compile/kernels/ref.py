"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest asserts kernel ≍ ref under hypothesis sweeps)."""

import jax.numpy as jnp
from jax import lax


def max_integrate_ref(a, b):
    """Element-wise max of two (D, H, W, C) feature maps."""
    return jnp.maximum(a, b)


def fused_integrate_conv_ref(a, b, w, bias):
    """Concat along channels + conv3d ("same" zero padding).

    a, b: (D, H, W, C); w: (k, k, k, 2C, Co) (DHWIO); bias: (Co,).
    """
    x = jnp.concatenate([a, b], axis=-1)[None]  # NDHWC
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1, 1),
        padding="SAME",
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    )
    return out[0] + bias


def gather_align_ref(feat, idx_map):
    """feat: (D, H, W, C); idx_map: (V,) int32 flat source or -1."""
    d, h, w, c = feat.shape
    flat = feat.reshape(-1, c)
    safe = jnp.maximum(idx_map, 0)
    out = flat[safe]
    out = jnp.where((idx_map >= 0)[:, None], out, 0.0)
    return out.reshape(d, h, w, c)
