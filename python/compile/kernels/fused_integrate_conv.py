"""Pallas kernel: fused concat + conv3d integration (paper §III-A.3,
method 2 — the paper's best variant with kernel size 3).

Instead of materializing the concatenated (D, H, W, 2C) tensor in HBM and
running a separate conv (what the paper's PyTorch stack does), the kernel
fuses both: each grid step loads the two source z-slabs, forms the
concatenated receptive field in VMEM and contracts it against the weights
on the MXU.

TPU mapping (DESIGN.md §Hardware-Adaptation):
- grid over D (z-slabs). Output block (1, H, W, Co).
- k=1: the contraction is a (H·W, 2C) × (2C, Co) matmul — a clean MXU
  feed with the W·C panel laid out on lanes.
- k=3: inputs stay fully VMEM-resident (both maps are 256 KiB at the
  canonical 8·64·64·8 f32 — far under the ~16 MiB VMEM budget), and each
  step contracts the 27-tap neighborhood as 27 shifted matmuls, i.e. an
  implicit-GEMM conv with z-halo handled by zero-masking at the slab
  boundary. On larger grids the H axis would be tiled with a +1 halo via
  BlockSpec index maps; at the canonical size the full slab fits.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel_k1(a_ref, b_ref, w_ref, bias_ref, o_ref):
    # (1, H, W, C) slabs; contraction over 2C.
    a = a_ref[0]  # (H, W, C)
    b = b_ref[0]
    h, w, c = a.shape
    x = jnp.concatenate([a, b], axis=-1).reshape(h * w, 2 * c)
    wt = w_ref[0, 0, 0]  # (2C, Co)
    out = x @ wt + bias_ref[...]
    o_ref[0] = out.reshape(h, w, -1)


def _kernel_k3(a_ref, b_ref, w_ref, bias_ref, o_ref):
    # Full-residency inputs: a_ref/b_ref are (D, H, W, C); output one slab.
    iz = pl.program_id(0)
    d, h, w, c = a_ref.shape
    co = o_ref.shape[-1]
    acc = jnp.zeros((h * w, co), dtype=jnp.float32)
    for dz in range(3):
        z = iz + dz - 1
        z_ok = jnp.logical_and(z >= 0, z < d)
        zc = jnp.clip(z, 0, d - 1)
        a_slab = jnp.where(z_ok, a_ref[zc], 0.0)
        b_slab = jnp.where(z_ok, b_ref[zc], 0.0)
        x = jnp.concatenate([a_slab, b_slab], axis=-1)  # (H, W, 2C)
        # Pad H/W for the 3x3 in-plane taps.
        xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
        for dy in range(3):
            for dx in range(3):
                patch = xp[dy : dy + h, dx : dx + w, :].reshape(h * w, 2 * c)
                wt = w_ref[dz, dy, dx]  # (2C, Co)
                acc = acc + patch @ wt
    o_ref[0] = (acc + bias_ref[...]).reshape(h, w, co)


def fused_integrate_conv(a, b, w, bias):
    """a, b: (D, H, W, C); w: (k, k, k, 2C, Co) DHWIO; bias: (Co,)."""
    d, h, wd, c = a.shape
    k = w.shape[0]
    co = w.shape[-1]
    out_shape = jax.ShapeDtypeStruct((d, h, wd, co), a.dtype)
    bias_spec = pl.BlockSpec(bias.shape, lambda i: (0,))
    w_spec = pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0, 0))
    out_spec = pl.BlockSpec((1, h, wd, co), lambda i: (i, 0, 0, 0))
    if k == 1:
        slab = pl.BlockSpec((1, h, wd, c), lambda i: (i, 0, 0, 0))
        return pl.pallas_call(
            _kernel_k1,
            grid=(d,),
            in_specs=[slab, slab, w_spec, bias_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=True,
        )(a, b, w, bias)
    elif k == 3:
        full = pl.BlockSpec((d, h, wd, c), lambda i: (0, 0, 0, 0))
        return pl.pallas_call(
            _kernel_k3,
            grid=(d,),
            in_specs=[full, full, w_spec, bias_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=True,
        )(a, b, w, bias)
    raise ValueError(f"unsupported kernel size {k}")
