"""Pallas kernel: element-wise max integration (paper §III-A.3, method 1).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over D; each step
holds one (1, H, W, C) z-slab of both inputs in VMEM (64·64·8·4 B =
128 KiB per input per slab) and writes the max — a pure VPU op with unit
arithmetic intensity, so the schedule is bandwidth-bound and the slab
pipeline (double-buffered HBM↔VMEM) is the whole optimization.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; lowering stays identical so the HLO the rust runtime loads
is the same graph shape a TPU build would specialize.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(a_ref[...], b_ref[...])


def max_integrate(a, b):
    """a, b: (D, H, W, C) f32 -> (D, H, W, C) f32."""
    d, h, w, c = a.shape
    spec = pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(d,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)
