"""Dataset loading for the build-time training path.

Reads the npy files written by `scmii datagen` (rust) and prepares the
per-variant model inputs, including the merged-cloud view for the
input-integration baseline (mirrors rust voxel::merge_clouds exactly:
interleave devices, truncate to max_points)."""

import json
import os

import numpy as np

from .configs import CFG, PAD_Z


def load_split(data_dir, split):
    """Returns dict with points per device (N, P, 4), labels (N, M, 8)."""
    d = os.path.join(data_dir, split)
    points = []
    dev = 0
    while True:
        p = os.path.join(d, f"points_dev{dev}.npy")
        if not os.path.exists(p):
            break
        points.append(np.load(p).astype(np.float32))
        dev += 1
    if not points:
        raise FileNotFoundError(f"no points_dev*.npy under {d}")
    labels = np.load(os.path.join(d, "labels.npy")).astype(np.float32)
    return {"points": points, "labels": labels}


def load_calib(calib_path):
    """Returns list of 4x4 row-major transforms (device -> common)."""
    with open(calib_path) as f:
        calib = json.load(f)
    return [np.array(t, dtype=np.float64).reshape(4, 4) for t in calib["transforms"]]


def transform_points(points, mat4):
    """points (..., 4); mat4 (4,4) row-major. Pads stay pads."""
    xyz = points[..., :3]
    out = xyz @ mat4[:3, :3].T + mat4[:3, 3]
    res = np.concatenate([out, points[..., 3:4]], axis=-1).astype(np.float32)
    pad = points[..., 2] <= -999.0
    res[pad] = points[pad]
    return res


def merge_clouds_np(clouds, max_points):
    """Mirror of rust voxel::merge_clouds for one frame.

    clouds: list of (P, 4) arrays already in the common frame (pads
    filtered by caller or kept — we drop pads first like the rust
    pipeline's merge_to_common)."""
    live = [c[c[:, 2] > -999.0] for c in clouds]
    longest = max((len(c) for c in live), default=0)
    out = []
    for i in range(longest):
        for c in live:
            if i < len(c):
                out.append(c[i])
                if len(out) >= max_points:
                    break
        if len(out) >= max_points:
            break
    merged = np.stack(out) if out else np.zeros((0, 4), dtype=np.float32)
    if len(merged) < max_points:
        pad = np.zeros((max_points - len(merged), 4), dtype=np.float32)
        pad[:, 2] = PAD_Z
        merged = np.concatenate([merged, pad])
    return merged.astype(np.float32)


def build_merged_split(split, calib, max_points=None):
    """(N, P, 4) merged common-frame clouds for the whole split."""
    max_points = max_points or CFG.grid.max_points
    n = split["points"][0].shape[0]
    out = np.zeros((n, max_points, 4), dtype=np.float32)
    for i in range(n):
        clouds = [
            transform_points(dev_pts[i], calib[d])
            for d, dev_pts in enumerate(split["points"])
        ]
        out[i] = merge_clouds_np(clouds, max_points)
    return out
