"""Functional NN layers (no flax in the image): conv3d / conv2d /
transpose-conv with He init, parameters as nested dicts of jnp arrays."""

import math

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Initialization


def he_init(key, shape, fan_in):
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, shape, dtype=jnp.float32) * std


def conv3d_params(key, k, c_in, c_out):
    wkey, _ = jax.random.split(key)
    return {
        "w": he_init(wkey, (k, k, k, c_in, c_out), k * k * k * c_in),
        "b": jnp.zeros((c_out,), dtype=jnp.float32),
    }


def conv2d_params(key, k, c_in, c_out):
    wkey, _ = jax.random.split(key)
    return {
        "w": he_init(wkey, (k, k, c_in, c_out), k * k * c_in),
        "b": jnp.zeros((c_out,), dtype=jnp.float32),
    }


def deconv2d_params(key, k, c_in, c_out):
    wkey, _ = jax.random.split(key)
    return {
        "w": he_init(wkey, (k, k, c_in, c_out), k * k * c_in),
        "b": jnp.zeros((c_out,), dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# Forward ops (single example, no batch dim; vmap adds batching)


def conv3d(p, x, stride=1):
    """x: (D, H, W, C) -> (D', H', W', Co). "Same" padding.

    Implemented as a z-unrolled 2D convolution: the k z-taps are folded
    into the input channels and D becomes the conv batch. Numerically
    identical to `lax.conv_general_dilated` with DHWIO numbers but ~9x
    faster on CPU XLA, whose native 3D conv path is unvectorized
    (EXPERIMENTS.md §Perf L2). On TPU both forms fuse to the same MXU
    loops; the layout also matches the Pallas kernels' slab tiling.
    """
    w = p["w"]
    k = w.shape[0]
    d, h, wd, ci = x.shape
    if k == 1:
        out = lax.conv_general_dilated(
            x,
            w[0],
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return (out[::stride] if stride > 1 else out) + p["b"]
    assert k == 3, "k in {1, 3}"
    xm = jnp.pad(x, ((1, 1), (0, 0), (0, 0), (0, 0)))
    # Slab z has channel blocks [taps z-1, z, z+1].
    xs = jnp.concatenate([xm[0:d], xm[1 : d + 1], xm[2 : d + 2]], axis=-1)
    if stride > 1:
        # Match XLA's SAME stride-2 padding (pad_total = 1 -> pad_lo = 0):
        # output o is centered on input z = 2o + 1.
        assert stride == 2 and d % 2 == 0
        xs = xs[1::stride]
    # (kz, ky, kx, ci, co) -> (ky, kx, kz*ci, co), kz-major channel blocks.
    wm = jnp.transpose(w, (1, 2, 0, 3, 4)).reshape(k, k, k * ci, -1)
    out = lax.conv_general_dilated(
        xs,
        wm,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"]


def conv2d(p, x, stride=1):
    """x: (H, W, C) -> (H', W', Co). "Same" padding."""
    s = (stride, stride)
    out = lax.conv_general_dilated(
        x[None],
        p["w"],
        window_strides=s,
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0] + p["b"]


def deconv2d(p, x, stride=2):
    """x: (H, W, C) -> (H·s, W·s, Co) transpose conv."""
    out = lax.conv_transpose(
        x[None],
        p["w"],
        strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0] + p["b"]


def relu(x):
    return jnp.maximum(x, 0.0)
