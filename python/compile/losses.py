"""Detection losses: focal BCE on anchor objectness + smooth-L1 box
regression (SECOND/PointPillars-style)."""

import jax.numpy as jnp

FOCAL_ALPHA = 0.25
FOCAL_GAMMA = 2.0
BOX_WEIGHT = 2.0


def sigmoid_focal_loss(logits, targets):
    """Per-element focal loss; `targets` in {0, 1} (ignore-masking is the
    caller's job)."""
    p = 1.0 / (1.0 + jnp.exp(-logits))
    ce = -(
        targets * jnp.log(jnp.clip(p, 1e-7, 1.0))
        + (1 - targets) * jnp.log(jnp.clip(1 - p, 1e-7, 1.0))
    )
    p_t = targets * p + (1 - targets) * (1 - p)
    alpha_t = targets * FOCAL_ALPHA + (1 - targets) * (1 - FOCAL_ALPHA)
    return alpha_t * (1 - p_t) ** FOCAL_GAMMA * ce


def smooth_l1(pred, target):
    d = jnp.abs(pred - target)
    return jnp.where(d < 1.0, 0.5 * d * d, d - 0.5)


def detection_loss(cls_logits, box_pred, cls_target, box_target):
    """cls_logits (Hb,Wb,A), box_pred (Hb,Wb,A,8); targets likewise.
    cls_target in {-1 (ignore), 0, 1}. Returns (total, cls, box) scalars.
    """
    valid = cls_target >= 0.0
    pos = cls_target > 0.5
    n_pos = jnp.maximum(pos.sum(), 1.0)

    cls_l = sigmoid_focal_loss(cls_logits, jnp.clip(cls_target, 0.0, 1.0))
    cls_l = jnp.where(valid, cls_l, 0.0).sum() / n_pos

    box_l = smooth_l1(box_pred, box_target).sum(axis=-1)
    box_l = jnp.where(pos, box_l, 0.0).sum() / n_pos

    total = cls_l + BOX_WEIGHT * box_l
    return total, cls_l, box_l
