"""VoxelDet — dense emulation of Voxel R-CNN shaped for SC-MII.

Split point (paper §IV-B): immediately after the first 3D convolution
following voxelization. Everything before it is the **head** (edge
device); everything after — alignment, integration, 3D backbone, BEV
projection, 2D backbone, detection heads — is the **tail** (edge server).

Sparse 3D convolution (spconv) is emulated densely: infrastructure-scale
grids (64·64·8) make dense conv3d affordable and MXU-friendly (DESIGN.md
§Hardware-Adaptation). The two-stage RoI refinement of Voxel R-CNN is
out of scope for the reproduction's claims (split + integration algebra
are unchanged); see DESIGN.md §4.

All functions are single-example; training vmaps over the batch.
"""

import jax
import jax.numpy as jnp

from . import layers
from .configs import CFG, ModelConfig
from .kernels.fused_integrate_conv import fused_integrate_conv
from .kernels.gather_align import gather_align
from .kernels.max_integrate import max_integrate
from .voxelize import voxelize

# ---------------------------------------------------------------------------
# Parameter initialization


def init_head_params(key, cfg: ModelConfig = CFG):
    """Head: voxelize -> conv3d(3) c_in -> c_head + ReLU (the split point)."""
    return {"stem": layers.conv3d_params(key, 3, cfg.grid.c_in, cfg.grid.c_head)}


def init_integration_params(key, variant, cfg: ModelConfig = CFG):
    c = cfg.grid.c_head
    if variant == "max":
        return {}
    k = 1 if variant == "conv_k1" else 3
    p = layers.conv3d_params(key, k, cfg.num_devices * c, c)
    # Identity-biased init: the center tap starts as mean-fusion
    # (out_ch ← 0.5·dev0_ch + 0.5·dev1_ch) plus small noise, so the
    # integration conv begins as a sensible fusion instead of scrambling
    # the stem features — markedly faster convergence for conv_k3.
    w = p["w"] * 0.1
    mid = k // 2
    for dev in range(cfg.num_devices):
        idx = jnp.arange(c)
        w = w.at[mid, mid, mid, dev * c + idx, idx].add(1.0 / cfg.num_devices)
    return {"conv": {"w": w, "b": p["b"]}}


def init_backbone_params(key, cfg: ModelConfig = CFG):
    keys = jax.random.split(key, 8)
    c1, c2, c3, cb = cfg.grid.c_head, cfg.c_block2, cfg.c_block3, cfg.c_bev
    a = cfg.n_anchors
    return {
        "block2_down": layers.conv3d_params(keys[0], 3, c1, c2),
        "block2_conv": layers.conv3d_params(keys[1], 3, c2, c2),
        "block3_down": layers.conv3d_params(keys[2], 3, c2, c3),
        "block3_conv": layers.conv3d_params(keys[3], 3, c3, c3),
        "bev_conv1": layers.conv2d_params(keys[4], 3, 2 * c3, cb),
        "bev_conv2": layers.conv2d_params(keys[5], 3, cb, cb),
        "up": layers.deconv2d_params(keys[6], 2, cb, cb),
        "head_cls": layers.conv2d_params(keys[7], 1, cb, a),
        "head_box": layers.conv2d_params(jax.random.fold_in(key, 99), 1, cb, a * 8),
    }


def init_variant_params(key, variant, cfg: ModelConfig = CFG):
    """Full parameter set for one SC-MII variant (per-device heads differ,
    as in the paper: same architecture, parameters diverge in training)."""
    keys = jax.random.split(key, cfg.num_devices + 2)
    return {
        "heads": [init_head_params(keys[i], cfg) for i in range(cfg.num_devices)],
        "integration": init_integration_params(keys[-2], variant, cfg),
        "backbone": init_backbone_params(keys[-1], cfg),
    }


def init_single_params(key, cfg: ModelConfig = CFG):
    """Single-LiDAR / input-integration full model: one head + backbone."""
    k1, k2 = jax.random.split(key)
    return {"head": init_head_params(k1, cfg), "backbone": init_backbone_params(k2, cfg)}


# ---------------------------------------------------------------------------
# Forward passes


def head_fn(params, points, cfg: ModelConfig = CFG):
    """Edge-device part: (N, 4) points -> (D, H, W, c_head) features."""
    vox = voxelize(points, cfg.grid)
    return layers.relu(layers.conv3d(params["stem"], vox, stride=1))


def integrate_fn(params, feats, variant, align_maps, cfg: ModelConfig = CFG,
                 use_kernels: bool = True):
    """Server-side alignment + integration.

    feats: list of (D, H, W, c_head), one per device, in device-local
    grids. align_maps: list of (V,) int32 gather maps (device -> common);
    map 0 is identity (device 0 is the reference).

    `use_kernels=True` routes through the Pallas kernels (the serving
    graphs lowered by aot.py); training passes False to use the pure-jnp
    oracles instead — `pallas_call` has no reverse-mode rule, and pytest
    pins kernel ≍ ref so the swap is behaviour-preserving.
    """
    from .kernels import ref

    g_align = gather_align if use_kernels else ref.gather_align_ref
    aligned = [
        g_align(f, m) if m is not None else f for f, m in zip(feats, align_maps)
    ]
    if variant == "max":
        f_max = max_integrate if use_kernels else ref.max_integrate_ref
        out = aligned[0]
        for f in aligned[1:]:
            out = f_max(out, f)
        return out
    assert len(aligned) == 2, "fused kernel takes two device maps"
    f_conv = fused_integrate_conv if use_kernels else ref.fused_integrate_conv_ref
    return layers.relu(
        f_conv(aligned[0], aligned[1], params["conv"]["w"], params["conv"]["b"])
    )


def backbone_fn(params, feat, cfg: ModelConfig = CFG):
    """3D backbone -> BEV -> 2D backbone -> (cls, box) heads.

    feat: (D, H, W, c_head) integrated features in the common grid.
    Returns cls (Hb, Wb, A) logits and box (Hb, Wb, A, 8) deltas.
    """
    x = layers.relu(layers.conv3d(params["block2_down"], feat, stride=2))
    x = layers.relu(layers.conv3d(params["block2_conv"], x, stride=1))
    x = layers.relu(layers.conv3d(params["block3_down"], x, stride=2))
    x = layers.relu(layers.conv3d(params["block3_conv"], x, stride=1))
    # (2, 16, 16, c3) -> BEV (16, 16, 2*c3)
    d, h, w, c = x.shape
    bev = jnp.transpose(x, (1, 2, 0, 3)).reshape(h, w, d * c)
    y = layers.relu(layers.conv2d(params["bev_conv1"], bev))
    y = layers.relu(layers.conv2d(params["bev_conv2"], y))
    y = layers.relu(layers.deconv2d(params["up"], y, stride=2))  # (32, 32, cb)
    cls = layers.conv2d(params["head_cls"], y)  # (Hb, Wb, A)
    box = layers.conv2d(params["head_box"], y)
    hb, wb, _ = box.shape
    box = box.reshape(hb, wb, cfg.n_anchors, 8)
    return cls, box


def scmii_fn(params, points_list, variant, align_maps, cfg: ModelConfig = CFG,
             use_kernels: bool = True):
    """End-to-end SC-MII: per-device heads -> alignment -> integration ->
    backbone. Training passes use_kernels=False (see integrate_fn)."""
    feats = [
        head_fn(hp, pts, cfg) for hp, pts in zip(params["heads"], points_list)
    ]
    fused = integrate_fn(
        params.get("integration", {}), feats, variant, align_maps, cfg, use_kernels
    )
    return backbone_fn(params["backbone"], fused, cfg)


def tail_fn(params, feats, variant, align_maps, cfg: ModelConfig = CFG,
            use_kernels: bool = True):
    """Server-side inference graph: device features -> (cls, box)."""
    fused = integrate_fn(
        params.get("integration", {}), feats, variant, align_maps, cfg, use_kernels
    )
    return backbone_fn(params["backbone"], fused, cfg)


def single_fn(params, points, cfg: ModelConfig = CFG):
    """Full single-cloud model (single-LiDAR and input-integration
    baselines): points are already in the frame the model detects in."""
    feat = head_fn(params["head"], points, cfg)
    return backbone_fn(params["backbone"], feat, cfg)
