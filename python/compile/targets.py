"""Anchor target assignment (numpy, precomputed once per dataset).

Distance-based matching (CenterPoint-style simplification of the IoU
assigner — vectorizes cleanly in numpy; evaluation still uses rotated
IoU on the rust side):

- positive: anchor center within `pos_radius` of a GT center of the
  anchor's class (cars additionally require the anchor yaw to be the
  closer of the two car-anchor orientations, mod π);
- the nearest eligible anchor of each GT is force-positive (so no GT
  goes unassigned);
- negative: no GT of the class within `neg_radius`;
- in between: ignored (cls target -1).

Box regression targets use the SECOND-style encoding shared with
rust/src/model/mod.rs::encode_box.
"""

import math

import numpy as np

from .configs import CFG, ModelConfig

POS_RADIUS = {0: 1.4, 1: 0.9}  # per class, metres
NEG_RADIUS = {0: 2.8, 1: 1.8}


def anchor_grid(cfg: ModelConfig = CFG):
    """Return (Hb, Wb, A, 2) anchor centers (x, y) and per-anchor specs."""
    hb, wb = cfg.bev_dims
    g = cfg.grid
    cell_x = (g.range_max[0] - g.range_min[0]) / wb
    cell_y = (g.range_max[1] - g.range_min[1]) / hb
    xs = g.range_min[0] + (np.arange(wb) + 0.5) * cell_x
    ys = g.range_min[1] + (np.arange(hb) + 0.5) * cell_y
    cx, cy = np.meshgrid(xs, ys)  # (Hb, Wb), row = y
    centers = np.stack([cx, cy], axis=-1)  # (Hb, Wb, 2)
    return centers


def encode_box(gt, anchor_center, anchor):
    """Mirror of rust model::encode_box. gt: [x,y,z,l,w,h,yaw]."""
    ax, ay = anchor_center
    al, aw, ah = anchor.size
    diag = math.sqrt(al * al + aw * aw)
    dyaw = gt[6] - anchor.yaw
    return np.array(
        [
            (gt[0] - ax) / diag,
            (gt[1] - ay) / diag,
            (gt[2] - anchor.z_center) / ah,
            math.log(max(gt[3], 1e-3) / al),
            math.log(max(gt[4], 1e-3) / aw),
            math.log(max(gt[5], 1e-3) / ah),
            math.sin(dyaw),
            math.cos(dyaw),
        ],
        dtype=np.float32,
    )


def _car_anchor_pref(gt_yaw, cfg):
    """Index (0 or 1) of the car anchor whose yaw is closer mod π."""
    best, best_d = 0, 1e9
    for k, a in enumerate(cfg.anchors):
        if a.class_id != 0:
            continue
        d = abs(((gt_yaw - a.yaw) + math.pi / 2) % math.pi - math.pi / 2)
        if d < best_d:
            best, best_d = k, d
    return best


def assign_frame(labels, cfg: ModelConfig = CFG):
    """labels: (M, 8) [x,y,z,l,w,h,yaw,class_id] (class_id -1 = pad).

    Returns cls_target (Hb, Wb, A) in {-1, 0, 1} and box_target
    (Hb, Wb, A, 8) (zeros where not positive).
    """
    hb, wb = cfg.bev_dims
    A = cfg.n_anchors
    centers = anchor_grid(cfg)  # (Hb, Wb, 2)
    cls_t = np.zeros((hb, wb, A), dtype=np.float32)
    box_t = np.zeros((hb, wb, A, 8), dtype=np.float32)

    valid = labels[labels[:, 7] >= 0] if len(labels) else labels
    if len(valid) == 0:
        return cls_t, box_t

    flat_centers = centers.reshape(-1, 2)  # (Hb*Wb, 2)

    # Ignore band first (per class), then positives overwrite.
    for cls_id in (0, 1):
        gts = valid[valid[:, 7] == cls_id]
        if len(gts) == 0:
            continue
        d = np.linalg.norm(
            flat_centers[:, None, :] - gts[None, :, :2], axis=-1
        )  # (cells, M)
        dmin = d.min(axis=1).reshape(hb, wb)
        anchor_ids = [k for k, a in enumerate(cfg.anchors) if a.class_id == cls_id]
        for k in anchor_ids:
            ignore = (dmin < NEG_RADIUS[cls_id]) & (dmin >= POS_RADIUS[cls_id])
            cls_t[:, :, k][ignore] = -1.0

    for gt in valid:
        cls_id = int(gt[7])
        k = _car_anchor_pref(gt[6], cfg) if cls_id == 0 else next(
            i for i, a in enumerate(cfg.anchors) if a.class_id == 1
        )
        anchor = cfg.anchors[k]
        d = np.linalg.norm(flat_centers - gt[:2], axis=-1).reshape(hb, wb)
        pos = d < POS_RADIUS[cls_id]
        # Force the nearest cell positive.
        nearest = np.unravel_index(np.argmin(d), d.shape)
        pos[nearest] = True
        rows, cols = np.nonzero(pos)
        for r, c in zip(rows, cols):
            cls_t[r, c, k] = 1.0
            box_t[r, c, k] = encode_box(gt[:7], centers[r, c], anchor)
    return cls_t, box_t


def assign_split(labels_all, cfg: ModelConfig = CFG):
    """labels_all: (N, M, 8) -> stacked targets for the whole split."""
    cls_list, box_list = [], []
    for labels in labels_all:
        c, b = assign_frame(labels, cfg)
        cls_list.append(c)
        box_list.append(b)
    return np.stack(cls_list), np.stack(box_list)
