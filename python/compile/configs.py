"""Canonical model/grid configuration — single source of truth for the
python build path; `aot.py` serializes it into artifacts/model_meta.json
which the rust runtime (rust/src/config/meta.rs) parses. The rust-side
defaults mirror these values for artifact-free unit tests.
"""

from dataclasses import dataclass, field
import math

# Padding sentinel for point tensors (matches rust voxel::Point::pad()).
PAD_Z = -1000.0
# Count clip in voxel feature 0 (matches rust VOXEL_COUNT_CLIP).
COUNT_CLIP = 16.0


@dataclass(frozen=True)
class GridConfig:
    """Detection grid in the common (sensor-1) frame.

    The sensor sits ~4.5 m above ground so the volume lies below the
    origin; x/y bounds cover the intersection (see rust config docs).
    """

    range_min: tuple = (-18.1, -18.1, -6.0)
    range_max: tuple = (33.1, 33.1, 0.0)
    voxel: tuple = (0.8, 0.8, 0.75)
    dims: tuple = (64, 64, 8)  # (W, H, D) = x, y, z cells
    c_in: int = 6
    c_head: int = 8
    max_points: int = 4096

    @property
    def W(self):
        return self.dims[0]

    @property
    def H(self):
        return self.dims[1]

    @property
    def D(self):
        return self.dims[2]

    def n_voxels(self):
        return self.W * self.H * self.D


@dataclass(frozen=True)
class Anchor:
    size: tuple  # (l, w, h)
    z_center: float
    yaw: float
    class_id: int


@dataclass(frozen=True)
class ModelConfig:
    grid: GridConfig = field(default_factory=GridConfig)
    classes: tuple = ("car", "pedestrian")
    # Ground sits at z = -4.5 in the common frame.
    anchors: tuple = (
        Anchor((4.5, 1.9, 1.6), -3.7, 0.0, 0),
        Anchor((4.5, 1.9, 1.6), -3.7, math.pi / 2, 0),
        Anchor((0.8, 0.8, 1.7), -3.65, 0.0, 1),
    )
    bev_dims: tuple = (32, 32)  # (rows = y, cols = x)
    # Backbone channel plan.
    c_block2: int = 16
    c_block3: int = 32
    c_bev: int = 64
    num_devices: int = 2

    @property
    def n_anchors(self):
        return len(self.anchors)


CFG = ModelConfig()

# Integration variants (paper §III-A.3) and baseline artifact names —
# shared with rust config::meta::IntegrationKind.
VARIANTS = ("max", "conv_k1", "conv_k3")


def head_name(variant, device):
    return f"head_{variant}_dev{device}"


def tail_name(variant):
    return f"tail_{variant}"


def single_name(device):
    return f"single_dev{device}"


INPUT_INTEGRATION = "input_integration"
