"""Build-time training (paper §III-B.3: centralized, offline).

Trains all six model configurations used by the evaluation:
  - SC-MII variants: max, conv_k1, conv_k3 (per-device heads + shared
    tail, trained end-to-end through the alignment gather);
  - single-LiDAR baselines (device 0 and 1);
  - input-point-cloud-integration baseline (merged raw clouds).

Hand-rolled Adam (no optax in the image); parameters are nested dicts
saved as flat npz under artifacts/weights/. Loss curves are logged to
weights/loss_log.json and summarized in EXPERIMENTS.md.

Coordinate transforms come from artifacts/calib.json — the NDT estimate,
not the simulator truth, exactly as the paper's setup phase prescribes.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import targets as targets_mod
from .align import build_align_map
from .configs import CFG, INPUT_INTEGRATION, VARIANTS, single_name
from .losses import detection_loss

# ---------------------------------------------------------------------------
# Parameter tree <-> flat npz


def flatten_params(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_params(v, f"{prefix}{k}."))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_params(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def unflatten_params(flat):
    tree = {}
    for key, value in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(value)

    def listify(node):
        if isinstance(node, dict):
            if node and all(k.isdigit() for k in node):
                return [listify(node[str(i)]) for i in range(len(node))]
            return {k: listify(v) for k, v in node.items()}
        return node

    return listify(tree)


# ---------------------------------------------------------------------------
# Adam


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, clip=10.0):
    # Global-norm gradient clipping.
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves) + 1e-12)
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def cosine_lr(base, step, total, warmup=20):
    warm = jnp.minimum(step / warmup, 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return base * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


# ---------------------------------------------------------------------------
# Training loops


def train_model(key, params, batched_loss, dataset_arrays, steps, batch, base_lr, tag):
    """Generic loop. `batched_loss(params, *batch_arrays) -> scalar`."""
    n = dataset_arrays[0].shape[0]
    state = adam_init(params)

    @jax.jit
    def step_fn(params, state, lr, *args):
        (loss, aux), grads = jax.value_and_grad(batched_loss, has_aux=True)(params, *args)
        params, state = adam_update(params, grads, state, lr)
        return params, state, loss, aux

    rng = np.random.default_rng(abs(hash(tag)) % (2**32))
    log = []
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        args = [jnp.asarray(a[idx]) for a in dataset_arrays]
        lr = cosine_lr(base_lr, step, steps)
        params, state, loss, aux = step_fn(params, state, lr, *args)
        if step % 10 == 0 or step == steps - 1:
            cls_l, box_l = float(aux[0]), float(aux[1])
            log.append(
                {"step": step, "loss": float(loss), "cls": cls_l, "box": box_l}
            )
            print(
                f"[{tag}] step {step:4d} loss {float(loss):8.4f} "
                f"(cls {cls_l:7.4f} box {box_l:7.4f}) "
                f"{time.time() - t0:6.1f}s",
                flush=True,
            )
    return params, log


def make_scmii_loss(variant, align_maps):
    maps = [None] + [jnp.asarray(m, dtype=jnp.int32) for m in align_maps[1:]]

    def single(params, pts0, pts1, cls_t, box_t):
        cls, box = model_mod.scmii_fn(
            params, [pts0, pts1], variant, maps, CFG, use_kernels=False
        )
        return detection_loss(cls, box, cls_t, box_t)

    def batched(params, pts0, pts1, cls_t, box_t):
        total, cls_l, box_l = jax.vmap(single, in_axes=(None, 0, 0, 0, 0))(
            params, pts0, pts1, cls_t, box_t
        )
        return total.mean(), (cls_l.mean(), box_l.mean())

    return batched


def make_single_loss(align_map):
    amap = None if align_map is None else jnp.asarray(align_map, dtype=jnp.int32)

    def single(params, pts, cls_t, box_t):
        feat = model_mod.head_fn(params["head"], pts, CFG)
        if amap is not None:
            from .kernels.ref import gather_align_ref

            feat = gather_align_ref(feat, amap)
        cls, box = model_mod.backbone_fn(params["backbone"], feat, CFG)
        return detection_loss(cls, box, cls_t, box_t)

    def batched(params, pts, cls_t, box_t):
        total, cls_l, box_l = jax.vmap(single, in_axes=(None, 0, 0, 0))(
            params, pts, cls_t, box_t
        )
        return total.mean(), (cls_l.mean(), box_l.mean())

    return batched


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../data")
    ap.add_argument("--calib", default="../artifacts/calib.json")
    ap.add_argument("--out", default="../artifacts/weights")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("SCMII_STEPS", 900)))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--skip-existing",
        action="store_true",
        help="skip models whose .npz already exists in --out (resume support)",
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    split = data_mod.load_split(args.data, "train")
    calib = data_mod.load_calib(args.calib)
    print(f"train frames: {split['points'][0].shape[0]}, devices: {len(split['points'])}")

    print("assigning anchor targets ...", flush=True)
    cls_t, box_t = targets_mod.assign_split(split["labels"], CFG)
    print(f"positives/frame: {(cls_t > 0.5).sum() / len(cls_t):.1f}")

    align_maps = [None] + [
        build_align_map(CFG.grid, calib[d].reshape(-1), 1)
        for d in range(1, len(calib))
    ]

    key = jax.random.PRNGKey(args.seed)
    logs = {}

    def done_already(tag):
        path = os.path.join(args.out, f"{tag}.npz")
        if args.skip_existing and os.path.exists(path):
            print(f"[{tag}] exists, skipping")
            return True
        return False

    # SC-MII variants.
    for i, variant in enumerate(VARIANTS):
        if done_already(variant):
            continue
        params = model_mod.init_variant_params(jax.random.fold_in(key, i), variant, CFG)
        loss_fn = make_scmii_loss(variant, align_maps)
        arrays = (split["points"][0], split["points"][1], cls_t, box_t)
        params, log = train_model(
            jax.random.fold_in(key, 100 + i), params, loss_fn, arrays,
            args.steps, args.batch, args.lr, variant,
        )
        np.savez(os.path.join(args.out, f"{variant}.npz"), **flatten_params(params))
        logs[variant] = log

    # Single-LiDAR baselines (device 1 detects in its local frame, then
    # aligns its features into the common frame — it still needs the
    # extrinsics to report in the shared ground-truth frame).
    for dev in range(len(split["points"])):
        if done_already(single_name(dev)):
            continue
        params = model_mod.init_single_params(jax.random.fold_in(key, 200 + dev), CFG)
        amap = align_maps[dev]
        loss_fn = make_single_loss(amap)
        arrays = (split["points"][dev], cls_t, box_t)
        tag = single_name(dev)
        params, log = train_model(
            jax.random.fold_in(key, 300 + dev), params, loss_fn, arrays,
            args.steps, args.batch, args.lr, tag,
        )
        np.savez(os.path.join(args.out, f"{tag}.npz"), **flatten_params(params))
        logs[tag] = log

    # Input-integration baseline on merged common-frame clouds.
    if done_already(INPUT_INTEGRATION):
        with open(os.path.join(args.out, "loss_log.json"), "w") as f:
            json.dump(logs, f, indent=1)
        with open(os.path.join(args.out, "DONE"), "w") as f:
            f.write("ok\n")
        print("training complete (resumed)")
        return
    print("merging clouds for the input-integration baseline ...", flush=True)
    merged = data_mod.build_merged_split(split, calib)
    params = model_mod.init_single_params(jax.random.fold_in(key, 400), CFG)
    loss_fn = make_single_loss(None)
    params, log = train_model(
        jax.random.fold_in(key, 500), params, loss_fn, (merged, cls_t, box_t),
        args.steps, args.batch, args.lr, INPUT_INTEGRATION,
    )
    np.savez(
        os.path.join(args.out, f"{INPUT_INTEGRATION}.npz"), **flatten_params(params)
    )
    logs[INPUT_INTEGRATION] = log

    with open(os.path.join(args.out, "loss_log.json"), "w") as f:
        json.dump(logs, f, indent=1)
    with open(os.path.join(args.out, "DONE"), "w") as f:
        f.write("ok\n")
    print("training complete")


if __name__ == "__main__":
    main()
