"""AOT lowering: trained jax models -> HLO text artifacts + model_meta.json.

HLO **text** (not `.serialize()`): the image's xla_extension 0.5.1
rejects jax>=0.5 protos whose instruction ids exceed INT_MAX; the text
parser reassigns ids (see /opt/xla-example/README.md). Every function is
lowered with `return_tuple=True`; the rust runtime decomposes the tuple.

Artifacts per SC-MII variant v ∈ {max, conv_k1, conv_k3}:
  head_{v}_dev{i}.hlo.txt   (P,4) points -> (D,H,W,C) features
  tail_{v}.hlo.txt          per-device features -> (cls, box)
Baselines:
  single_dev{i}.hlo.txt     (P,4) -> (cls, box)   (full model)
  input_integration.hlo.txt (P,4) merged common-frame points -> (cls, box)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .align import build_align_map
from .configs import (
    CFG,
    INPUT_INTEGRATION,
    VARIANTS,
    head_name,
    single_name,
    tail_name,
)
from .data import load_calib
from .train import unflatten_params


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides
    # big literals as `{...}`, which the consuming XLA text parser happily
    # accepts and fills with garbage — every baked weight/align-map would
    # silently corrupt (this cost us a debugging session; see
    # EXPERIMENTS.md "Reproduction notes").
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, example_args, out_path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"wrote {out_path} ({len(text)} chars)")


def load_weights(weights_dir, name):
    flat = dict(np.load(os.path.join(weights_dir, f"{name}.npz")))
    return unflatten_params(flat)


def meta_json():
    g = CFG.grid
    return {
        "grid": {
            "range_min": list(g.range_min),
            "range_max": list(g.range_max),
            "voxel": list(g.voxel),
            "dims": list(g.dims),
            "c_in": g.c_in,
            "c_head": g.c_head,
            "max_points": g.max_points,
        },
        "classes": list(CFG.classes),
        "anchors": [
            {
                "size": list(a.size),
                "z_center": a.z_center,
                "yaw": a.yaw,
                "class_id": a.class_id,
            }
            for a in CFG.anchors
        ],
        "bev_dims": list(CFG.bev_dims),
        "variants": [
            {
                "integration": v,
                "heads": [head_name(v, d) for d in range(CFG.num_devices)],
                "tail": tail_name(v),
            }
            for v in VARIANTS
        ],
        "single_full": [single_name(d) for d in range(CFG.num_devices)],
        "input_integration_full": INPUT_INTEGRATION,
        "num_devices": CFG.num_devices,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--weights", default="../artifacts/weights")
    ap.add_argument("--calib", default="../artifacts/calib.json")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    g = CFG.grid
    points_spec = jax.ShapeDtypeStruct((g.max_points, 4), jnp.float32)
    feat_spec = jax.ShapeDtypeStruct((g.D, g.H, g.W, g.c_head), jnp.float32)

    calib = load_calib(args.calib)
    align_maps = [None] + [
        jnp.asarray(build_align_map(g, calib[d].reshape(-1), 1), dtype=jnp.int32)
        for d in range(1, len(calib))
    ]

    for variant in VARIANTS:
        params = load_weights(args.weights, variant)
        for dev in range(CFG.num_devices):
            head_params = params["heads"][dev]

            def head(points, hp=head_params):
                return (model_mod.head_fn(hp, points, CFG),)

            lower_and_write(
                head,
                (points_spec,),
                os.path.join(args.out, f"{head_name(variant, dev)}.hlo.txt"),
            )

        def tail(*feats, p=params, v=variant):
            return model_mod.tail_fn(p, list(feats), v, align_maps, CFG)

        lower_and_write(
            tail,
            tuple(feat_spec for _ in range(CFG.num_devices)),
            os.path.join(args.out, f"{tail_name(variant)}.hlo.txt"),
        )

    # Baselines.
    for dev in range(CFG.num_devices):
        params = load_weights(args.weights, single_name(dev))
        amap = align_maps[dev]

        def single(points, p=params, m=amap):
            feat = model_mod.head_fn(p["head"], points, CFG)
            if m is not None:
                from .kernels.gather_align import gather_align

                feat = gather_align(feat, m)
            return model_mod.backbone_fn(p["backbone"], feat, CFG)

        lower_and_write(
            single,
            (points_spec,),
            os.path.join(args.out, f"{single_name(dev)}.hlo.txt"),
        )

    params = load_weights(args.weights, INPUT_INTEGRATION)

    def input_integration(points, p=params):
        return model_mod.single_fn(p, points, CFG)

    lower_and_write(
        input_integration,
        (points_spec,),
        os.path.join(args.out, f"{INPUT_INTEGRATION}.hlo.txt"),
    )

    with open(os.path.join(args.out, "model_meta.json"), "w") as f:
        json.dump(meta_json(), f, indent=1)
    print("wrote model_meta.json")


if __name__ == "__main__":
    main()
