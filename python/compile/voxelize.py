"""Point-cloud voxelization in jnp (mirrors rust/src/voxel/features.rs).

Produces a dense (D, H, W, 6) feature map from an (N, 4) point tensor via
segment-sum scatter. Pad points (z <= -999) and out-of-range points fall
into a discard bin. The six statistics per occupied voxel:

  0: min(count, CLIP)/CLIP
  1: mean x offset / dx      2: mean y offset / dy
  3: mean z offset / dz      4: mean intensity
  5: (max_z - z_min) / z_span
"""

import jax.numpy as jnp
import jax

from .configs import COUNT_CLIP, GridConfig


def voxelize(points, grid: GridConfig):
    """points: (N, 4) f32 [x, y, z, intensity] -> (D, H, W, 6) f32."""
    W, H, D = grid.dims
    n_vox = W * H * D
    x, y, z, inten = points[:, 0], points[:, 1], points[:, 2], points[:, 3]

    fx = (x - grid.range_min[0]) / grid.voxel[0]
    fy = (y - grid.range_min[1]) / grid.voxel[1]
    fz = (z - grid.range_min[2]) / grid.voxel[2]
    ix = jnp.floor(fx).astype(jnp.int32)
    iy = jnp.floor(fy).astype(jnp.int32)
    iz = jnp.floor(fz).astype(jnp.int32)

    valid = (
        (fx >= 0)
        & (fy >= 0)
        & (fz >= 0)
        & (ix < W)
        & (iy < H)
        & (iz < D)
        & (z > -999.0)
    )
    flat = (iz * H + iy) * W + ix
    flat = jnp.where(valid, flat, n_vox)  # discard bin

    # Offsets from voxel centers (normalized by voxel size).
    cx = grid.range_min[0] + (ix.astype(jnp.float32) + 0.5) * grid.voxel[0]
    cy = grid.range_min[1] + (iy.astype(jnp.float32) + 0.5) * grid.voxel[1]
    cz = grid.range_min[2] + (iz.astype(jnp.float32) + 0.5) * grid.voxel[2]
    dx = (x - cx) / grid.voxel[0]
    dy = (y - cy) / grid.voxel[1]
    dz = (z - cz) / grid.voxel[2]

    ns = n_vox + 1
    # One fused scatter for all sum statistics (5 columns) — a single
    # segment_sum over an (N, 5) matrix is ~4x faster on CPU XLA than five
    # scalar scatters (see EXPERIMENTS.md §Perf L2).
    cols = jnp.stack([valid.astype(jnp.float32), dx, dy, dz, inten], axis=-1)
    cols = jnp.where(valid[:, None], cols, 0.0)
    sums = jax.ops.segment_sum(cols, flat, num_segments=ns)
    max_z = jax.ops.segment_max(
        jnp.where(valid, z, -jnp.inf), flat, num_segments=ns
    )

    count = sums[:n_vox, 0]
    sum_dx = sums[:, 1]
    sum_dy = sums[:, 2]
    sum_dz = sums[:, 3]
    sum_i = sums[:, 4]
    occupied = count > 0
    inv_n = jnp.where(occupied, 1.0 / jnp.maximum(count, 1.0), 0.0)
    z_span = grid.range_max[2] - grid.range_min[2]

    f0 = jnp.minimum(count, COUNT_CLIP) / COUNT_CLIP
    f1 = sum_dx[:n_vox] * inv_n
    f2 = sum_dy[:n_vox] * inv_n
    f3 = sum_dz[:n_vox] * inv_n
    f4 = sum_i[:n_vox] * inv_n
    f5 = jnp.where(
        occupied, (max_z[:n_vox] - grid.range_min[2]) / z_span, 0.0
    )
    feats = jnp.stack([f0, f1, f2, f3, f4, f5], axis=-1)
    return feats.reshape(D, H, W, 6)
