"""Coordinate transformation of intermediate outputs (paper §III-A.2).

Builds the static gather index map realizing the voxel-index → physical →
rigid-transform → voxel-index chain; mirrors rust/src/align/mod.rs
(including rust's round-half-away-from-zero). The map is baked as a
constant into the tail HLO, so the server's alignment runs inside the
compiled graph.
"""

import numpy as np

from .configs import GridConfig


def _round_half_away(x):
    """Match rust f64::round (half away from zero); np.rint is half-even."""
    return np.where(x >= 0, np.floor(x + 0.5), np.ceil(x - 0.5))


def build_align_map(grid: GridConfig, device_to_common_4x4, stride: int = 1):
    """Return (V,) int64: for each output voxel (common grid, flattened
    (D,H,W)) the flat source voxel in the device grid, or -1.

    `device_to_common_4x4`: row-major 16-vector or (4,4) array mapping
    device-local coordinates into the common frame.
    """
    m = np.asarray(device_to_common_4x4, dtype=np.float64).reshape(4, 4)
    rot, trans = m[:3, :3], m[:3, 3]
    # common -> device
    inv_rot = rot.T
    inv_trans = -inv_rot @ trans

    W, H, D = grid.dims
    Ws, Hs, Ds = W // stride, H // stride, D // stride
    eff = np.array(grid.voxel) * stride
    rmin = np.array(grid.range_min)

    iz, iy, ix = np.meshgrid(
        np.arange(Ds), np.arange(Hs), np.arange(Ws), indexing="ij"
    )
    # Voxel centers in the common frame.
    px = rmin[0] + (ix + 0.5) * eff[0]
    py = rmin[1] + (iy + 0.5) * eff[1]
    pz = rmin[2] + (iz + 0.5) * eff[2]
    pts = np.stack([px, py, pz], axis=-1).reshape(-1, 3)
    local = pts @ inv_rot.T + inv_trans

    f = (local - rmin) / eff - 0.5
    j = _round_half_away(f).astype(np.int64)
    jx, jy, jz = j[:, 0], j[:, 1], j[:, 2]
    valid = (
        (jx >= 0) & (jx < Ws) & (jy >= 0) & (jy < Hs) & (jz >= 0) & (jz < Ds)
    )
    flat = (jz * Hs + jy) * Ws + jx
    return np.where(valid, flat, -1)


def identity_map(grid: GridConfig, stride: int = 1):
    eye = np.eye(4).reshape(-1)
    return build_align_map(grid, eye, stride)
