//! Oriented 3D bounding boxes (7-DoF: center xyz, size lwh, yaw).
//!
//! The encoding matches the python target assigner
//! (`python/compile/targets.py`): length along the box's local +x at
//! yaw = 0, width along +y, height along +z, yaw about +z.

use super::pose::Mat3;
use super::vec::Vec3;

/// Oriented box. `size = (length, width, height)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Box3 {
    pub center: Vec3,
    pub size: Vec3,
    pub yaw: f64,
}

impl Box3 {
    pub fn new(center: Vec3, size: Vec3, yaw: f64) -> Box3 {
        Box3 { center, size, yaw }
    }

    pub fn from_xyzlwh_yaw(v: &[f32; 7]) -> Box3 {
        Box3 {
            center: Vec3::new(v[0] as f64, v[1] as f64, v[2] as f64),
            size: Vec3::new(v[3] as f64, v[4] as f64, v[5] as f64),
            yaw: v[6] as f64,
        }
    }

    pub fn to_array(&self) -> [f32; 7] {
        [
            self.center.x as f32,
            self.center.y as f32,
            self.center.z as f32,
            self.size.x as f32,
            self.size.y as f32,
            self.size.z as f32,
            self.yaw as f32,
        ]
    }

    /// BEV footprint corners, counter-clockwise.
    pub fn bev_corners(&self) -> [(f64, f64); 4] {
        let (s, c) = self.yaw.sin_cos();
        let hl = self.size.x / 2.0;
        let hw = self.size.y / 2.0;
        let local = [(hl, hw), (-hl, hw), (-hl, -hw), (hl, -hw)];
        let mut out = [(0.0, 0.0); 4];
        for (i, (lx, ly)) in local.iter().enumerate() {
            out[i] = (
                self.center.x + c * lx - s * ly,
                self.center.y + s * lx + c * ly,
            );
        }
        out
    }

    /// All eight corners in world coordinates.
    pub fn corners(&self) -> [Vec3; 8] {
        let rot = Mat3::rot_z(self.yaw);
        let h = self.size / 2.0;
        let mut out = [Vec3::ZERO; 8];
        let mut i = 0;
        for &sx in &[-1.0, 1.0] {
            for &sy in &[-1.0, 1.0] {
                for &sz in &[-1.0, 1.0] {
                    out[i] =
                        self.center + rot.apply(Vec3::new(sx * h.x, sy * h.y, sz * h.z));
                    i += 1;
                }
            }
        }
        out
    }

    pub fn z_min(&self) -> f64 {
        self.center.z - self.size.z / 2.0
    }

    pub fn z_max(&self) -> f64 {
        self.center.z + self.size.z / 2.0
    }

    pub fn bev_area(&self) -> f64 {
        self.size.x * self.size.y
    }

    pub fn volume(&self) -> f64 {
        self.size.x * self.size.y * self.size.z
    }

    /// Is a world point inside this box?
    pub fn contains(&self, p: Vec3) -> bool {
        let local = Mat3::rot_z(-self.yaw).apply(p - self.center);
        local.x.abs() <= self.size.x / 2.0
            && local.y.abs() <= self.size.y / 2.0
            && local.z.abs() <= self.size.z / 2.0
    }

    /// Transform the box by a pose (rigid; yaw-only rotation assumed, i.e.
    /// the pose's roll/pitch must be small — true for our sensor rigs).
    pub fn transformed(&self, rot_yaw: f64, rot: &Mat3, trans: Vec3) -> Box3 {
        Box3 {
            center: rot.apply(self.center) + trans,
            size: self.size,
            yaw: normalize_angle(self.yaw + rot_yaw),
        }
    }
}

/// Wrap an angle into (-π, π].
pub fn normalize_angle(a: f64) -> f64 {
    let mut a = a % (2.0 * std::f64::consts::PI);
    if a <= -std::f64::consts::PI {
        a += 2.0 * std::f64::consts::PI;
    } else if a > std::f64::consts::PI {
        a -= 2.0 * std::f64::consts::PI;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bev_corners_axis_aligned() {
        let b = Box3::new(Vec3::new(1.0, 2.0, 0.0), Vec3::new(4.0, 2.0, 1.5), 0.0);
        let cs = b.bev_corners();
        assert!((cs[0].0 - 3.0).abs() < 1e-12 && (cs[0].1 - 3.0).abs() < 1e-12);
        assert!((cs[2].0 - -1.0).abs() < 1e-12 && (cs[2].1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn contains_respects_yaw() {
        let b = Box3::new(Vec3::ZERO, Vec3::new(4.0, 2.0, 2.0), std::f64::consts::FRAC_PI_2);
        // after 90° yaw the long axis is along y
        assert!(b.contains(Vec3::new(0.0, 1.9, 0.0)));
        assert!(!b.contains(Vec3::new(1.9, 0.0, 0.0)));
    }

    #[test]
    fn corners_count_and_extent() {
        let b = Box3::new(Vec3::new(0.0, 0.0, 1.0), Vec3::new(2.0, 2.0, 2.0), 0.3);
        let cs = b.corners();
        for c in cs {
            assert!((c - b.center).norm() <= (3.0f64).sqrt() + 1e-9);
        }
    }

    #[test]
    fn normalize_angle_range() {
        for k in -10..10 {
            let a = 0.5 + k as f64 * 2.0 * std::f64::consts::PI;
            assert!((normalize_angle(a) - 0.5).abs() < 1e-9);
        }
        assert!(normalize_angle(std::f64::consts::PI + 0.1) < 0.0);
    }

    #[test]
    fn roundtrip_array() {
        let b = Box3::new(Vec3::new(1.0, 2.0, 3.0), Vec3::new(4.0, 5.0, 6.0), 0.7);
        let b2 = Box3::from_xyzlwh_yaw(&b.to_array());
        assert!((b.center - b2.center).norm() < 1e-6);
    }
}
