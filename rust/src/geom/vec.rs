//! 3-vector with the handful of operations the stack needs.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// Double-precision 3-vector (simulation and calibration run in f64;
/// tensors handed to the model are converted to f32 at the boundary).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(x: f64, y: f64, z: f64) -> Self {
        Vec3 { x, y, z }
    }

    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    pub fn norm(self) -> f64 {
        self.dot(self).sqrt()
    }

    pub fn norm_sq(self) -> f64 {
        self.dot(self)
    }

    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n == 0.0 {
            Vec3::ZERO
        } else {
            self / n
        }
    }

    /// Horizontal (xy-plane) distance.
    pub fn norm_xy(self) -> f64 {
        (self.x * self.x + self.y * self.y).sqrt()
    }

    pub fn to_f32_array(self) -> [f32; 3] {
        [self.x as f32, self.y as f32, self.z as f32]
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f64> for Vec3 {
    type Output = Vec3;
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_cross_identities() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-2.0, 0.5, 4.0);
        // cross product orthogonal to both inputs
        let c = a.cross(b);
        assert!(c.dot(a).abs() < 1e-12);
        assert!(c.dot(b).abs() < 1e-12);
        // lagrange identity
        let lhs = c.norm_sq();
        let rhs = a.norm_sq() * b.norm_sq() - a.dot(b) * a.dot(b);
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn normalize() {
        let v = Vec3::new(3.0, 4.0, 0.0).normalized();
        assert!((v.norm() - 1.0).abs() < 1e-12);
        assert_eq!(Vec3::ZERO.normalized(), Vec3::ZERO);
    }
}
