//! Geometry substrate: vectors, SE(3) poses, oriented 3D boxes, rotated
//! IoU (polygon clipping) and ray intersections for the LiDAR simulator.

pub mod box3;
pub mod iou;
pub mod pose;
pub mod ray;
pub mod vec;

pub use box3::Box3;
pub use iou::{bev_iou, iou_3d, polygon_area, polygon_clip};
pub use pose::{Mat3, Pose};
pub use vec::Vec3;
