//! Ray intersections used by the LiDAR raycaster: ray vs oriented box
//! (slab test in the box frame) and ray vs ground plane.

use super::box3::Box3;
use super::pose::Mat3;
use super::vec::Vec3;

/// A ray `origin + t * dir`, `dir` unit length.
#[derive(Clone, Copy, Debug)]
pub struct Ray {
    pub origin: Vec3,
    pub dir: Vec3,
}

impl Ray {
    pub fn new(origin: Vec3, dir: Vec3) -> Ray {
        Ray { origin, dir: dir.normalized() }
    }

    pub fn at(&self, t: f64) -> Vec3 {
        self.origin + self.dir * t
    }
}

/// Distance along `ray` to the first intersection with `b`, if any
/// (t must be positive — hits behind the origin are ignored).
pub fn ray_box(ray: &Ray, b: &Box3) -> Option<f64> {
    // Transform the ray into the box's local frame.
    let inv_rot = Mat3::rot_z(-b.yaw);
    let o = inv_rot.apply(ray.origin - b.center);
    let d = inv_rot.apply(ray.dir);
    let half = b.size / 2.0;

    let mut t_min = f64::NEG_INFINITY;
    let mut t_max = f64::INFINITY;
    for (oc, dc, hc) in [(o.x, d.x, half.x), (o.y, d.y, half.y), (o.z, d.z, half.z)] {
        if dc.abs() < 1e-12 {
            if oc.abs() > hc {
                return None;
            }
        } else {
            let inv = 1.0 / dc;
            let (mut t0, mut t1) = ((-hc - oc) * inv, (hc - oc) * inv);
            if t0 > t1 {
                std::mem::swap(&mut t0, &mut t1);
            }
            t_min = t_min.max(t0);
            t_max = t_max.min(t1);
            if t_min > t_max {
                return None;
            }
        }
    }
    if t_max < 0.0 {
        return None;
    }
    Some(if t_min >= 0.0 { t_min } else { t_max })
}

/// Distance along `ray` to the plane `z = z0` (None if parallel or behind).
pub fn ray_ground(ray: &Ray, z0: f64) -> Option<f64> {
    if ray.dir.z.abs() < 1e-12 {
        return None;
    }
    let t = (z0 - ray.origin.z) / ray.dir.z;
    if t > 0.0 {
        Some(t)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ray_hits_axis_aligned_box() {
        let b = Box3::new(Vec3::new(10.0, 0.0, 0.0), Vec3::new(2.0, 2.0, 2.0), 0.0);
        let r = Ray::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0));
        let t = ray_box(&r, &b).unwrap();
        assert!((t - 9.0).abs() < 1e-9);
    }

    #[test]
    fn ray_misses_offset_box() {
        let b = Box3::new(Vec3::new(10.0, 5.0, 0.0), Vec3::new(2.0, 2.0, 2.0), 0.0);
        let r = Ray::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0));
        assert!(ray_box(&r, &b).is_none());
    }

    #[test]
    fn ray_hits_rotated_box() {
        // 45°-rotated long box: the ray along x should clip its corner region
        let b = Box3::new(
            Vec3::new(10.0, 0.0, 0.0),
            Vec3::new(6.0, 1.0, 2.0),
            std::f64::consts::FRAC_PI_4,
        );
        let r = Ray::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0));
        let t = ray_box(&r, &b).unwrap();
        let hit = r.at(t);
        assert!(b.contains(hit + r.dir * 1e-9) || b.contains(hit - r.dir * 1e-9));
    }

    #[test]
    fn origin_inside_box_returns_exit() {
        let b = Box3::new(Vec3::ZERO, Vec3::new(4.0, 4.0, 4.0), 0.3);
        let r = Ray::new(Vec3::ZERO, Vec3::new(0.0, 1.0, 0.0));
        let t = ray_box(&r, &b).unwrap();
        assert!(t > 0.0);
    }

    #[test]
    fn ground_intersection() {
        // sensor looking down from 4.5 m
        let r = Ray::new(Vec3::new(0.0, 0.0, 4.5), Vec3::new(1.0, 0.0, -0.5));
        let t = ray_ground(&r, 0.0).unwrap();
        let p = r.at(t);
        assert!(p.z.abs() < 1e-9);
        // upward ray never hits ground
        let r_up = Ray::new(Vec3::new(0.0, 0.0, 4.5), Vec3::new(1.0, 0.0, 0.5));
        assert!(ray_ground(&r_up, 0.0).is_none());
    }

    #[test]
    fn behind_origin_ignored() {
        let b = Box3::new(Vec3::new(-10.0, 0.0, 0.0), Vec3::new(2.0, 2.0, 2.0), 0.0);
        let r = Ray::new(Vec3::ZERO, Vec3::new(1.0, 0.0, 0.0));
        assert!(ray_box(&r, &b).is_none());
    }
}
