//! Rotated-rectangle IoU via Sutherland–Hodgman polygon clipping, plus
//! 3D IoU (BEV intersection × vertical overlap).
//!
//! This is the matching metric behind both the target assigner (python
//! mirrors it) and the AP evaluation reproducing Table III. AP@0.3 /
//! AP@0.5 in the paper are BEV-IoU thresholds, matching V2X-Real's
//! evaluation protocol.

use super::box3::Box3;

/// Area of a simple polygon (shoelace). Positive for CCW winding.
pub fn polygon_area(poly: &[(f64, f64)]) -> f64 {
    if poly.len() < 3 {
        return 0.0;
    }
    let mut acc = 0.0;
    for i in 0..poly.len() {
        let (x0, y0) = poly[i];
        let (x1, y1) = poly[(i + 1) % poly.len()];
        acc += x0 * y1 - x1 * y0;
    }
    acc / 2.0
}

/// Clip polygon `subject` against convex polygon `clip` (both CCW).
pub fn polygon_clip(subject: &[(f64, f64)], clip: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut output: Vec<(f64, f64)> = subject.to_vec();
    for i in 0..clip.len() {
        if output.is_empty() {
            return output;
        }
        let a = clip[i];
        let b = clip[(i + 1) % clip.len()];
        let input = std::mem::take(&mut output);
        // inside = left of directed edge a->b
        let inside = |p: (f64, f64)| (b.0 - a.0) * (p.1 - a.1) - (b.1 - a.1) * (p.0 - a.0) >= 0.0;
        let intersect = |p: (f64, f64), q: (f64, f64)| {
            let a1 = b.1 - a.1;
            let b1 = a.0 - b.0;
            let c1 = a1 * a.0 + b1 * a.1;
            let a2 = q.1 - p.1;
            let b2 = p.0 - q.0;
            let c2 = a2 * p.0 + b2 * p.1;
            let det = a1 * b2 - a2 * b1;
            if det.abs() < 1e-18 {
                p // parallel; degenerate, return an endpoint
            } else {
                ((b2 * c1 - b1 * c2) / det, (a1 * c2 - a2 * c1) / det)
            }
        };
        for j in 0..input.len() {
            let cur = input[j];
            let prev = input[(j + input.len() - 1) % input.len()];
            let cur_in = inside(cur);
            let prev_in = inside(prev);
            if cur_in {
                if !prev_in {
                    output.push(intersect(prev, cur));
                }
                output.push(cur);
            } else if prev_in {
                output.push(intersect(prev, cur));
            }
        }
    }
    output
}

/// Intersection area of two rotated rectangles given as corner lists.
pub fn rect_intersection_area(a: &[(f64, f64); 4], b: &[(f64, f64); 4]) -> f64 {
    let inter = polygon_clip(a, b);
    polygon_area(&inter).abs()
}

/// Bird's-eye-view IoU of two oriented boxes.
pub fn bev_iou(a: &Box3, b: &Box3) -> f64 {
    // Cheap reject: circumscribed circles don't touch.
    let ra = (a.size.x * a.size.x + a.size.y * a.size.y).sqrt() / 2.0;
    let rb = (b.size.x * b.size.x + b.size.y * b.size.y).sqrt() / 2.0;
    let d = (a.center - b.center).norm_xy();
    if d > ra + rb {
        return 0.0;
    }
    let inter = rect_intersection_area(&a.bev_corners(), &b.bev_corners());
    let union = a.bev_area() + b.bev_area() - inter;
    if union <= 0.0 {
        0.0
    } else {
        (inter / union).clamp(0.0, 1.0)
    }
}

/// Full 3D IoU: BEV intersection × z-overlap over union of volumes.
pub fn iou_3d(a: &Box3, b: &Box3) -> f64 {
    let inter_bev = rect_intersection_area(&a.bev_corners(), &b.bev_corners());
    if inter_bev <= 0.0 {
        return 0.0;
    }
    let z_overlap = (a.z_max().min(b.z_max()) - a.z_min().max(b.z_min())).max(0.0);
    let inter = inter_bev * z_overlap;
    let union = a.volume() + b.volume() - inter;
    if union <= 0.0 {
        0.0
    } else {
        (inter / union).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Vec3;

    fn boxb(x: f64, y: f64, l: f64, w: f64, yaw: f64) -> Box3 {
        Box3::new(Vec3::new(x, y, 0.0), Vec3::new(l, w, 2.0), yaw)
    }

    #[test]
    fn identical_boxes_iou_one() {
        let a = boxb(1.0, 2.0, 4.0, 2.0, 0.3);
        assert!((bev_iou(&a, &a) - 1.0).abs() < 1e-9);
        assert!((iou_3d(&a, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_boxes_iou_zero() {
        let a = boxb(0.0, 0.0, 4.0, 2.0, 0.0);
        let b = boxb(100.0, 0.0, 4.0, 2.0, 0.0);
        assert_eq!(bev_iou(&a, &b), 0.0);
        assert_eq!(iou_3d(&a, &b), 0.0);
    }

    #[test]
    fn axis_aligned_half_overlap() {
        // two 2x2 squares overlapping in a 1x2 strip: inter=2, union=6
        let a = boxb(0.0, 0.0, 2.0, 2.0, 0.0);
        let b = boxb(1.0, 0.0, 2.0, 2.0, 0.0);
        assert!((bev_iou(&a, &b) - 2.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn rotation_invariance() {
        // IoU invariant under rotating both boxes by the same angle
        let a0 = boxb(0.0, 0.0, 4.0, 2.0, 0.0);
        let b0 = boxb(1.0, 0.5, 3.0, 2.0, 0.4);
        let base = bev_iou(&a0, &b0);
        for k in 1..8 {
            let t = k as f64 * 0.5;
            let (s, c) = t.sin_cos();
            let rot = |bx: &Box3| {
                Box3::new(
                    Vec3::new(
                        c * bx.center.x - s * bx.center.y,
                        s * bx.center.x + c * bx.center.y,
                        0.0,
                    ),
                    bx.size,
                    bx.yaw + t,
                )
            };
            let iou = bev_iou(&rot(&a0), &rot(&b0));
            assert!((iou - base).abs() < 1e-9, "angle {t}: {iou} vs {base}");
        }
    }

    #[test]
    fn crossed_rectangles() {
        // two 4x2 rectangles crossed at 90°: intersection is 2x2 square
        let a = boxb(0.0, 0.0, 4.0, 2.0, 0.0);
        let b = boxb(0.0, 0.0, 4.0, 2.0, std::f64::consts::FRAC_PI_2);
        let inter = rect_intersection_area(&a.bev_corners(), &b.bev_corners());
        assert!((inter - 4.0).abs() < 1e-9);
        assert!((bev_iou(&a, &b) - 4.0 / 12.0).abs() < 1e-9);
    }

    #[test]
    fn z_offset_kills_3d_iou_only() {
        let a = Box3::new(Vec3::new(0.0, 0.0, 0.0), Vec3::new(2.0, 2.0, 2.0), 0.0);
        let b = Box3::new(Vec3::new(0.0, 0.0, 5.0), Vec3::new(2.0, 2.0, 2.0), 0.0);
        assert!((bev_iou(&a, &b) - 1.0).abs() < 1e-9);
        assert_eq!(iou_3d(&a, &b), 0.0);
    }

    #[test]
    fn contained_box() {
        let outer = boxb(0.0, 0.0, 4.0, 4.0, 0.2);
        let inner = boxb(0.0, 0.0, 2.0, 2.0, 0.2);
        assert!((bev_iou(&outer, &inner) - 4.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn shoelace_signs() {
        let ccw = [(0.0, 0.0), (1.0, 0.0), (1.0, 1.0), (0.0, 1.0)];
        let cw: Vec<_> = ccw.iter().rev().cloned().collect();
        assert!((polygon_area(&ccw) - 1.0).abs() < 1e-12);
        assert!((polygon_area(&cw) + 1.0).abs() < 1e-12);
    }
}
