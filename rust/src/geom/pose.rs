//! SE(3) rigid transforms and 3×3 rotation matrices.
//!
//! Poses describe sensor extrinsics: `pose.apply(p)` maps a point from the
//! sensor's local frame into the world/common frame. NDT scan matching
//! (`crate::ndt`) estimates these; the alignment index maps
//! (`crate::align`) consume them.

use super::vec::Vec3;

/// Row-major 3×3 matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat3 {
    pub m: [[f64; 3]; 3],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 { m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]] };

    pub fn from_rows(r0: [f64; 3], r1: [f64; 3], r2: [f64; 3]) -> Mat3 {
        Mat3 { m: [r0, r1, r2] }
    }

    /// Rotation about +z by `yaw` radians (counter-clockwise looking down).
    pub fn rot_z(yaw: f64) -> Mat3 {
        let (s, c) = yaw.sin_cos();
        Mat3::from_rows([c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0])
    }

    /// Rotation about +y by `pitch` radians.
    pub fn rot_y(pitch: f64) -> Mat3 {
        let (s, c) = pitch.sin_cos();
        Mat3::from_rows([c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c])
    }

    /// Rotation about +x by `roll` radians.
    pub fn rot_x(roll: f64) -> Mat3 {
        let (s, c) = roll.sin_cos();
        Mat3::from_rows([1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c])
    }

    /// ZYX Euler composition: `rot_z(yaw) * rot_y(pitch) * rot_x(roll)`.
    pub fn from_euler(roll: f64, pitch: f64, yaw: f64) -> Mat3 {
        Mat3::rot_z(yaw) * Mat3::rot_y(pitch) * Mat3::rot_x(roll)
    }

    /// Extract (roll, pitch, yaw) assuming ZYX composition.
    pub fn to_euler(&self) -> (f64, f64, f64) {
        let m = &self.m;
        let pitch = (-m[2][0]).asin();
        let roll = m[2][1].atan2(m[2][2]);
        let yaw = m[1][0].atan2(m[0][0]);
        (roll, pitch, yaw)
    }

    pub fn transpose(&self) -> Mat3 {
        let m = &self.m;
        Mat3::from_rows(
            [m[0][0], m[1][0], m[2][0]],
            [m[0][1], m[1][1], m[2][1]],
            [m[0][2], m[1][2], m[2][2]],
        )
    }

    pub fn apply(&self, v: Vec3) -> Vec3 {
        let m = &self.m;
        Vec3::new(
            m[0][0] * v.x + m[0][1] * v.y + m[0][2] * v.z,
            m[1][0] * v.x + m[1][1] * v.y + m[1][2] * v.z,
            m[2][0] * v.x + m[2][1] * v.y + m[2][2] * v.z,
        )
    }

    pub fn det(&self) -> f64 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    /// Inverse of a general 3×3 (adjugate / det). Panics on singular.
    pub fn inverse(&self) -> Mat3 {
        let m = &self.m;
        let det = self.det();
        assert!(det.abs() > 1e-18, "singular matrix");
        let inv_det = 1.0 / det;
        Mat3::from_rows(
            [
                (m[1][1] * m[2][2] - m[1][2] * m[2][1]) * inv_det,
                (m[0][2] * m[2][1] - m[0][1] * m[2][2]) * inv_det,
                (m[0][1] * m[1][2] - m[0][2] * m[1][1]) * inv_det,
            ],
            [
                (m[1][2] * m[2][0] - m[1][0] * m[2][2]) * inv_det,
                (m[0][0] * m[2][2] - m[0][2] * m[2][0]) * inv_det,
                (m[0][2] * m[1][0] - m[0][0] * m[1][2]) * inv_det,
            ],
            [
                (m[1][0] * m[2][1] - m[1][1] * m[2][0]) * inv_det,
                (m[0][1] * m[2][0] - m[0][0] * m[2][1]) * inv_det,
                (m[0][0] * m[1][1] - m[0][1] * m[1][0]) * inv_det,
            ],
        )
    }

    /// Solve `self * x = b` (via inverse; 3×3 only ever).
    pub fn solve(&self, b: Vec3) -> Vec3 {
        self.inverse().apply(b)
    }
}

impl std::ops::Mul for Mat3 {
    type Output = Mat3;
    fn mul(self, o: Mat3) -> Mat3 {
        let mut out = [[0.0; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                out[i][j] = (0..3).map(|k| self.m[i][k] * o.m[k][j]).sum();
            }
        }
        Mat3 { m: out }
    }
}

/// Rigid transform: `world = rot * local + trans`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pose {
    pub rot: Mat3,
    pub trans: Vec3,
}

impl Pose {
    pub const IDENTITY: Pose = Pose { rot: Mat3::IDENTITY, trans: Vec3::ZERO };

    pub fn new(rot: Mat3, trans: Vec3) -> Pose {
        Pose { rot, trans }
    }

    /// Pose from xyz translation + ZYX euler angles.
    pub fn from_xyz_rpy(x: f64, y: f64, z: f64, roll: f64, pitch: f64, yaw: f64) -> Pose {
        Pose::new(Mat3::from_euler(roll, pitch, yaw), Vec3::new(x, y, z))
    }

    pub fn apply(&self, p: Vec3) -> Vec3 {
        self.rot.apply(p) + self.trans
    }

    /// Rotate a direction (no translation).
    pub fn apply_dir(&self, d: Vec3) -> Vec3 {
        self.rot.apply(d)
    }

    pub fn inverse(&self) -> Pose {
        let rt = self.rot.transpose();
        Pose::new(rt, -rt.apply(self.trans))
    }

    /// `self ∘ other`: apply `other` first, then `self`.
    pub fn compose(&self, other: &Pose) -> Pose {
        Pose::new(self.rot * other.rot, self.rot.apply(other.trans) + self.trans)
    }

    /// Row-major 4×4 homogeneous matrix (for calib.json interchange).
    pub fn to_mat4(&self) -> [f64; 16] {
        let m = &self.rot.m;
        [
            m[0][0], m[0][1], m[0][2], self.trans.x, //
            m[1][0], m[1][1], m[1][2], self.trans.y, //
            m[2][0], m[2][1], m[2][2], self.trans.z, //
            0.0, 0.0, 0.0, 1.0,
        ]
    }

    pub fn from_mat4(m: &[f64; 16]) -> Pose {
        Pose::new(
            Mat3::from_rows([m[0], m[1], m[2]], [m[4], m[5], m[6]], [m[8], m[9], m[10]]),
            Vec3::new(m[3], m[7], m[11]),
        )
    }

    /// Rotation/translation distance to another pose, for calibration
    /// error reporting: (rotation angle in radians, translation metres).
    pub fn error_to(&self, other: &Pose) -> (f64, f64) {
        let rel = self.inverse().compose(other);
        let trace = rel.rot.m[0][0] + rel.rot.m[1][1] + rel.rot.m[2][2];
        let angle = ((trace - 1.0) / 2.0).clamp(-1.0, 1.0).acos();
        (angle, rel.trans.norm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rot_z_quarter_turn() {
        let r = Mat3::rot_z(std::f64::consts::FRAC_PI_2);
        let v = r.apply(Vec3::new(1.0, 0.0, 0.0));
        assert!((v - Vec3::new(0.0, 1.0, 0.0)).norm() < 1e-12);
    }

    #[test]
    fn euler_roundtrip() {
        let (roll, pitch, yaw) = (0.1, -0.2, 1.3);
        let r = Mat3::from_euler(roll, pitch, yaw);
        let (r2, p2, y2) = r.to_euler();
        assert!((roll - r2).abs() < 1e-12);
        assert!((pitch - p2).abs() < 1e-12);
        assert!((yaw - y2).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrips_points() {
        let pose = Pose::from_xyz_rpy(1.0, -2.0, 3.0, 0.05, -0.1, 2.2);
        let inv = pose.inverse();
        let p = Vec3::new(4.0, 5.0, -6.0);
        assert!((inv.apply(pose.apply(p)) - p).norm() < 1e-12);
        assert!((pose.apply(inv.apply(p)) - p).norm() < 1e-12);
    }

    #[test]
    fn compose_associates_with_apply() {
        let a = Pose::from_xyz_rpy(1.0, 0.0, 0.0, 0.0, 0.0, 0.7);
        let b = Pose::from_xyz_rpy(0.0, 2.0, 0.5, 0.1, 0.0, -0.3);
        let p = Vec3::new(0.3, -0.4, 0.5);
        let lhs = a.compose(&b).apply(p);
        let rhs = a.apply(b.apply(p));
        assert!((lhs - rhs).norm() < 1e-12);
    }

    #[test]
    fn mat4_roundtrip() {
        let pose = Pose::from_xyz_rpy(10.0, -5.0, 4.5, 0.0, 0.02, 1.9);
        let back = Pose::from_mat4(&pose.to_mat4());
        let (ang, t) = pose.error_to(&back);
        assert!(ang < 1e-12 && t < 1e-12);
    }

    #[test]
    fn error_to_measures_rotation() {
        let a = Pose::IDENTITY;
        let b = Pose::from_xyz_rpy(0.0, 0.0, 0.0, 0.0, 0.0, 0.25);
        let (ang, t) = a.error_to(&b);
        assert!((ang - 0.25).abs() < 1e-12);
        assert!(t < 1e-12);
    }

    #[test]
    fn mat3_inverse_solves() {
        let m = Mat3::from_rows([2.0, 1.0, 0.0], [0.0, 3.0, 1.0], [1.0, 0.0, 2.0]);
        let x = Vec3::new(1.0, -2.0, 0.5);
        let b = m.apply(x);
        assert!((m.solve(b) - x).norm() < 1e-10);
    }
}
