//! Synthetic intersection + infrastructure-LiDAR simulator.
//!
//! Stands in for the V2X-Real dataset (DESIGN.md §4): two fixed LiDARs —
//! an emulated Ouster OS1-64 and OS1-128 — observe a four-way
//! intersection with moving cars and pedestrians, static corner buildings
//! and ground. Each sensor reports points in its **own local frame**; the
//! rigid transform between frames is exactly what the setup phase (NDT)
//! must recover.
//!
//! The properties the paper's evaluation depends on are reproduced:
//! overlapping fields of view with disjoint occlusion shadows, roughly 2×
//! the point count on device 2, and a common frame fixed to sensor 1.

pub mod dataset;
pub mod lidar;
pub mod scene;

pub use dataset::{generate_dataset, SimConfig};
pub use lidar::{LidarModel, LidarSpec};
pub use scene::{ObjClass, Scene, SceneObject};
