//! Dataset generation: the V2X-Real substitute.
//!
//! Simulates the paper's two-sensor intersection rig over time and writes
//! npy files the python training path (`python/compile/data.py`) and the
//! rust serving/eval paths both consume:
//!
//! ```text
//! data/{train,val}/points_dev{0,1}.npy   (N, max_points, 4) f32, local frame
//! data/{train,val}/labels.npy            (N, MAX_OBJ, 8)    f32, common frame
//! data/calib/calib_dev{0,1}.npy          (M, 4)             f32, static scene
//! data/meta.json                          rig + split metadata
//! ```
//!
//! Labels are `[x, y, z, l, w, h, yaw, class_id]` in the **common frame**
//! (device 0's local frame), padded with `class_id = -1`.

use super::lidar::{LidarModel, LidarSpec};
use super::scene::Scene;
use crate::config::GridConfig;
use crate::geom::{Mat3, Pose, Vec3};
use crate::utils::json::Json;
use crate::utils::npy::{self, NpyArray};
use crate::utils::rng::Pcg64;
use crate::utils::threadpool::ThreadPool;
use crate::voxel::Point;
use anyhow::Result;
use std::path::Path;

/// Max ground-truth objects per frame in the label tensor.
pub const MAX_OBJECTS: usize = 24;

/// Dataset generation parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub seed: u64,
    pub train_frames: usize,
    pub val_frames: usize,
    /// Sensor frame period (paper: 10 Hz).
    pub dt: f64,
    pub n_cars: usize,
    pub n_peds: usize,
    /// Points kept per scan (subsampled, fixed-size model input).
    pub max_points: usize,
    /// Points per calibration scan (setup phase; denser is better for NDT).
    pub calib_points: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            seed: 20260710,
            train_frames: 400,
            val_frames: 80,
            dt: 0.1,
            n_cars: 8,
            n_peds: 5,
            max_points: 4096,
            calib_points: 16384,
        }
    }
}

/// The fixed two-sensor rig (world-frame mounting poses).
///
/// Poles stand at opposite corners of the intersection, between the road
/// edge (±5 m) and the set-back corner buildings (≥9 m).
/// Device 0: OS1-64 on the south-west pole, axis-aligned mount.
/// Device 1: OS1-128 on the north-east pole, yawed 3.3 rad — alignment
/// must handle a large rotation, as in a real install.
pub fn sensor_rig() -> Vec<LidarModel> {
    vec![
        LidarModel::new(
            LidarSpec::os1_64(),
            Pose::new(Mat3::rot_z(0.0), Vec3::new(-7.5, -7.5, 4.5)),
        ),
        LidarModel::new(
            LidarSpec::os1_128(),
            Pose::new(Mat3::rot_z(3.3), Vec3::new(7.5, 7.5, 5.2)),
        ),
    ]
}

/// Ground-truth transform mapping device `i`'s local frame to the common
/// frame (device 0's local frame): `T = pose0⁻¹ ∘ posei`.
pub fn true_device_transform(rig: &[LidarModel], device: usize) -> Pose {
    rig[0].pose.inverse().compose(&rig[device].pose)
}

/// One generated frame (in-memory form, also used by serving demos).
#[derive(Clone, Debug)]
pub struct Frame {
    /// Per-device clouds in each device's local frame (subsampled).
    pub clouds: Vec<Vec<Point>>,
    /// GT boxes in the common frame: `[x,y,z,l,w,h,yaw,class_id]`.
    pub labels: Vec<[f32; 8]>,
}

/// Simulate `n` frames starting from a seeded scene. Raycasting fans out
/// over a thread pool (frames are independent given pre-stepped scenes).
pub fn simulate_frames(cfg: &SimConfig, split_tag: u64, n: usize, grid: &GridConfig) -> Vec<Frame> {
    let rig = sensor_rig();
    let mut scene = Scene::new(cfg.seed ^ split_tag, cfg.n_cars, cfg.n_peds);
    // Collect per-frame scene snapshots first (stepping is sequential).
    let mut snapshots = Vec::with_capacity(n);
    for _ in 0..n {
        scene.step(cfg.dt);
        snapshots.push(scene.clone());
    }
    let pool = ThreadPool::default_size();
    let cfg = cfg.clone();
    let grid = grid.clone();
    let base_seed = cfg.seed ^ split_tag;
    let snapshots = std::sync::Arc::new(snapshots);
    let snaps = std::sync::Arc::clone(&snapshots);
    pool.map(n, move |i| {
        render_frame(&snaps[i], &rig, &cfg, &grid, base_seed.wrapping_add(i as u64 * 7919))
    })
}

fn render_frame(
    scene: &Scene,
    rig: &[LidarModel],
    cfg: &SimConfig,
    grid: &GridConfig,
    seed: u64,
) -> Frame {
    let mut rng = Pcg64::new(seed);
    let mut clouds = Vec::with_capacity(rig.len());
    for lidar in rig {
        let mut scan_rng = rng.fork(lidar.spec.beams as u64);
        let pts = lidar.scan(scene, &mut scan_rng);
        clouds.push(subsample_in_grid(pts, grid, cfg.max_points, &mut rng));
    }
    let labels = extract_labels(scene, &rig[0].pose, grid);
    Frame { clouds, labels }
}

/// Keep up to `max_points`, preferring points inside the detection grid
/// (in the *local* frame — each device voxelizes locally; grid bounds are
/// identical across devices per the paper's common-grid assumption).
fn subsample_in_grid(
    pts: Vec<Point>,
    grid: &GridConfig,
    max_points: usize,
    rng: &mut Pcg64,
) -> Vec<Point> {
    let (mut inside, mut outside): (Vec<Point>, Vec<Point>) = (Vec::new(), Vec::new());
    for p in pts {
        if grid.voxel_of(p.x as f64, p.y as f64, p.z as f64).is_some() {
            inside.push(p);
        } else {
            outside.push(p);
        }
    }
    rng.shuffle(&mut inside);
    if inside.len() >= max_points {
        inside.truncate(max_points);
        return inside;
    }
    rng.shuffle(&mut outside);
    let need = max_points - inside.len();
    inside.extend(outside.into_iter().take(need));
    inside
}

/// GT boxes transformed into the common frame, filtered to the grid range.
fn extract_labels(scene: &Scene, pose0: &Pose, grid: &GridConfig) -> Vec<[f32; 8]> {
    let inv = pose0.inverse();
    let (_, _, inv_yaw) = inv.rot.to_euler();
    let mut out = Vec::new();
    for obj in &scene.objects {
        let b = obj.bbox.transformed(inv_yaw, &inv.rot, inv.trans);
        let c = b.center;
        // Keep objects whose center lies in the BEV range (z check relaxed
        // by a margin — boxes straddle voxel layers).
        if c.x < grid.range_min[0]
            || c.x > grid.range_max[0]
            || c.y < grid.range_min[1]
            || c.y > grid.range_max[1]
        {
            continue;
        }
        if out.len() >= MAX_OBJECTS {
            break;
        }
        let arr = b.to_array();
        out.push([
            arr[0],
            arr[1],
            arr[2],
            arr[3],
            arr[4],
            arr[5],
            arr[6],
            obj.class.id() as f32,
        ]);
    }
    out
}

/// Dense calibration scans of the static scene (setup phase, Fig 4).
pub fn calibration_scans(cfg: &SimConfig) -> Vec<Vec<Point>> {
    let rig = sensor_rig();
    let scene = Scene::new(cfg.seed ^ 0xCA11B, 0, 0); // static structure only
    let scene = scene.static_only();
    let mut out = Vec::new();
    for (i, lidar) in rig.iter().enumerate() {
        // Dense scan: crank azimuth steps for calibration quality.
        let mut dense = lidar.clone();
        dense.spec.azimuth_steps = 1024;
        let mut rng = Pcg64::new(cfg.seed ^ (0xCA11B + i as u64));
        let mut pts = dense.scan(&scene, &mut rng);
        let mut sub_rng = rng.fork(99);
        sub_rng.shuffle(&mut pts);
        pts.truncate(cfg.calib_points);
        out.push(pts);
    }
    out
}

/// Write a split (train/val) to `dir`.
fn write_split(dir: &Path, frames: &[Frame], max_points: usize) -> Result<()> {
    let n = frames.len();
    let n_dev = frames.first().map(|f| f.clouds.len()).unwrap_or(2);
    for dev in 0..n_dev {
        let mut data = Vec::with_capacity(n * max_points * 4);
        for f in frames {
            data.extend_from_slice(&crate::voxel::points_to_tensor(&f.clouds[dev], max_points));
        }
        npy::write(
            &dir.join(format!("points_dev{dev}.npy")),
            &NpyArray::from_f32(&[n, max_points, 4], &data),
        )?;
    }
    let mut labels = vec![0.0f32; n * MAX_OBJECTS * 8];
    for (i, f) in frames.iter().enumerate() {
        for slot in 0..MAX_OBJECTS {
            let base = (i * MAX_OBJECTS + slot) * 8;
            if let Some(l) = f.labels.get(slot) {
                labels[base..base + 8].copy_from_slice(l);
            } else {
                labels[base + 7] = -1.0; // pad marker
            }
        }
    }
    npy::write(&dir.join("labels.npy"), &NpyArray::from_f32(&[n, MAX_OBJECTS, 8], &labels))?;
    Ok(())
}

/// Generate the full dataset (train + val + calibration) under `out_dir`.
pub fn generate_dataset(cfg: &SimConfig, grid: &GridConfig, out_dir: &Path) -> Result<()> {
    log::info!(
        "datagen: {} train + {} val frames, seed {}",
        cfg.train_frames,
        cfg.val_frames,
        cfg.seed
    );
    let train = simulate_frames(cfg, 0x7EA1, cfg.train_frames, grid);
    write_split(&out_dir.join("train"), &train, cfg.max_points)?;
    let val = simulate_frames(cfg, 0x0E7A, cfg.val_frames, grid);
    write_split(&out_dir.join("val"), &val, cfg.max_points)?;

    let calib = calibration_scans(cfg);
    for (i, pts) in calib.iter().enumerate() {
        let flat: Vec<f32> = pts.iter().flat_map(|p| [p.x, p.y, p.z, p.intensity]).collect();
        npy::write(
            &out_dir.join("calib").join(format!("calib_dev{i}.npy")),
            &NpyArray::from_f32(&[pts.len(), 4], &flat),
        )?;
    }

    // Rig + dataset metadata (true poses recorded for NDT validation only;
    // the pipeline uses the NDT estimate, as in the paper).
    let rig = sensor_rig();
    let mut meta = Json::obj();
    meta.set("seed", Json::Num(cfg.seed as f64))
        .set("train_frames", Json::Num(cfg.train_frames as f64))
        .set("val_frames", Json::Num(cfg.val_frames as f64))
        .set("max_points", Json::Num(cfg.max_points as f64))
        .set("max_objects", Json::Num(MAX_OBJECTS as f64))
        .set("dt", Json::Num(cfg.dt))
        .set("grid", grid.to_json())
        .set(
            "sensors",
            Json::Arr(
                rig.iter()
                    .map(|l| {
                        let mut s = Json::obj();
                        s.set("model", Json::Str(l.spec.name.into()))
                            .set("beams", Json::Num(l.spec.beams as f64))
                            .set(
                                "true_pose_world",
                                Json::from_f64_slice(&l.pose.to_mat4()),
                            );
                        s
                    })
                    .collect(),
            ),
        );
    crate::utils::json::write_file(&out_dir.join("meta.json"), &meta)?;
    log::info!("datagen: wrote {}", out_dir.display());
    Ok(())
}

/// Load a split back (serving + eval paths).
pub fn load_split(dir: &Path) -> Result<Vec<Frame>> {
    let mut clouds_per_dev = Vec::new();
    let mut dev = 0;
    loop {
        let p = dir.join(format!("points_dev{dev}.npy"));
        if !p.exists() {
            break;
        }
        let arr = npy::read(&p)?;
        anyhow::ensure!(arr.shape.len() == 3 && arr.shape[2] == 4, "bad points shape");
        clouds_per_dev.push((arr.shape[0], arr.shape[1], arr.as_f32()?));
        dev += 1;
    }
    anyhow::ensure!(!clouds_per_dev.is_empty(), "no points_dev*.npy in {}", dir.display());
    let labels_arr = npy::read(&dir.join("labels.npy"))?;
    let labels = labels_arr.as_f32()?;
    let n = clouds_per_dev[0].0;
    let max_obj = labels_arr.shape[1];

    let mut frames = Vec::with_capacity(n);
    for i in 0..n {
        let mut clouds = Vec::with_capacity(clouds_per_dev.len());
        for (_, mp, data) in &clouds_per_dev {
            let start = i * mp * 4;
            clouds.push(crate::voxel::tensor_to_points(&data[start..start + mp * 4]));
        }
        let mut frame_labels = Vec::new();
        for slot in 0..max_obj {
            let base = (i * max_obj + slot) * 8;
            let row: [f32; 8] = labels[base..base + 8].try_into().unwrap();
            if row[7] >= 0.0 {
                frame_labels.push(row);
            }
        }
        frames.push(Frame { clouds, labels: frame_labels });
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SimConfig {
        SimConfig {
            seed: 7,
            train_frames: 2,
            val_frames: 1,
            dt: 0.1,
            n_cars: 5,
            n_peds: 3,
            max_points: 512,
            calib_points: 2048,
        }
    }

    #[test]
    fn device2_sees_roughly_twice_the_points() {
        let cfg = tiny_cfg();
        let grid = GridConfig::default();
        let rig = sensor_rig();
        let scene = {
            let mut s = Scene::new(1, 6, 3);
            s.step(0.1);
            s
        };
        let mut r0 = Pcg64::new(1);
        let mut r1 = Pcg64::new(1);
        let full0 = rig[0].scan(&scene, &mut r0).len();
        let full1 = rig[1].scan(&scene, &mut r1).len();
        let ratio = full1 as f64 / full0 as f64;
        assert!(ratio > 1.5 && ratio < 2.5, "point ratio {ratio}");
        let _ = cfg;
    }

    #[test]
    fn frames_have_labels_in_grid() {
        let cfg = tiny_cfg();
        let grid = GridConfig::default();
        let frames = simulate_frames(&cfg, 0x7EA1, 2, &grid);
        assert_eq!(frames.len(), 2);
        for f in &frames {
            assert_eq!(f.clouds.len(), 2);
            for l in &f.labels {
                assert!(l[0] >= grid.range_min[0] as f32 && l[0] <= grid.range_max[0] as f32);
                assert!(l[7] == 0.0 || l[7] == 1.0);
                // objects sit near the ground plane of the common frame
                assert!(l[2] > -6.0 && l[2] < -2.0, "z = {}", l[2]);
            }
        }
    }

    #[test]
    fn roundtrip_write_load() {
        let cfg = tiny_cfg();
        let grid = GridConfig::default();
        let dir = std::env::temp_dir().join("scmii_ds_test");
        let _ = std::fs::remove_dir_all(&dir);
        generate_dataset(&cfg, &grid, &dir).unwrap();
        let train = load_split(&dir.join("train")).unwrap();
        assert_eq!(train.len(), cfg.train_frames);
        assert_eq!(train[0].clouds[0].len(), cfg.max_points);
        let val = load_split(&dir.join("val")).unwrap();
        assert_eq!(val.len(), cfg.val_frames);
        assert!(dir.join("calib/calib_dev0.npy").exists());
        assert!(dir.join("meta.json").exists());
    }

    #[test]
    fn true_transform_matches_rig() {
        let rig = sensor_rig();
        let t = true_device_transform(&rig, 1);
        // device 1 origin mapped into device 0 frame = world offset
        let p = t.apply(crate::geom::Vec3::ZERO);
        assert!((p.x - 15.0).abs() < 1e-9);
        assert!((p.y - 15.0).abs() < 1e-9);
        assert!((p.z - 0.7).abs() < 1e-9);
        // device 0 transform is identity
        let t0 = true_device_transform(&rig, 0);
        let (ang, tr) = t0.error_to(&Pose::IDENTITY);
        assert!(ang < 1e-12 && tr < 1e-12);
    }

    #[test]
    fn determinism() {
        let cfg = tiny_cfg();
        let grid = GridConfig::default();
        let a = simulate_frames(&cfg, 0x7EA1, 2, &grid);
        let b = simulate_frames(&cfg, 0x7EA1, 2, &grid);
        for (fa, fb) in a.iter().zip(&b) {
            assert_eq!(fa.clouds, fb.clouds);
            assert_eq!(fa.labels, fb.labels);
        }
    }
}
