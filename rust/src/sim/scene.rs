//! Intersection scene: static structure + dynamic traffic.
//!
//! World frame: ground plane z = 0, roads along the x and y axes crossing
//! at the origin. Cars follow straight lanes through the intersection;
//! pedestrians cross on crosswalks. Four corner buildings produce the
//! occlusion that motivates multi-LiDAR fusion.

use crate::geom::{Box3, Vec3};
use crate::utils::rng::Pcg64;

/// Object category (matches `classes` in model_meta.json).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjClass {
    Car = 0,
    Pedestrian = 1,
}

impl ObjClass {
    pub fn id(self) -> usize {
        self as usize
    }

    pub fn from_id(id: usize) -> Option<ObjClass> {
        match id {
            0 => Some(ObjClass::Car),
            1 => Some(ObjClass::Pedestrian),
            _ => None,
        }
    }
}

/// A dynamic object: box + constant velocity along its heading.
#[derive(Clone, Debug)]
pub struct SceneObject {
    pub class: ObjClass,
    pub bbox: Box3,
    /// Speed along the heading (m/s).
    pub speed: f64,
    /// Reflectivity in [0, 1] (feeds the intensity channel).
    pub reflectivity: f32,
}

impl SceneObject {
    pub fn step(&mut self, dt: f64) {
        let dir = Vec3::new(self.bbox.yaw.cos(), self.bbox.yaw.sin(), 0.0);
        self.bbox.center += dir * (self.speed * dt);
    }
}

/// Static obstacle (building facade / parked trailer).
#[derive(Clone, Debug)]
pub struct StaticObstacle {
    pub bbox: Box3,
    pub reflectivity: f32,
}

/// Scene state at one instant.
#[derive(Clone, Debug)]
pub struct Scene {
    pub objects: Vec<SceneObject>,
    pub statics: Vec<StaticObstacle>,
    /// Half-extent of the simulated world (objects beyond this despawn).
    pub world_half: f64,
    rng: Pcg64,
    /// Target number of live cars / pedestrians.
    target_cars: usize,
    target_peds: usize,
}

/// Lane offsets from the road centerline (two lanes per direction).
const LANE_OFFSETS: [f64; 2] = [2.0, -2.0];
/// Road half-width (keeps pedestrians off the roadway except crosswalks).
const ROAD_HALF: f64 = 5.0;

impl Scene {
    /// Build the static intersection and spawn initial traffic.
    pub fn new(seed: u64, target_cars: usize, target_peds: usize) -> Scene {
        let mut statics = Vec::new();
        // Corner structures, deliberately asymmetric (like any real
        // intersection): two office buildings, a low kiosk, and a parking
        // lot with two parked cars on the fourth corner. Asymmetry matters
        // twice over — it creates different occlusion shadows per sensor
        // (the paper's blind-spot story) and it breaks the 180° rotational
        // near-symmetry that would otherwise make NDT's yaw estimate
        // ambiguous.
        let corners: [(f64, f64, f64, f64, f32); 3] = [
            // (cx, cy, half_footprint, height, reflectivity)
            (16.0, 16.0, 7.0, 9.0, 0.35),  // NE office block
            (-17.0, 15.0, 6.0, 7.0, 0.4),  // NW office block
            (17.0, -14.0, 2.5, 3.2, 0.45), // SE kiosk
        ];
        for (cx, cy, half, height, refl) in corners {
            statics.push(StaticObstacle {
                bbox: Box3::new(
                    Vec3::new(cx, cy, height / 2.0),
                    Vec3::new(half * 2.0, half * 2.0, height),
                    0.0,
                ),
                reflectivity: refl,
            });
        }
        // SW parking lot: two parked cars.
        statics.push(StaticObstacle {
            bbox: Box3::new(Vec3::new(-13.0, -11.0, 0.75), Vec3::new(4.6, 1.9, 1.5), 0.3),
            reflectivity: 0.6,
        });
        statics.push(StaticObstacle {
            bbox: Box3::new(Vec3::new(-17.0, -13.0, 0.7), Vec3::new(4.4, 1.8, 1.4), 1.2),
            reflectivity: 0.55,
        });
        // A parked box-truck near one curb: occludes part of one street for
        // sensor 1 but not sensor 2 — the paper's blind-spot scenario.
        statics.push(StaticObstacle {
            bbox: Box3::new(Vec3::new(-8.5, 6.8, 1.4), Vec3::new(7.0, 2.4, 2.8), 0.0),
            reflectivity: 0.5,
        });

        let mut scene = Scene {
            objects: Vec::new(),
            statics,
            world_half: 30.0,
            rng: Pcg64::new(seed),
            target_cars,
            target_peds,
        };
        // Pre-roll so frame 0 already has traffic mid-scene. Cars spawn at
        // the upstream world edge, so advance them 0..1.6·world_half along
        // their heading (stays inside the despawn boundary).
        for _ in 0..scene.target_cars {
            let mut car = scene.spawn_car();
            let along = scene.rng.range(0.0, 1.6) * scene.world_half;
            let dir = Vec3::new(car.bbox.yaw.cos(), car.bbox.yaw.sin(), 0.0);
            car.bbox.center += dir * along;
            scene.objects.push(car);
        }
        for _ in 0..scene.target_peds {
            let ped = scene.spawn_pedestrian();
            scene.objects.push(ped);
        }
        scene
    }

    fn spawn_car(&mut self) -> SceneObject {
        let rng = &mut self.rng;
        let length = rng.range(4.1, 4.9);
        let width = rng.range(1.75, 2.0);
        let height = rng.range(1.45, 1.75);
        // Pick a road (x or y), a direction (+ or -) and a lane.
        let along_x = rng.chance(0.5);
        let forward = rng.chance(0.5);
        let lane = *rng.choose(&LANE_OFFSETS);
        let speed = rng.range(4.0, 12.0);
        let half = self.world_half;
        let (center, yaw) = if along_x {
            let y = if forward { -lane } else { lane };
            let x = if forward { -half } else { half };
            (Vec3::new(x, y, height / 2.0), if forward { 0.0 } else { std::f64::consts::PI })
        } else {
            let x = if forward { lane } else { -lane };
            let y = if forward { -half } else { half };
            (
                Vec3::new(x, y, height / 2.0),
                if forward { std::f64::consts::FRAC_PI_2 } else { -std::f64::consts::FRAC_PI_2 },
            )
        };
        SceneObject {
            class: ObjClass::Car,
            bbox: Box3::new(center, Vec3::new(length, width, height), yaw),
            speed,
            reflectivity: rng.range(0.3, 0.9) as f32,
        }
    }

    fn spawn_pedestrian(&mut self) -> SceneObject {
        let rng = &mut self.rng;
        let size = rng.range(0.55, 0.85);
        let height = rng.range(1.55, 1.85);
        // Walk along a sidewalk (just outside the road) or cross at the
        // crosswalk band near the intersection.
        let crossing = rng.chance(0.35);
        let (center, yaw) = if crossing {
            let along_x = rng.chance(0.5);
            let sgn = if rng.chance(0.5) { 1.0 } else { -1.0 };
            let band = rng.range(ROAD_HALF + 0.5, ROAD_HALF + 1.5) * sgn;
            let start = rng.range(-ROAD_HALF, ROAD_HALF);
            if along_x {
                // crossing the y-road: walk along x at y = band
                (Vec3::new(start, band, height / 2.0), if sgn > 0.0 { 0.0 } else { std::f64::consts::PI })
            } else {
                (Vec3::new(band, start, height / 2.0), sgn * std::f64::consts::FRAC_PI_2)
            }
        } else {
            let along_x = rng.chance(0.5);
            let side = rng.range(ROAD_HALF + 0.8, ROAD_HALF + 2.5)
                * if rng.chance(0.5) { 1.0 } else { -1.0 };
            let along = rng.range(-0.7, 0.7) * self.world_half;
            let forward = rng.chance(0.5);
            if along_x {
                (
                    Vec3::new(along, side, height / 2.0),
                    if forward { 0.0 } else { std::f64::consts::PI },
                )
            } else {
                (
                    Vec3::new(side, along, height / 2.0),
                    if forward {
                        std::f64::consts::FRAC_PI_2
                    } else {
                        -std::f64::consts::FRAC_PI_2
                    },
                )
            }
        };
        SceneObject {
            class: ObjClass::Pedestrian,
            bbox: Box3::new(center, Vec3::new(size, size, height), yaw),
            speed: rng.range(0.6, 1.8),
            reflectivity: rng.range(0.2, 0.6) as f32,
        }
    }

    /// Advance all objects by `dt` seconds, despawning those that leave
    /// the world and respawning replacements at the edges.
    pub fn step(&mut self, dt: f64) {
        for obj in &mut self.objects {
            obj.step(dt);
        }
        let half = self.world_half;
        self.objects.retain(|o| {
            o.bbox.center.x.abs() <= half + 3.0 && o.bbox.center.y.abs() <= half + 3.0
        });
        while self.count(ObjClass::Car) < self.target_cars {
            let car = self.spawn_car();
            self.objects.push(car);
        }
        while self.count(ObjClass::Pedestrian) < self.target_peds {
            let ped = self.spawn_pedestrian();
            self.objects.push(ped);
        }
    }

    fn count(&self, class: ObjClass) -> usize {
        self.objects.iter().filter(|o| o.class == class).count()
    }

    /// All occluder boxes a LiDAR ray can hit (dynamic + static).
    pub fn occluders(&self) -> Vec<(Box3, f32)> {
        self.objects
            .iter()
            .map(|o| (o.bbox, o.reflectivity))
            .chain(self.statics.iter().map(|s| (s.bbox, s.reflectivity)))
            .collect()
    }

    /// Scene with traffic removed (for calibration scans: NDT aligns on
    /// static structure the way the paper collects setup-phase clouds).
    pub fn static_only(&self) -> Scene {
        Scene {
            objects: Vec::new(),
            statics: self.statics.clone(),
            world_half: self.world_half,
            rng: Pcg64::new(0),
            target_cars: 0,
            target_peds: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_population_matches_targets() {
        let s = Scene::new(1, 8, 4);
        assert_eq!(s.count(ObjClass::Car), 8);
        assert_eq!(s.count(ObjClass::Pedestrian), 4);
        assert_eq!(s.statics.len(), 6);
    }

    #[test]
    fn cars_move_pedestrians_slower() {
        let mut s = Scene::new(2, 4, 4);
        let before: Vec<Vec3> = s.objects.iter().map(|o| o.bbox.center).collect();
        // Small step: no object reaches the despawn boundary, so the
        // object list (and its order) is stable across the step.
        s.step(0.2);
        assert_eq!(s.objects.len(), before.len());
        for (obj, b) in s.objects.iter().zip(&before) {
            let moved = (obj.bbox.center - *b).norm();
            match obj.class {
                ObjClass::Car => assert!(moved >= 0.2 * 3.9, "car moved {moved}"),
                ObjClass::Pedestrian => assert!(moved <= 0.2 * 1.9, "ped moved {moved}"),
            }
        }
    }

    #[test]
    fn population_is_maintained_over_time() {
        let mut s = Scene::new(3, 6, 3);
        for _ in 0..200 {
            s.step(0.1);
        }
        assert_eq!(s.count(ObjClass::Car), 6);
        assert_eq!(s.count(ObjClass::Pedestrian), 3);
        for o in &s.objects {
            assert!(o.bbox.center.x.abs() <= s.world_half + 3.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Scene::new(42, 5, 5);
        let mut b = Scene::new(42, 5, 5);
        for _ in 0..50 {
            a.step(0.1);
            b.step(0.1);
        }
        for (x, y) in a.objects.iter().zip(&b.objects) {
            assert_eq!(x.bbox.center, y.bbox.center);
        }
    }

    #[test]
    fn objects_stay_on_ground() {
        let mut s = Scene::new(7, 6, 4);
        for _ in 0..100 {
            s.step(0.1);
        }
        for o in &s.objects {
            assert!((o.bbox.z_min()).abs() < 1e-9, "object floats: {:?}", o.bbox);
        }
    }
}
