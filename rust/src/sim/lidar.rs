//! Spinning-LiDAR sensor model with occlusion raycasting.
//!
//! Emulates the two Ouster sensors the paper deploys (Table II):
//! OS1-64 (64 beams) on device 1 and OS1-128 (128 beams) on device 2,
//! both 10 Hz, vertical FoV ±22.5°. Rays are cast against the scene's
//! occluder boxes and the ground plane; the nearest hit wins (that *is*
//! occlusion). Gaussian range noise and per-ray dropout model real
//! returns. Output points are expressed in the sensor's local frame.

use super::scene::Scene;
use crate::geom::ray::{ray_box, ray_ground, Ray};
use crate::geom::{Pose, Vec3};
use crate::utils::rng::Pcg64;
use crate::voxel::Point;

/// Static description of a sensor model.
#[derive(Clone, Debug)]
pub struct LidarSpec {
    pub name: &'static str,
    pub beams: usize,
    /// Azimuth samples per revolution (decimated from the real 1024 to
    /// keep datagen fast; density ratios between sensors are preserved).
    pub azimuth_steps: usize,
    /// Vertical field of view (radians, down/up from horizontal).
    pub fov_down: f64,
    pub fov_up: f64,
    pub max_range: f64,
    /// 1-σ range noise, metres.
    pub range_noise: f64,
    /// Probability a valid return is dropped.
    pub dropout: f64,
}

impl LidarSpec {
    /// Ouster OS1-64 emulation.
    pub fn os1_64() -> LidarSpec {
        LidarSpec {
            name: "OS1-64",
            beams: 64,
            azimuth_steps: 512,
            fov_down: -22.5f64.to_radians(),
            fov_up: 22.5f64.to_radians(),
            max_range: 90.0,
            range_noise: 0.025,
            dropout: 0.05,
        }
    }

    /// Ouster OS1-128 emulation (twice the beams of the OS1-64 — device 2
    /// processes roughly twice the points, as in the paper §IV-A).
    pub fn os1_128() -> LidarSpec {
        LidarSpec {
            name: "OS1-128",
            beams: 128,
            azimuth_steps: 512,
            fov_down: -22.5f64.to_radians(),
            fov_up: 22.5f64.to_radians(),
            max_range: 90.0,
            range_noise: 0.025,
            dropout: 0.05,
        }
    }
}

/// A sensor instance: spec + mounting pose (sensor → world).
#[derive(Clone, Debug)]
pub struct LidarModel {
    pub spec: LidarSpec,
    pub pose: Pose,
}

impl LidarModel {
    pub fn new(spec: LidarSpec, pose: Pose) -> LidarModel {
        LidarModel { spec, pose }
    }

    /// Capture one scan of `scene`. Returns points in the sensor's local
    /// frame. `rng` drives noise/dropout (fork it per frame for
    /// determinism).
    pub fn scan(&self, scene: &Scene, rng: &mut Pcg64) -> Vec<Point> {
        let occluders = scene.occluders();
        let inv = self.pose.inverse();
        let origin = self.pose.trans;
        let mut out = Vec::with_capacity(self.spec.beams * self.spec.azimuth_steps / 4);

        for b in 0..self.spec.beams {
            let frac = if self.spec.beams == 1 { 0.5 } else { b as f64 / (self.spec.beams - 1) as f64 };
            let elev = self.spec.fov_down + frac * (self.spec.fov_up - self.spec.fov_down);
            let (sin_e, cos_e) = elev.sin_cos();
            for a in 0..self.spec.azimuth_steps {
                let az = a as f64 / self.spec.azimuth_steps as f64 * std::f64::consts::TAU;
                let (sin_a, cos_a) = az.sin_cos();
                // Direction in sensor frame, rotated to world.
                let dir_local = Vec3::new(cos_e * cos_a, cos_e * sin_a, sin_e);
                let dir = self.pose.apply_dir(dir_local);
                let ray = Ray { origin, dir };

                // Nearest hit among boxes and ground.
                let mut best_t = f64::INFINITY;
                let mut best_refl = 0.0f32;
                for (bbox, refl) in &occluders {
                    if let Some(t) = ray_box(&ray, bbox) {
                        if t < best_t {
                            best_t = t;
                            best_refl = *refl;
                        }
                    }
                }
                if let Some(t) = ray_ground(&ray, 0.0) {
                    if t < best_t {
                        best_t = t;
                        best_refl = 0.15; // asphalt
                    }
                }
                if !best_t.is_finite() || best_t > self.spec.max_range {
                    continue;
                }
                if rng.chance(self.spec.dropout) {
                    continue;
                }
                let t_noisy = best_t + rng.gauss(0.0, self.spec.range_noise);
                let world_pt = ray.at(t_noisy);
                let local = inv.apply(world_pt);
                // Intensity: reflectivity attenuated by range (1/r² folded
                // into a soft falloff, clamped).
                let atten = (1.0 - (best_t / self.spec.max_range)).clamp(0.05, 1.0) as f32;
                out.push(Point::new(
                    local.x as f32,
                    local.y as f32,
                    local.z as f32,
                    (best_refl * atten).clamp(0.0, 1.0),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Mat3;

    fn test_sensor(beams: usize) -> LidarModel {
        let spec = LidarSpec {
            name: "test",
            beams,
            azimuth_steps: 128,
            fov_down: -22.5f64.to_radians(),
            fov_up: 22.5f64.to_radians(),
            max_range: 90.0,
            range_noise: 0.0,
            dropout: 0.0,
        };
        let pose =
            Pose::new(Mat3::rot_z(0.0), Vec3::new(-7.5, -7.5, 4.5));
        LidarModel::new(spec, pose)
    }

    #[test]
    fn scan_produces_points_in_local_frame() {
        let scene = Scene::new(1, 6, 3);
        let lidar = test_sensor(16);
        let mut rng = Pcg64::new(9);
        let pts = lidar.scan(&scene, &mut rng);
        assert!(!pts.is_empty());
        // Ground hits: in local frame the sensor is at origin, ground at
        // z ≈ -4.5.
        let ground_pts = pts.iter().filter(|p| (p.z + 4.5).abs() < 0.2).count();
        assert!(ground_pts > pts.len() / 8, "{} of {}", ground_pts, pts.len());
    }

    #[test]
    fn more_beams_more_points() {
        let scene = Scene::new(2, 6, 3);
        let small = test_sensor(16);
        let big = test_sensor(32);
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(1);
        let n_small = small.scan(&scene, &mut r1).len();
        let n_big = big.scan(&scene, &mut r2).len();
        let ratio = n_big as f64 / n_small as f64;
        assert!(ratio > 1.6 && ratio < 2.4, "ratio {ratio}");
    }

    #[test]
    fn occlusion_hides_object_behind_building() {
        // An object directly behind the (16,16) building as seen from the
        // sensor pole should receive no points.
        let mut scene = Scene::new(3, 0, 0);
        scene.objects.push(super::super::scene::SceneObject {
            class: super::super::scene::ObjClass::Car,
            bbox: crate::geom::Box3::new(Vec3::new(26.0, 26.0, 0.8), Vec3::new(4.5, 1.9, 1.6), 0.0),
            speed: 0.0,
            reflectivity: 0.9,
        });
        let lidar = test_sensor(64);
        let mut rng = Pcg64::new(5);
        let pts = lidar.scan(&scene, &mut rng);
        // Count points near the hidden car (local frame: car at world
        // (26,26) minus sensor (-7.5,-7.5,4.5) = (33.5,33.5,-3.7)).
        let near_car = pts
            .iter()
            .filter(|p| (p.x - 33.5).abs() < 3.0 && (p.y - 33.5).abs() < 3.0 && p.z > -4.0)
            .count();
        assert_eq!(near_car, 0, "car behind building must be occluded");
    }

    #[test]
    fn visible_object_gets_points() {
        let mut scene = Scene::new(4, 0, 0);
        // Car in the open intersection, visible from the pole.
        scene.objects.push(super::super::scene::SceneObject {
            class: super::super::scene::ObjClass::Car,
            bbox: crate::geom::Box3::new(Vec3::new(0.0, 0.0, 0.8), Vec3::new(4.5, 1.9, 1.6), 0.3),
            speed: 0.0,
            reflectivity: 0.9,
        });
        let lidar = test_sensor(64);
        let mut rng = Pcg64::new(6);
        let pts = lidar.scan(&scene, &mut rng);
        let world_box = &scene.objects[0].bbox;
        let on_car = pts
            .iter()
            .filter(|p| {
                let w = lidar.pose.apply(Vec3::new(p.x as f64, p.y as f64, p.z as f64));
                world_box.contains(w + Vec3::new(0.0, 0.0, 0.0))
            })
            .count();
        assert!(on_car > 10, "visible car got {} points", on_car);
    }

    #[test]
    fn determinism_per_seed() {
        let scene = Scene::new(8, 4, 2);
        let lidar = test_sensor(16);
        let a = lidar.scan(&scene, &mut Pcg64::new(3));
        let b = lidar.scan(&scene, &mut Pcg64::new(3));
        assert_eq!(a, b);
    }
}
