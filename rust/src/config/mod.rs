//! Run-time configuration: detection-grid geometry, testbed latency
//! model, artifact metadata emitted by the python AOT path.
//!
//! The single source of truth for model geometry is
//! `python/compile/configs.py`; `aot.py` serializes it into
//! `artifacts/model_meta.json`, which [`ModelMeta::load`] parses. The
//! rust defaults below mirror the same canonical profile so unit tests
//! and the simulator run without artifacts present.

pub mod meta;

pub use meta::{
    deep_channels, executable_split, normalize_split, split_executable, wire_channels,
    IntegrationKind, ModelMeta, VariantMeta, DEFAULT_SPLIT, SPLIT_DEEP, SPLIT_DEPTHS,
    SPLIT_MID, SPLIT_SHALLOW,
};

use crate::utils::json::Json;
use anyhow::Result;
use std::path::Path;

/// Voxel-grid geometry of the detector (matches python `configs.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct GridConfig {
    /// Detection range minimum corner (x, y, z) in the common frame, metres.
    pub range_min: [f64; 3],
    /// Detection range maximum corner.
    pub range_max: [f64; 3],
    /// Voxel edge lengths (dx, dy, dz), metres.
    pub voxel: [f64; 3],
    /// Grid dimensions (W = x cells, H = y cells, D = z cells).
    pub dims: [usize; 3],
    /// Per-voxel input feature channels (voxelization statistics).
    pub c_in: usize,
    /// Head output channels (the intermediate output that crosses the wire).
    pub c_head: usize,
    /// Max points per LiDAR fed to the model (fixed-size padding).
    pub max_points: usize,
}

impl Default for GridConfig {
    fn default() -> Self {
        // The common frame is LiDAR 1's local frame (paper: one sensor is
        // the reference). The sensor sits ~4.5 m above ground, so the
        // detection volume lies below the origin; x/y bounds are chosen so
        // the grid covers the intersection the rig observes (sensor at
        // world (-7.5, -7.5), intersection at world (0, 0), world extent
        // ±25.6 m around it).
        GridConfig {
            range_min: [-18.1, -18.1, -6.0],
            range_max: [33.1, 33.1, 0.0],
            voxel: [0.8, 0.8, 0.75],
            dims: [64, 64, 8],
            c_in: 6,
            c_head: 8,
            max_points: 4096,
        }
    }
}

impl GridConfig {
    /// Total voxel count (W·H·D).
    pub fn n_voxels(&self) -> usize {
        self.dims[0] * self.dims[1] * self.dims[2]
    }

    /// Intermediate-output element count (W·H·D·c_head).
    pub fn feature_len(&self) -> usize {
        self.n_voxels() * self.c_head
    }

    /// Intermediate-output payload in bytes (f32).
    pub fn feature_bytes(&self) -> usize {
        self.feature_len() * 4
    }

    /// Voxel index (ix, iy, iz) of a point, if inside range.
    pub fn voxel_of(&self, x: f64, y: f64, z: f64) -> Option<[usize; 3]> {
        let fx = (x - self.range_min[0]) / self.voxel[0];
        let fy = (y - self.range_min[1]) / self.voxel[1];
        let fz = (z - self.range_min[2]) / self.voxel[2];
        if fx < 0.0 || fy < 0.0 || fz < 0.0 {
            return None;
        }
        let (ix, iy, iz) = (fx as usize, fy as usize, fz as usize);
        if ix >= self.dims[0] || iy >= self.dims[1] || iz >= self.dims[2] {
            return None;
        }
        Some([ix, iy, iz])
    }

    /// Center of a voxel in metres.
    pub fn voxel_center(&self, ix: usize, iy: usize, iz: usize) -> [f64; 3] {
        [
            self.range_min[0] + (ix as f64 + 0.5) * self.voxel[0],
            self.range_min[1] + (iy as f64 + 0.5) * self.voxel[1],
            self.range_min[2] + (iz as f64 + 0.5) * self.voxel[2],
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("range_min", Json::from_f64_slice(&self.range_min))
            .set("range_max", Json::from_f64_slice(&self.range_max))
            .set("voxel", Json::from_f64_slice(&self.voxel))
            .set("dims", Json::from_usize_slice(&self.dims))
            .set("c_in", Json::Num(self.c_in as f64))
            .set("c_head", Json::Num(self.c_head as f64))
            .set("max_points", Json::Num(self.max_points as f64));
        j
    }

    pub fn from_json(j: &Json) -> Result<GridConfig> {
        let vec3 = |key: &str| -> Result<[f64; 3]> {
            let v = j.req(key)?.as_f64_vec()?;
            anyhow::ensure!(v.len() == 3, "{key} must have 3 entries");
            Ok([v[0], v[1], v[2]])
        };
        let dims = j.req("dims")?.as_usize_vec()?;
        anyhow::ensure!(dims.len() == 3, "dims must have 3 entries");
        Ok(GridConfig {
            range_min: vec3("range_min")?,
            range_max: vec3("range_max")?,
            voxel: vec3("voxel")?,
            dims: [dims[0], dims[1], dims[2]],
            c_in: j.req("c_in")?.as_usize()?,
            c_head: j.req("c_head")?.as_usize()?,
            max_points: j.req("max_points")?.as_usize()?,
        })
    }
}

/// Testbed latency model standing in for the paper's hardware (Table I):
/// Jetson Orin Nano edge devices, RTX-4090 server, 1 Gbps wired LAN.
///
/// We measure compute on this machine's CPU PJRT backend and scale by
/// device factors. Fig 5 compares *arrangements* of the same compute, so
/// ratios survive the substitution (see DESIGN.md §4).
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyConfig {
    /// Edge-device slowdown vs the measurement machine (Jetson Orin Nano
    /// running the 3D backbone vs our CPU baseline).
    pub edge_factor: f64,
    /// Server speedup/slowdown vs the measurement machine (RTX 4090).
    pub server_factor: f64,
    /// Link bandwidth, bits per second (paper: 1 Gbps wired LAN).
    pub bandwidth_bps: f64,
    /// Fixed per-message latency (framing + kernel + switch), seconds.
    pub base_rtt: f64,
}

impl Default for LatencyConfig {
    fn default() -> Self {
        LatencyConfig {
            edge_factor: 6.0,
            server_factor: 0.25,
            bandwidth_bps: 1e9,
            base_rtt: 0.5e-3,
        }
    }
}

impl LatencyConfig {
    /// Transmission time for a payload of `bytes`.
    pub fn tx_time(&self, bytes: usize) -> f64 {
        self.base_rtt + (bytes as f64 * 8.0) / self.bandwidth_bps
    }
}

/// Where artifacts/data live; every binary takes these as flags.
#[derive(Clone, Debug)]
pub struct Paths {
    pub artifacts: std::path::PathBuf,
    pub data: std::path::PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        Paths { artifacts: "artifacts".into(), data: "data".into() }
    }
}

impl Paths {
    pub fn new(artifacts: &str, data: &str) -> Paths {
        Paths { artifacts: artifacts.into(), data: data.into() }
    }

    pub fn model_meta(&self) -> std::path::PathBuf {
        self.artifacts.join("model_meta.json")
    }

    pub fn calib(&self) -> std::path::PathBuf {
        self.artifacts.join("calib.json")
    }

    pub fn hlo(&self, name: &str) -> std::path::PathBuf {
        self.artifacts.join(format!("{name}.hlo.txt"))
    }
}

/// Find the repository root (directory containing Cargo.toml) so tests and
/// examples work from any cwd.
pub fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("Cargo.toml").exists() {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}

/// Paths anchored at the repo root (used by tests/examples).
pub fn default_paths() -> Paths {
    let root = repo_root();
    Paths { artifacts: root.join("artifacts"), data: root.join("data") }
}

/// True when the AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_present(paths: &Paths) -> bool {
    paths.model_meta().exists()
}

/// Load the calibration transforms written by `scmii setup`
/// (`artifacts/calib.json`), one device→common pose per device.
pub fn load_calib(paths: &Paths) -> Result<Vec<crate::geom::Pose>> {
    let j = crate::utils::json::read_file(&paths.calib())?;
    let arr = j.req("transforms")?.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for t in arr {
        let v = t.as_f64_vec()?;
        anyhow::ensure!(v.len() == 16, "transform must be 4x4");
        let mut m = [0.0; 16];
        m.copy_from_slice(&v);
        out.push(crate::geom::Pose::from_mat4(&m));
    }
    Ok(out)
}

/// Convenience: load grid config from model_meta.json if present, else default.
pub fn grid_or_default(paths: &Paths) -> GridConfig {
    fn load(p: &Path) -> Result<GridConfig> {
        let j = crate::utils::json::read_file(p)?;
        GridConfig::from_json(j.req("grid")?)
    }
    load(&paths.model_meta()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_dims_consistent_with_range() {
        let g = GridConfig::default();
        for a in 0..3 {
            let extent = g.range_max[a] - g.range_min[a];
            let cells = (extent / g.voxel[a]).round() as usize;
            assert_eq!(cells, g.dims[a], "axis {a}");
        }
    }

    #[test]
    fn voxel_of_bounds() {
        let g = GridConfig::default();
        assert_eq!(g.voxel_of(-18.1, -18.1, -6.0), Some([0, 0, 0]));
        assert_eq!(g.voxel_of(33.09, 33.09, -0.01), Some([63, 63, 7]));
        assert_eq!(g.voxel_of(33.2, 0.0, -1.0), None);
        assert_eq!(g.voxel_of(0.0, 0.0, 0.5), None);
    }

    #[test]
    fn voxel_center_inverts_voxel_of() {
        let g = GridConfig::default();
        let c = g.voxel_center(10, 20, 3);
        assert_eq!(g.voxel_of(c[0], c[1], c[2]), Some([10, 20, 3]));
    }

    #[test]
    fn grid_json_roundtrip() {
        let g = GridConfig::default();
        let j = g.to_json();
        let g2 = GridConfig::from_json(&j).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn feature_payload_is_1mib() {
        let g = GridConfig::default();
        assert_eq!(g.feature_bytes(), 64 * 64 * 8 * 8 * 4);
    }

    #[test]
    fn tx_time_scales_with_bytes() {
        let l = LatencyConfig::default();
        let t1 = l.tx_time(1_000_000);
        let t2 = l.tx_time(2_000_000);
        assert!(t2 > t1);
        // 1 MB over 1 Gbps = 8 ms plus base
        assert!((t1 - (0.5e-3 + 8e-3)).abs() < 1e-9);
    }
}
