//! `artifacts/model_meta.json` — the contract between the python AOT
//! path and the rust runtime. Describes the lowered artifacts (which HLO
//! file implements which model part), the anchor layout the detection
//! heads were trained with, and the grid geometry.

use super::GridConfig;
use crate::utils::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Integration method of a SC-MII variant (paper §III-A.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntegrationKind {
    /// Element-wise max across device feature maps.
    Max,
    /// Concat along channels + conv3d with kernel size 1.
    ConvK1,
    /// Concat along channels + conv3d with kernel size 3.
    ConvK3,
}

impl IntegrationKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "max" => Ok(IntegrationKind::Max),
            "conv_k1" => Ok(IntegrationKind::ConvK1),
            "conv_k3" => Ok(IntegrationKind::ConvK3),
            other => bail!("unknown integration kind {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IntegrationKind::Max => "max",
            IntegrationKind::ConvK1 => "conv_k1",
            IntegrationKind::ConvK3 => "conv_k3",
        }
    }

    pub fn all() -> [IntegrationKind; 3] {
        [IntegrationKind::Max, IntegrationKind::ConvK1, IntegrationKind::ConvK3]
    }
}

/// Shallowest split depth: the device only voxelizes and ships the raw
/// per-voxel statistics (`c_in` channels); the per-voxel projection is
/// deferred to the server tail.
pub const SPLIT_SHALLOW: &str = "split-shallow";
/// The default split depth — the cut every pre-split deployment already
/// serves: voxelize + per-voxel projection on the device, `c_head`
/// channels on the wire.
pub const SPLIT_MID: &str = "split-mid";
/// Deepest split depth: the device additionally runs a bottleneck stage
/// down to [`deep_channels`] channels (smaller uplink, more device
/// compute); the tail expands back to `c_head` before alignment.
pub const SPLIT_DEEP: &str = "split-deep";
/// Every split depth the runtime serves, shallowest first.
pub const SPLIT_DEPTHS: [&str; 3] = [SPLIT_SHALLOW, SPLIT_MID, SPLIT_DEEP];
/// The depth legacy clients (and empty `split` fields) land on.
pub const DEFAULT_SPLIT: &str = SPLIT_MID;

/// Canonicalize a user-facing split name: the empty string means the
/// default depth; anything outside [`SPLIT_DEPTHS`] is an error naming
/// the offender.
pub fn normalize_split(split: &str) -> Result<&'static str> {
    match split {
        "" | SPLIT_MID => Ok(SPLIT_MID),
        SPLIT_SHALLOW => Ok(SPLIT_SHALLOW),
        SPLIT_DEEP => Ok(SPLIT_DEEP),
        other => bail!("unknown split depth {other:?} (expected one of {SPLIT_DEPTHS:?})"),
    }
}

/// Executable name of artifact `base` at `split`. The default depth
/// keeps the bare artifact name — pre-split deployments resolve (and
/// synthesize weights, which are seeded by name) exactly as before —
/// while other depths append `@split`, so every depth is a distinct
/// executable and batch keys never mix splits.
pub fn split_executable(base: &str, split: &str) -> Result<String> {
    let split = normalize_split(split)?;
    if split == DEFAULT_SPLIT {
        Ok(base.to_string())
    } else {
        Ok(format!("{base}@{split}"))
    }
}

/// Inverse of [`split_executable`]: the `(base, canonical split)` of an
/// executable name. Names without a recognized `@split` suffix are the
/// default depth.
pub fn executable_split(name: &str) -> (&str, &'static str) {
    if let Some((base, suffix)) = name.rsplit_once('@') {
        if let Ok(split) = normalize_split(suffix) {
            return (base, split);
        }
    }
    (name, DEFAULT_SPLIT)
}

/// Channel width of the deep cut's device-side bottleneck stage.
pub fn deep_channels(grid: &GridConfig) -> usize {
    (grid.c_head / 2).max(1)
}

/// Channels a device feature map carries on the wire at `split` (the
/// uplink payload scales linearly with this).
pub fn wire_channels(grid: &GridConfig, split: &str) -> Result<usize> {
    Ok(match normalize_split(split)? {
        SPLIT_SHALLOW => grid.c_in,
        SPLIT_DEEP => deep_channels(grid),
        _ => grid.c_head,
    })
}

/// One trained SC-MII variant and its artifact names.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub integration: IntegrationKind,
    /// Artifact name of the head model per device (index = device id).
    pub heads: Vec<String>,
    /// Artifact name of the tail model (takes all aligned head outputs).
    pub tail: String,
}

impl VariantMeta {
    /// Head executable for `device` at `split` (default depth = the bare
    /// artifact name).
    pub fn head_for(&self, device: usize, split: &str) -> Result<String> {
        let head = self
            .heads
            .get(device)
            .with_context(|| format!("variant {} has no head for device {device}", self.tail))?;
        split_executable(head, split)
    }

    /// Tail executable at `split` (default depth = the bare artifact name).
    pub fn tail_for(&self, split: &str) -> Result<String> {
        split_executable(&self.tail, split)
    }
}

/// An anchor template of the detection head.
#[derive(Clone, Copy, Debug)]
pub struct Anchor {
    /// (length, width, height), metres.
    pub size: [f64; 3],
    /// z of the anchor box center in the common frame.
    pub z_center: f64,
    pub yaw: f64,
    /// Index into `classes`.
    pub class_id: usize,
}

/// Full metadata for a set of lowered artifacts.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub grid: GridConfig,
    pub classes: Vec<String>,
    pub anchors: Vec<Anchor>,
    /// BEV head resolution (rows = y cells, cols = x cells).
    pub bev_dims: [usize; 2],
    pub variants: Vec<VariantMeta>,
    /// Full single-LiDAR models (accuracy baseline), one per device.
    pub single_full: Vec<String>,
    /// Full model over merged raw point clouds (paper's accuracy
    /// upper bound and the edge-only latency baseline).
    pub input_integration_full: String,
    pub num_devices: usize,
}

impl ModelMeta {
    pub fn load(path: &Path) -> Result<ModelMeta> {
        let j = json::read_file(path)?;
        Self::from_json(&j).with_context(|| format!("parse {}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<ModelMeta> {
        let grid = GridConfig::from_json(j.req("grid")?)?;
        let classes = j
            .req("classes")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let anchors = j
            .req("anchors")?
            .as_arr()?
            .iter()
            .map(|a| {
                let size = a.req("size")?.as_f64_vec()?;
                anyhow::ensure!(size.len() == 3, "anchor size must have 3 entries");
                Ok(Anchor {
                    size: [size[0], size[1], size[2]],
                    z_center: a.req("z_center")?.as_f64()?,
                    yaw: a.req("yaw")?.as_f64()?,
                    class_id: a.req("class_id")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let bev = j.req("bev_dims")?.as_usize_vec()?;
        anyhow::ensure!(bev.len() == 2, "bev_dims must have 2 entries");
        let variants = j
            .req("variants")?
            .as_arr()?
            .iter()
            .map(|v| {
                Ok(VariantMeta {
                    integration: IntegrationKind::parse(v.req("integration")?.as_str()?)?,
                    heads: v
                        .req("heads")?
                        .as_arr()?
                        .iter()
                        .map(|h| Ok(h.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                    tail: v.req("tail")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let single_full = j
            .req("single_full")?
            .as_arr()?
            .iter()
            .map(|h| Ok(h.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let meta = ModelMeta {
            grid,
            classes,
            anchors,
            bev_dims: [bev[0], bev[1]],
            variants,
            single_full,
            input_integration_full: j.req("input_integration_full")?.as_str()?.to_string(),
            num_devices: j.req("num_devices")?.as_usize()?,
        };
        meta.validate()?;
        Ok(meta)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_devices >= 1, "need at least one device");
        anyhow::ensure!(!self.anchors.is_empty(), "no anchors");
        anyhow::ensure!(!self.classes.is_empty(), "no classes");
        for a in &self.anchors {
            anyhow::ensure!(a.class_id < self.classes.len(), "anchor class out of range");
        }
        for v in &self.variants {
            anyhow::ensure!(
                v.heads.len() == self.num_devices,
                "variant {} has {} heads for {} devices",
                v.tail,
                v.heads.len(),
                self.num_devices
            );
        }
        anyhow::ensure!(
            self.single_full.len() == self.num_devices,
            "single_full count != num_devices"
        );
        Ok(())
    }

    pub fn variant(&self, kind: IntegrationKind) -> Result<&VariantMeta> {
        self.variants
            .iter()
            .find(|v| v.integration == kind)
            .with_context(|| format!("no variant {:?} in model_meta", kind))
    }

    /// BEV cell center (x, y) in metres for a head output cell.
    /// Row index runs along y, column along x.
    pub fn bev_cell_center(&self, row: usize, col: usize) -> (f64, f64) {
        let g = &self.grid;
        let cell_x = (g.range_max[0] - g.range_min[0]) / self.bev_dims[1] as f64;
        let cell_y = (g.range_max[1] - g.range_min[1]) / self.bev_dims[0] as f64;
        (
            g.range_min[0] + (col as f64 + 0.5) * cell_x,
            g.range_min[1] + (row as f64 + 0.5) * cell_y,
        )
    }

    /// A default meta for unit tests that don't need real artifacts.
    pub fn test_default() -> ModelMeta {
        let grid = GridConfig::default();
        ModelMeta {
            grid,
            classes: vec!["car".into(), "pedestrian".into()],
            // z_center is in the common (sensor-1) frame: ground sits at
            // z = -4.5, so a 1.6 m car is centered at -3.7.
            anchors: vec![
                Anchor { size: [4.5, 1.9, 1.6], z_center: -3.7, yaw: 0.0, class_id: 0 },
                Anchor {
                    size: [4.5, 1.9, 1.6],
                    z_center: -3.7,
                    yaw: std::f64::consts::FRAC_PI_2,
                    class_id: 0,
                },
                Anchor { size: [0.8, 0.8, 1.7], z_center: -3.65, yaw: 0.0, class_id: 1 },
            ],
            bev_dims: [32, 32],
            variants: IntegrationKind::all()
                .iter()
                .map(|&k| VariantMeta {
                    integration: k,
                    heads: vec![
                        format!("head_{}_dev0", k.name()),
                        format!("head_{}_dev1", k.name()),
                    ],
                    tail: format!("tail_{}", k.name()),
                })
                .collect(),
            single_full: vec!["single_dev0".into(), "single_dev1".into()],
            input_integration_full: "input_integration".into(),
            num_devices: 2,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("grid", self.grid.to_json());
        j.set("classes", Json::Arr(self.classes.iter().map(|c| Json::Str(c.clone())).collect()));
        j.set(
            "anchors",
            Json::Arr(
                self.anchors
                    .iter()
                    .map(|a| {
                        let mut o = Json::obj();
                        o.set("size", Json::from_f64_slice(&a.size))
                            .set("z_center", Json::Num(a.z_center))
                            .set("yaw", Json::Num(a.yaw))
                            .set("class_id", Json::Num(a.class_id as f64));
                        o
                    })
                    .collect(),
            ),
        );
        j.set("bev_dims", Json::from_usize_slice(&self.bev_dims));
        j.set(
            "variants",
            Json::Arr(
                self.variants
                    .iter()
                    .map(|v| {
                        let mut o = Json::obj();
                        o.set("integration", Json::Str(v.integration.name().into()))
                            .set(
                                "heads",
                                Json::Arr(
                                    v.heads.iter().map(|h| Json::Str(h.clone())).collect(),
                                ),
                            )
                            .set("tail", Json::Str(v.tail.clone()));
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "single_full",
            Json::Arr(self.single_full.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        j.set("input_integration_full", Json::Str(self.input_integration_full.clone()));
        j.set("num_devices", Json::Num(self.num_devices as f64));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_json_roundtrip() {
        let meta = ModelMeta::test_default();
        let j = meta.to_json();
        let back = ModelMeta::from_json(&j).unwrap();
        assert_eq!(back.classes, meta.classes);
        assert_eq!(back.anchors.len(), meta.anchors.len());
        assert_eq!(back.variants.len(), 3);
        assert_eq!(back.num_devices, 2);
    }

    #[test]
    fn variant_lookup() {
        let meta = ModelMeta::test_default();
        assert_eq!(meta.variant(IntegrationKind::ConvK3).unwrap().tail, "tail_conv_k3");
        assert_eq!(meta.variant(IntegrationKind::Max).unwrap().heads.len(), 2);
    }

    #[test]
    fn bev_cell_centers_cover_range() {
        let meta = ModelMeta::test_default();
        let (x0, y0) = meta.bev_cell_center(0, 0);
        let (x1, y1) = meta.bev_cell_center(31, 31);
        assert!((x0 - -17.3).abs() < 1e-9, "{x0}");
        assert!((y0 - -17.3).abs() < 1e-9, "{y0}");
        assert!((x1 - 32.3).abs() < 1e-9, "{x1}");
        assert!((y1 - 32.3).abs() < 1e-9, "{y1}");
    }

    #[test]
    fn validate_rejects_bad_meta() {
        let mut meta = ModelMeta::test_default();
        meta.anchors[0].class_id = 99;
        assert!(meta.validate().is_err());
        let mut meta2 = ModelMeta::test_default();
        meta2.variants[0].heads.pop();
        assert!(meta2.validate().is_err());
    }

    #[test]
    fn split_names_normalize_and_mangle() {
        assert_eq!(normalize_split("").unwrap(), SPLIT_MID);
        assert_eq!(normalize_split("split-mid").unwrap(), SPLIT_MID);
        assert_eq!(normalize_split("split-shallow").unwrap(), SPLIT_SHALLOW);
        let err = normalize_split("split-depe").unwrap_err().to_string();
        assert!(err.contains("split-depe"), "{err}");

        // The default depth keeps the bare artifact name (synthetic
        // weights are seeded by name, so this is what keeps pre-split
        // deployments byte-identical).
        assert_eq!(split_executable("tail_max", "").unwrap(), "tail_max");
        assert_eq!(split_executable("tail_max", SPLIT_MID).unwrap(), "tail_max");
        assert_eq!(
            split_executable("tail_max", SPLIT_DEEP).unwrap(),
            "tail_max@split-deep"
        );
        assert_eq!(executable_split("tail_max"), ("tail_max", SPLIT_MID));
        assert_eq!(
            executable_split("tail_max@split-deep"),
            ("tail_max", SPLIT_DEEP)
        );
        // An '@' that is not a split suffix stays part of the base name.
        assert_eq!(executable_split("weird@name"), ("weird@name", SPLIT_MID));
    }

    #[test]
    fn variant_split_names_and_wire_channels() {
        let meta = ModelMeta::test_default();
        let v = meta.variant(IntegrationKind::Max).unwrap();
        assert_eq!(v.head_for(0, "").unwrap(), "head_max_dev0");
        assert_eq!(v.head_for(1, SPLIT_SHALLOW).unwrap(), "head_max_dev1@split-shallow");
        assert_eq!(v.tail_for(SPLIT_DEEP).unwrap(), "tail_max@split-deep");
        assert!(v.head_for(2, "").is_err());
        assert!(v.tail_for("nope").is_err());

        let g = &meta.grid;
        assert_eq!(wire_channels(g, SPLIT_SHALLOW).unwrap(), g.c_in);
        assert_eq!(wire_channels(g, "").unwrap(), g.c_head);
        assert_eq!(wire_channels(g, SPLIT_DEEP).unwrap(), (g.c_head / 2).max(1));
    }

    #[test]
    fn integration_kind_parse() {
        assert_eq!(IntegrationKind::parse("max").unwrap(), IntegrationKind::Max);
        assert_eq!(IntegrationKind::parse("conv_k3").unwrap(), IntegrationKind::ConvK3);
        assert!(IntegrationKind::parse("bogus").is_err());
    }
}
