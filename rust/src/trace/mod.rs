//! Trace record/replay load generator (`scmii trace`).
//!
//! Every performance number this repo emits ultimately depends on the
//! *load shape*: how many devices feed how many sessions, how bursty
//! their arrivals are, which (frame, device) slots never arrive. The
//! fleet scenario harness synthesizes that shape; this module instead
//! **captures the real one** and plays it back:
//!
//! - [`TraceSink`] tees the live wire stream on the server: every
//!   decoded intermediate output ([`Msg::Features`] / [`Msg::FeaturesQ`])
//!   is re-framed and appended — with its arrival timestamp — to a
//!   length-prefixed capture file. Recording is enabled with
//!   `scmii serve --trace out.scmt` or `scmii trace record` (which runs
//!   a scenario with the tee on).
//! - [`TraceSource`] reads a capture back; [`replay`] feeds it into
//!   fresh [`DetectorSession`](crate::coordinator::session::DetectorSession)s
//!   at `--speed N` times recorded pace, `--repeat R` times over, and
//!   verifies the outcome is **identical every repeat** (same
//!   frames-done and synchronizer accounting) — the determinism gate CI
//!   runs. With `--connect host:port` the same pacing streams the raw
//!   frames over real TCP at a live server instead.
//! - `scmii trace bench` sweeps replay at 1×/4×/16× and writes
//!   `BENCH_replay.json` (sustained frames/sec plus the scratch-arena
//!   hit rate; schema in `docs/BENCHMARKS.md`).
//!
//! ## Capture file format
//!
//! ```text
//! header:  "SCMT" | u32 version (LE, currently 1)
//! record:  u64 arrival_micros (LE) | u32 len (LE) | len framed wire bytes
//! ```
//!
//! The payload of each record is a complete wire frame exactly as
//! [`encode_frame`](crate::net::encode_frame) produces it (magic,
//! type, length, payload), so a capture can be replayed byte-for-byte
//! onto a TCP socket without re-encoding, and decoding reuses
//! [`read_msg`] unchanged.

use crate::cli::Args;
use crate::config::{IntegrationKind, ModelMeta, Paths};
use crate::net::{read_msg, Msg};
use crate::runtime::arena::ArenaStats;
use crate::utils::json::Json;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Capture-file magic ("SCMT" = SC-MII trace).
pub const TRACE_MAGIC: [u8; 4] = *b"SCMT";
/// Capture format version written after the magic.
pub const TRACE_VERSION: u32 = 1;
/// Upper bound on a single record's frame length — anything larger
/// means a corrupt or desynced capture, not a real intermediate output.
const MAX_RECORD_BYTES: usize = 256 << 20;

/// Appends timestamped wire frames to a capture file (see the module
/// docs for the format). The server holds one behind a mutex and tees
/// every decoded feature message into it.
pub struct TraceSink {
    w: BufWriter<File>,
    records: u64,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TraceSink {{ records: {} }}", self.records)
    }
}

impl TraceSink {
    /// Create (truncate) `path` — parent directories included — and
    /// write the capture header.
    pub fn create(path: &Path) -> Result<TraceSink> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create trace dir {}", parent.display()))?;
        }
        let file = File::create(path)
            .with_context(|| format!("create trace {}", path.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(&TRACE_MAGIC)?;
        w.write_all(&TRACE_VERSION.to_le_bytes())?;
        Ok(TraceSink { w, records: 0 })
    }

    /// Append one record: a complete wire frame plus its arrival stamp
    /// (µs since the Unix epoch, as stamped by the receiver).
    pub fn record(&mut self, arrival_micros: u64, frame: &[u8]) -> Result<()> {
        self.w.write_all(&arrival_micros.to_le_bytes())?;
        self.w.write_all(&(frame.len() as u32).to_le_bytes())?;
        self.w.write_all(frame)?;
        self.records += 1;
        Ok(())
    }

    /// Records written so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Flush buffered records to disk (called on server shutdown).
    pub fn flush(&mut self) -> Result<()> {
        self.w.flush().context("flush trace")
    }
}

/// One captured record: a framed wire message and when it arrived.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    /// Arrival stamp in µs since the Unix epoch.
    pub arrival_micros: u64,
    /// The complete framed wire bytes (magic through payload).
    pub frame: Vec<u8>,
}

impl TraceRecord {
    /// Decode the framed bytes back into a [`Msg`].
    pub fn decode(&self) -> Result<Msg> {
        read_msg(&mut &self.frame[..])
    }
}

/// Streaming reader over a capture file.
pub struct TraceSource {
    r: BufReader<File>,
}

impl TraceSource {
    /// Open `path` and validate the capture header.
    pub fn open(path: &Path) -> Result<TraceSource> {
        let file = File::open(path)
            .with_context(|| format!("open trace {}", path.display()))?;
        let mut r = BufReader::new(file);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)
            .with_context(|| format!("{}: truncated trace header", path.display()))?;
        anyhow::ensure!(
            magic == TRACE_MAGIC,
            "{}: not a trace capture (magic {:?})",
            path.display(),
            magic
        );
        let mut ver = [0u8; 4];
        r.read_exact(&mut ver)
            .with_context(|| format!("{}: truncated trace version", path.display()))?;
        let version = u32::from_le_bytes(ver);
        anyhow::ensure!(
            version == TRACE_VERSION,
            "{}: unsupported trace version {version} (have {TRACE_VERSION})",
            path.display()
        );
        Ok(TraceSource { r })
    }

    /// Read the next record; `Ok(None)` at a clean end of file. A file
    /// that ends mid-record is an error, not a silent short read.
    pub fn next_record(&mut self) -> Result<Option<TraceRecord>> {
        let mut head = [0u8; 12];
        let mut filled = 0;
        while filled < head.len() {
            let n = self.r.read(&mut head[filled..])?;
            if n == 0 {
                if filled == 0 {
                    return Ok(None);
                }
                bail!("truncated trace record header ({filled} of 12 bytes)");
            }
            filled += n;
        }
        let arrival_micros = u64::from_le_bytes(head[0..8].try_into().expect("8-byte stamp"));
        let len = u32::from_le_bytes(head[8..12].try_into().expect("4-byte length")) as usize;
        anyhow::ensure!(len <= MAX_RECORD_BYTES, "trace record of {len} bytes — corrupt capture");
        let mut frame = vec![0u8; len];
        self.r.read_exact(&mut frame).context("truncated trace record body")?;
        Ok(Some(TraceRecord { arrival_micros, frame }))
    }

    /// Read every record of the capture at `path` into memory.
    pub fn read_all(path: &Path) -> Result<Vec<TraceRecord>> {
        let mut src = TraceSource::open(path)?;
        let mut out = Vec::new();
        while let Some(rec) = src.next_record()? {
            out.push(rec);
        }
        Ok(out)
    }
}

/// How [`replay`] drives a capture into fresh sessions.
#[derive(Clone, Debug)]
pub struct ReplayConfig {
    /// Pacing multiplier over recorded arrival spacing (1.0 = as
    /// captured, 16.0 = sixteen times faster).
    pub speed: f64,
    /// Times the whole capture is replayed; every repeat must reproduce
    /// the first one's outcome exactly.
    pub repeats: usize,
    /// Integration method the replay sessions run.
    pub variant: IntegrationKind,
    /// Frame-sync deadline of the replay sessions.
    pub deadline: Duration,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            speed: 1.0,
            repeats: 1,
            variant: IntegrationKind::Max,
            deadline: Duration::from_millis(150),
        }
    }
}

/// Outcome of one replay sweep — a row of `BENCH_replay.json`.
#[derive(Clone, Debug)]
pub struct ReplayRow {
    /// Pacing multiplier the sweep ran at.
    pub speed: f64,
    /// Repeats executed (all reproduced the same outcome).
    pub repeats: usize,
    /// Records in the capture.
    pub records: usize,
    /// Frames completed per repeat, summed over sessions.
    pub frames_done: u64,
    /// Frames emitted with every device present (per repeat).
    pub sync_complete: u64,
    /// Frames resolved by deadline expiry (per repeat).
    pub sync_timed_out: u64,
    /// Wall-clock seconds spent replaying (submission through final
    /// poll, settle included, summed over repeats).
    pub wall_secs: f64,
    /// Sustained completed frames per second across all repeats.
    pub frames_per_sec: f64,
    /// Scratch-arena counters after the sweep (cumulative per backend).
    pub arena: ArenaStats,
}

impl ReplayRow {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "replay {:>4.1}x: {} records -> {} frames/repeat x{} in {:.3}s \
             ({:.1} frames/s, complete {}, timed_out {}, arena hit rate {:.2})",
            self.speed,
            self.records,
            self.frames_done,
            self.repeats,
            self.wall_secs,
            self.frames_per_sec,
            self.sync_complete,
            self.sync_timed_out,
            self.arena.hit_rate(),
        )
    }

    /// The `BENCH_replay.json` row (schema in `docs/BENCHMARKS.md`).
    pub fn to_json(&self, trace: &Path) -> Json {
        let mut j = Json::obj();
        j.set("op", Json::Str("trace_replay".into()))
            .set("trace", Json::Str(trace.display().to_string()))
            .set("speed", Json::Num(self.speed))
            .set("repeats", Json::Num(self.repeats as f64))
            .set("records", Json::Num(self.records as f64))
            .set("frames_done", Json::Num(self.frames_done as f64))
            .set("sync_complete", Json::Num(self.sync_complete as f64))
            .set("sync_timed_out", Json::Num(self.sync_timed_out as f64))
            .set("wall_secs", Json::Num(self.wall_secs))
            .set("frames_per_sec", Json::Num(self.frames_per_sec))
            .set("arena_hits", Json::Num(self.arena.hits as f64))
            .set("arena_misses", Json::Num(self.arena.misses as f64))
            .set("arena_hit_rate", Json::Num(self.arena.hit_rate()));
        j
    }
}

/// Replay a capture into fresh in-process sessions, `cfg.repeats` times
/// over, verifying every repeat reproduces repeat 0's outcome exactly
/// (frames done and the full synchronizer accounting, per session).
/// That check *is* the CI determinism gate — a divergence fails the
/// command. The execution backend (and its scratch arena) is shared
/// across repeats, so repeats after the first measure the warm path.
#[cfg(feature = "native")]
pub fn replay(paths: &Paths, trace_path: &Path, cfg: &ReplayConfig) -> Result<ReplayRow> {
    use crate::coordinator::scheduler::LossPolicy;
    use crate::coordinator::session::{DetectorSession, FeaturePayload, SessionConfig};
    use crate::runtime::native::NativeBackend;
    use crate::runtime::ExecBackend;
    use crate::sync::time::Instant;
    use crate::sync::Arc;

    anyhow::ensure!(
        cfg.speed > 0.0 && cfg.speed.is_finite(),
        "--speed must be a positive number"
    );
    anyhow::ensure!(cfg.repeats >= 1, "--repeat must be at least 1");
    let records = TraceSource::read_all(trace_path)?;
    anyhow::ensure!(!records.is_empty(), "trace {} holds no records", trace_path.display());

    // Decode everything up front so pacing measures the serving path,
    // not wire parsing, and so a corrupt capture fails before any
    // session sees a frame.
    let mut frames = Vec::with_capacity(records.len());
    let mut session_names: Vec<String> = Vec::new();
    for (i, r) in records.iter().enumerate() {
        let msg = r.decode().with_context(|| format!("decode trace record {i}"))?;
        match &msg {
            Msg::Features { session, .. } | Msg::FeaturesQ { session, .. } => {
                if !session_names.contains(session) {
                    session_names.push(session.clone());
                }
            }
            other => bail!("trace record {i} is not an intermediate output: {other:?}"),
        }
        frames.push((r.arrival_micros, msg));
    }
    let t0 = frames.iter().map(|(t, _)| *t).min().unwrap_or(0);

    let paths = crate::scenario::materialize_paths(paths, "trace_replay")?;
    let meta = ModelMeta::load(&paths.model_meta())?;
    // A typed backend handle (not `build_backend`'s `dyn` one) so the
    // arena counters stay reachable; sessions get a coerced clone.
    let backend = Arc::new(NativeBackend::from_paths(&paths, &meta)?);
    backend.load(&meta.variant(cfg.variant)?.tail)?;
    let exec: Arc<dyn ExecBackend> = Arc::clone(&backend) as Arc<dyn ExecBackend>;

    type Outcome = Vec<(String, u64, (u64, u64, u64, u64, u64))>;
    let mut outcomes: Vec<Outcome> = Vec::new();
    let mut wall_secs = 0.0;
    for rep in 0..cfg.repeats {
        // Fresh sessions every repeat (identical starting state); the
        // shared backend keeps its arena warm between repeats.
        let mut sessions: std::collections::BTreeMap<String, DetectorSession> =
            Default::default();
        for name in &session_names {
            let sc = SessionConfig::new(cfg.variant)
                .deadline(cfg.deadline)
                .policy(LossPolicy::ZeroFill);
            sessions.insert(
                name.clone(),
                DetectorSession::new(name, meta.clone(), Arc::clone(&exec), sc)?,
            );
        }
        let start = Instant::now();
        for (arrival, msg) in &frames {
            let due = Duration::from_micros(arrival - t0).div_f64(cfg.speed);
            let elapsed = start.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            let (session, frame_id, device_id, payload, capture) = match msg.clone() {
                Msg::Features { frame_id, device_id, tensor, session, capture_micros } => {
                    (session, frame_id, device_id, FeaturePayload::Raw(tensor), capture_micros)
                }
                Msg::FeaturesQ { frame_id, device_id, tensor, session, capture_micros } => (
                    session,
                    frame_id,
                    device_id,
                    FeaturePayload::Quantized(tensor),
                    capture_micros,
                ),
                _ => unreachable!("non-feature records rejected during decode"),
            };
            anyhow::ensure!(
                (device_id as usize) < meta.num_devices,
                "trace device {device_id} out of range ({} devices)",
                meta.num_devices
            );
            let sess = sessions.get(&session).expect("session created for every trace name");
            let metrics = sess.metrics();
            metrics.incr("trace_replayed", 1);
            if let Err(e) = sess.submit_at(frame_id, device_id as usize, payload, capture) {
                log::warn!("replay submit failed: {e:#}");
            }
        }
        // Settle past the sync deadline, then resolve stragglers: the
        // replay loop finishes well inside one deadline even at 1x, so
        // every incomplete frame expires here — at the same point every
        // repeat — rather than racing the submission loop.
        std::thread::sleep(cfg.deadline + Duration::from_millis(100));
        let mut outcome: Outcome = Vec::new();
        for (name, sess) in &sessions {
            let _ = sess.poll();
            let s = sess.sync_stats();
            outcome.push((
                name.clone(),
                sess.frames_done(),
                (s.complete, s.timed_out, s.dropped_frames, s.late_arrivals, s.duplicates),
            ));
        }
        wall_secs += start.elapsed().as_secs_f64();
        let arena = backend.arena_stats();
        for sess in sessions.values() {
            let metrics = sess.metrics();
            metrics.set("arena_hits", arena.hits);
            metrics.set("arena_misses", arena.misses);
        }
        if let Some(first) = outcomes.first() {
            anyhow::ensure!(
                *first == outcome,
                "replay repeat {rep} diverged from repeat 0:\n  {outcome:?}\nvs\n  {first:?}"
            );
        }
        outcomes.push(outcome);
    }

    let first = &outcomes[0];
    let frames_done: u64 = first.iter().map(|(_, f, _)| *f).sum();
    let sync_complete: u64 = first.iter().map(|(_, _, s)| s.0).sum();
    let sync_timed_out: u64 = first.iter().map(|(_, _, s)| s.1).sum();
    Ok(ReplayRow {
        speed: cfg.speed,
        repeats: cfg.repeats,
        records: records.len(),
        frames_done,
        sync_complete,
        sync_timed_out,
        wall_secs,
        frames_per_sec: if wall_secs > 0.0 {
            (frames_done * cfg.repeats as u64) as f64 / wall_secs
        } else {
            0.0
        },
        arena: backend.arena_stats(),
    })
}

/// Stub for builds without the native backend — in-process replay needs
/// an execution backend that exists without HLO artifacts.
#[cfg(not(feature = "native"))]
pub fn replay(_paths: &Paths, _trace_path: &Path, _cfg: &ReplayConfig) -> Result<ReplayRow> {
    bail!("`scmii trace replay` needs the native backend (build with `--features native`)")
}

/// Stream a capture's raw frames to a live server over TCP at `speed`×
/// recorded pace, `repeats` times over. No re-encoding: the recorded
/// framed bytes go on the wire verbatim, followed by one `Bye`. Returns
/// frames sent.
pub fn replay_over_tcp(
    trace_path: &Path,
    addr: &str,
    speed: f64,
    repeats: usize,
) -> Result<u64> {
    use crate::sync::time::Instant;

    anyhow::ensure!(speed > 0.0 && speed.is_finite(), "--speed must be a positive number");
    let records = TraceSource::read_all(trace_path)?;
    anyhow::ensure!(!records.is_empty(), "trace {} holds no records", trace_path.display());
    let t0 = records.iter().map(|r| r.arrival_micros).min().unwrap_or(0);
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true)?;
    let mut sent = 0u64;
    for _ in 0..repeats.max(1) {
        let start = Instant::now();
        for r in &records {
            let due = Duration::from_micros(r.arrival_micros - t0).div_f64(speed);
            let elapsed = start.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
            stream.write_all(&r.frame)?;
            sent += 1;
        }
    }
    crate::net::write_msg(&mut stream, &Msg::Bye)?;
    stream.flush()?;
    Ok(sent)
}

/// `scmii trace` CLI entry: `record`, `replay` or `bench`.
pub fn cmd_trace(args: &Args) -> Result<()> {
    match args.positional().first().map(String::as_str) {
        Some("record") => cmd_record(args),
        Some("replay") => cmd_replay(args),
        Some("bench") => cmd_bench(args),
        _ => bail!(
            "usage: scmii trace record [--name SCENARIO|--spec FILE] [--trace FILE]\n\
             \x20      scmii trace replay [--trace FILE] [--speed N] [--repeat R] \
             [--connect HOST:PORT]\n\
             \x20      scmii trace bench [--trace FILE] [--repeat R] [--out DIR]"
        ),
    }
}

fn paths_from(args: &Args) -> Paths {
    Paths::new(&args.str_or("artifacts", "artifacts"), &args.str_or("data", "data"))
}

/// `scmii trace record`: run a scenario with the server tee enabled,
/// leaving a replayable capture behind.
fn cmd_record(args: &Args) -> Result<()> {
    args.check_known(&["name", "spec", "trace", "artifacts", "data", "seed"])?;
    let trace_path = PathBuf::from(args.str_or("trace", "capture.scmt"));
    let mut spec = match args.str_opt("spec") {
        Some(path) => {
            let j = crate::utils::json::read_file(Path::new(path))?;
            crate::scenario::ScenarioSpec::from_json(&j)
                .with_context(|| format!("parse scenario {path}"))?
        }
        None => crate::scenario::ScenarioSpec::builtin(&args.str_or("name", "ci-smoke"))?,
    };
    spec.seed = args.u64_or("seed", spec.seed)?;
    spec.trace = Some(trace_path.clone());
    let report = crate::scenario::run_scenario(&paths_from(args), &spec)?;
    print!("{}", report.summary());
    // Hard-gate semantics: an empty capture means the tee is broken.
    let records = TraceSource::read_all(&trace_path)?;
    anyhow::ensure!(
        !records.is_empty(),
        "recorded trace {} holds no records — server tee broken",
        trace_path.display()
    );
    println!("recorded {} intermediate outputs -> {}", records.len(), trace_path.display());
    Ok(())
}

fn replay_config_from(args: &Args) -> Result<ReplayConfig> {
    Ok(ReplayConfig {
        speed: args.f64_or("speed", 1.0)?,
        repeats: args.usize_or("repeat", 1)?.max(1),
        variant: IntegrationKind::parse(&args.str_or("variant", "max"))?,
        deadline: args.ms_or("deadline-ms", 150)?,
    })
}

fn write_rows(out_dir: &Path, trace: &Path, rows: &[ReplayRow]) -> Result<()> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("create output dir {}", out_dir.display()))?;
    let out = out_dir.join("BENCH_replay.json");
    let json = Json::Arr(rows.iter().map(|r| r.to_json(trace)).collect());
    crate::utils::json::write_file(&out, &json)
        .with_context(|| format!("write {}", out.display()))?;
    println!("wrote {}", out.display());
    Ok(())
}

/// `scmii trace replay`: one in-process replay (or, with `--connect`,
/// a raw TCP replay against a live server).
fn cmd_replay(args: &Args) -> Result<()> {
    args.check_known(&[
        "trace",
        "speed",
        "repeat",
        "variant",
        "deadline-ms",
        "out",
        "artifacts",
        "data",
        "connect",
    ])?;
    let trace_path = PathBuf::from(args.str_or("trace", "capture.scmt"));
    let cfg = replay_config_from(args)?;
    if let Some(addr) = args.str_opt("connect") {
        let sent = replay_over_tcp(&trace_path, addr, cfg.speed, cfg.repeats)?;
        println!("replayed {sent} frames to {addr} at {}x", cfg.speed);
        return Ok(());
    }
    let row = replay(&paths_from(args), &trace_path, &cfg)?;
    println!("{}", row.summary());
    write_rows(Path::new(&args.str_or("out", ".")), &trace_path, &[row])
}

/// `scmii trace bench`: replay the capture at 1×/4×/16× and write every
/// row to `BENCH_replay.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    args.check_known(&[
        "trace",
        "repeat",
        "variant",
        "deadline-ms",
        "out",
        "artifacts",
        "data",
    ])?;
    let trace_path = PathBuf::from(args.str_or("trace", "capture.scmt"));
    let base = replay_config_from(args)?;
    let paths = paths_from(args);
    let mut rows = Vec::new();
    for speed in [1.0, 4.0, 16.0] {
        let row = replay(&paths, &trace_path, &ReplayConfig { speed, ..base.clone() })?;
        println!("{}", row.summary());
        rows.push(row);
    }
    write_rows(Path::new(&args.str_or("out", ".")), &trace_path, &rows)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::net::encode_frame;
    use crate::runtime::HostTensor;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("scmii_trace_{}_{}", name, std::process::id()))
    }

    fn feature_frame(frame_id: u64, device_id: u32) -> Vec<u8> {
        encode_frame(&Msg::Features {
            frame_id,
            device_id,
            tensor: HostTensor::zeros(&[1, 2, 2, 3]),
            session: "north".into(),
            capture_micros: 7,
        })
        .unwrap()
    }

    #[test]
    fn round_trips_records() {
        let path = tmp("roundtrip");
        let mut sink = TraceSink::create(&path).unwrap();
        for i in 0..5u64 {
            sink.record(1000 + i, &feature_frame(i, (i % 2) as u32)).unwrap();
        }
        assert_eq!(sink.records(), 5);
        sink.flush().unwrap();
        drop(sink);

        let records = TraceSource::read_all(&path).unwrap();
        assert_eq!(records.len(), 5);
        for (i, r) in records.iter().enumerate() {
            assert_eq!(r.arrival_micros, 1000 + i as u64);
            match r.decode().unwrap() {
                Msg::Features { frame_id, session, capture_micros, .. } => {
                    assert_eq!(frame_id, i as u64);
                    assert_eq!(session, "north");
                    assert_eq!(capture_micros, 7);
                }
                other => panic!("decoded {other:?}"),
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_capture_is_valid_and_empty() {
        let path = tmp("empty");
        TraceSink::create(&path).unwrap().flush().unwrap();
        assert!(TraceSource::read_all(&path).unwrap().is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(TraceSource::open(&path).is_err());
        let mut bytes = TRACE_MAGIC.to_vec();
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = TraceSource::open(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "{err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_record_errors_not_silently_ends() {
        let path = tmp("truncated");
        let mut sink = TraceSink::create(&path).unwrap();
        sink.record(1, &feature_frame(0, 0)).unwrap();
        sink.flush().unwrap();
        drop(sink);
        let full = std::fs::read(&path).unwrap();
        // Cut the last record short: header survives, body does not.
        std::fs::write(&path, &full[..full.len() - 4]).unwrap();
        let mut src = TraceSource::open(&path).unwrap();
        assert!(src.next_record().is_err());
        // Cut into the 12-byte record header itself.
        std::fs::write(&path, &full[..TRACE_MAGIC.len() + 4 + 6]).unwrap();
        let mut src = TraceSource::open(&path).unwrap();
        assert!(src.next_record().is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_config_parses_flags() {
        let args = Args::parse(
            ["--speed", "16", "--repeat", "4", "--variant", "max", "--deadline-ms", "90"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = replay_config_from(&args).unwrap();
        assert_eq!(cfg.speed, 16.0);
        assert_eq!(cfg.repeats, 4);
        assert_eq!(cfg.variant, IntegrationKind::Max);
        assert_eq!(cfg.deadline, Duration::from_millis(90));
        // Defaults.
        let cfg =
            replay_config_from(&Args::parse(std::iter::empty::<String>()).unwrap()).unwrap();
        assert_eq!(cfg.speed, 1.0);
        assert_eq!(cfg.repeats, 1);
    }

    #[test]
    fn replay_row_json_has_schema_keys() {
        let row = ReplayRow {
            speed: 4.0,
            repeats: 2,
            records: 20,
            frames_done: 12,
            sync_complete: 8,
            sync_timed_out: 4,
            wall_secs: 0.5,
            frames_per_sec: 48.0,
            arena: ArenaStats { hits: 30, misses: 6 },
        };
        let j = row.to_json(Path::new("cap.scmt"));
        assert_eq!(j.req("op").unwrap().as_str().unwrap(), "trace_replay");
        assert_eq!(j.req("records").unwrap().as_usize().unwrap(), 20);
        assert_eq!(j.req("frames_done").unwrap().as_usize().unwrap(), 12);
        assert!((j.req("arena_hit_rate").unwrap().as_f64().unwrap() - 30.0 / 36.0).abs() < 1e-12);
        assert!(j.req("frames_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.summary().contains("20 records"));
    }
}
