//! NDT scan matching (paper §II-C, setup phase §III-B.1).
//!
//! Implements the Normal Distributions Transform of Biber & Straßer: the
//! reference cloud is modelled as per-voxel Gaussians; a source cloud is
//! registered by maximizing the sum of Gaussian likelihoods of its
//! transformed points over SE(3). Optimization is multi-resolution
//! (coarse→fine cell sizes) gradient ascent with backtracking line search
//! and a yaw-sweep multi-start for global initialization (infrastructure
//! installs can differ by arbitrary yaw; real deployments seed this from
//! a survey — the sweep plays that role here).

mod map;
mod register;

pub use map::{GaussianCell, NdtMap};
pub use register::{calibrate, register, score_pose, NdtParams, NdtResult};
