//! SE(3) registration against an NDT map.
//!
//! Maximizes `Σ_p exp(-½ (Tp-μ)ᵀΣ⁻¹(Tp-μ))` over the 6 pose parameters
//! (translation + ZYX euler) with numerically-differentiated gradient
//! ascent + backtracking line search, run coarse-to-fine. A yaw-sweep
//! multi-start provides the global initialization (DESIGN.md §4).

use super::map::NdtMap;
use crate::geom::{Pose, Vec3};
use crate::voxel::Point;

/// Tunables for registration.
#[derive(Clone, Debug)]
pub struct NdtParams {
    /// Coarse-to-fine NDT cell sizes, metres.
    pub resolutions: Vec<f64>,
    /// Source-cloud subsample size per stage (objective cost control).
    pub max_source_points: usize,
    pub max_iters: usize,
    /// Stop when the parameter step norm falls below this.
    pub tol: f64,
    /// Number of yaw hypotheses in the global-init sweep.
    pub yaw_starts: usize,
}

impl Default for NdtParams {
    fn default() -> Self {
        // Finest resolution stays at 2 m: LiDAR-density clouds keep
        // ≥ MIN_POINTS per 2 m cell; 1 m cells go sparse and destabilize
        // the fine stage. Half-voxel (0.4 m) residual error is below the
        // detector's 0.8 m grid resolution.
        NdtParams {
            resolutions: vec![4.0, 2.0],
            max_source_points: 3000,
            max_iters: 60,
            tol: 1e-5,
            yaw_starts: 32,
        }
    }
}

/// Outcome of a registration.
#[derive(Clone, Debug)]
pub struct NdtResult {
    pub pose: Pose,
    /// Final normalized score (mean per-point likelihood, 0..~7).
    pub score: f64,
    pub iterations: usize,
}

/// 6-parameter pose vector: [tx, ty, tz, roll, pitch, yaw].
fn pose_from_params(x: &[f64; 6]) -> Pose {
    Pose::from_xyz_rpy(x[0], x[1], x[2], x[3], x[4], x[5])
}

fn score(map: &NdtMap, src: &[Vec3], x: &[f64; 6]) -> f64 {
    let pose = pose_from_params(x);
    let mut s = 0.0;
    for &p in src {
        s += map.point_score(pose.apply(p));
    }
    s / src.len() as f64
}

fn numerical_gradient(map: &NdtMap, src: &[Vec3], x: &[f64; 6]) -> [f64; 6] {
    let mut g = [0.0; 6];
    for i in 0..6 {
        let h = if i < 3 { 1e-3 } else { 1e-4 };
        let mut xp = *x;
        let mut xm = *x;
        xp[i] += h;
        xm[i] -= h;
        g[i] = (score(map, src, &xp) - score(map, src, &xm)) / (2.0 * h);
    }
    g
}

/// Gradient-ascent refinement with backtracking line search from `init`
/// at one resolution (rotations move on a smaller scale than
/// translations; the coordinate polish afterwards handles the residual
/// coupled yaw↔translation valley).
fn refine(map: &NdtMap, src: &[Vec3], init: [f64; 6], params: &NdtParams) -> ([f64; 6], f64, usize) {
    let mut x = init;
    let mut current = score(map, src, &x);
    let mut iters = 0;
    for _ in 0..params.max_iters {
        iters += 1;
        let g = numerical_gradient(map, src, &x);
        let gnorm = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        if gnorm < 1e-9 {
            break;
        }
        // Backtracking line search along the gradient, translation-scaled.
        let mut step = map.cell_size; // start ambitious: one cell
        let mut improved = false;
        while step > params.tol {
            let mut xn = x;
            for i in 0..6 {
                // rotations get a smaller scale than translations
                let scale = if i < 3 { 1.0 } else { 0.25 };
                xn[i] += step * scale * g[i] / gnorm;
            }
            let s = score(map, src, &xn);
            if s > current + 1e-12 {
                x = xn;
                current = s;
                improved = true;
                break;
            }
            step *= 0.5;
        }
        if !improved {
            break;
        }
    }
    (x, current, iters)
}

/// Coordinate-wise golden-section polish at the finest resolution:
/// gradient ascent on the smoothed NDT objective stalls near flat ridges;
/// optimizing one parameter at a time with a bracketed search reliably
/// centers the estimate within a fraction of a cell.
fn coordinate_polish(
    map: &NdtMap,
    src: &[Vec3],
    mut x: [f64; 6],
    rounds: usize,
    param_mask: &[bool; 6],
) -> ([f64; 6], f64) {
    let spans = [
        map.cell_size * 0.6,
        map.cell_size * 0.6,
        map.cell_size * 0.6,
        0.06,
        0.06,
        0.12,
    ];
    // Source centroid: yaw is searched as a rotation about the *cloud*,
    // not the origin — otherwise every yaw trial drags the far-away cloud
    // sideways (lever arm ≈ |centroid|), coupling the axes so strongly
    // that per-axis search cannot move.
    let centroid = {
        let mut c = Vec3::ZERO;
        for &p in src {
            c += p;
        }
        c / src.len().max(1) as f64
    };
    let mut best = score(map, src, &x);
    for _ in 0..rounds {
        for i in (0..6).filter(|&i| param_mask[i]) {
            let (mut lo, mut hi) = (x[i] - spans[i], x[i] + spans[i]);
            // Golden-section maximization on parameter i.
            let phi = 0.618_033_988_749_895;
            let mut a = hi - phi * (hi - lo);
            let mut b = lo + phi * (hi - lo);
            let candidate = |x: &[f64; 6], v: f64| {
                let mut xt = *x;
                xt[i] = v;
                if i == 5 {
                    // pivot the yaw change about the transformed centroid
                    let pose0 = pose_from_params(x);
                    let pivot = pose0.apply(centroid);
                    let rot_new = crate::geom::Mat3::from_euler(xt[3], xt[4], v);
                    let t_new = pivot - rot_new.apply(centroid);
                    xt[0] = t_new.x;
                    xt[1] = t_new.y;
                    xt[2] = t_new.z;
                }
                xt
            };
            let eval =
                |map: &NdtMap, x: &[f64; 6], _i: usize, v: f64| score(map, src, &candidate(x, v));
            let mut fa = eval(map, &x, i, a);
            let mut fb = eval(map, &x, i, b);
            for _ in 0..14 {
                if fa > fb {
                    hi = b;
                    b = a;
                    fb = fa;
                    a = hi - phi * (hi - lo);
                    fa = eval(map, &x, i, a);
                } else {
                    lo = a;
                    a = b;
                    fa = fb;
                    b = lo + phi * (hi - lo);
                    fb = eval(map, &x, i, b);
                }
            }
            let v = (lo + hi) / 2.0;
            let fv = eval(map, &x, i, v);
            if fv > best {
                x = candidate(&x, v);
                best = fv;
            }
        }
    }
    (x, best)
}

/// Register `source` onto `target` starting from `init`.
pub fn register(
    target: &[Point],
    source: &[Point],
    init: Pose,
    params: &NdtParams,
) -> NdtResult {
    let (roll0, pitch0, yaw0) = init.rot.to_euler();
    let mut x = [init.trans.x, init.trans.y, init.trans.z, roll0, pitch0, yaw0];
    let src_full = subsample(source, params.max_source_points);
    let mut total_iters = 0;
    let mut final_score = 0.0;
    let mut finest: Option<NdtMap> = None;
    for &res in &params.resolutions {
        let map = NdtMap::build(target, res);
        let (xr, s, it) = refine(&map, &src_full, x, params);
        x = xr;
        final_score = s;
        total_iters += it;
        finest = Some(map);
    }
    if let Some(map) = finest {
        // Alternate coordinate polish and gradient ascent: the polish
        // escapes the coupled yaw↔translation valley one axis at a time,
        // after which the gradient makes progress again.
        for _ in 0..3 {
            let (xp, sp) = coordinate_polish(&map, &src_full, x, 2, &[true; 6]);
            let improved = sp > final_score + 1e-9;
            x = xp;
            final_score = sp;
            let (xr, sr, it) = refine(&map, &src_full, x, params);
            total_iters += it;
            if sr > final_score {
                x = xr;
                final_score = sr;
            } else if !improved {
                break;
            }
        }
    }
    NdtResult { pose: pose_from_params(&x), score: final_score, iterations: total_iters }
}

/// Full setup-phase calibration with yaw-sweep global init: registers
/// `source` (sensor i local frame) onto `target` (reference sensor local
/// frame), returning the estimated rigid transform source→target.
///
/// Real cross-sensor scans overlap only partially (each sensor is dense
/// near its own pole), so: clouds are cropped to a working radius to
/// balance the overlap region, every yaw hypothesis is seeded from the
/// cropped centroids, and the best few hypotheses get the full
/// coarse-to-fine refinement.
pub fn calibrate(target: &[Point], source: &[Point], params: &NdtParams) -> NdtResult {
    const CROP_RADIUS: f64 = 55.0;
    let target = crop(target, CROP_RADIUS);
    let source = crop(source, CROP_RADIUS);

    // Yaw disambiguation runs on *structure* points only: the ground
    // plane carries no yaw information yet dominates the raw score, which
    // lets near-symmetric wrong fits (an intersection looks similar under
    // 180°) outrank the true one. Walls/buildings break the symmetry.
    let tgt_struct = above_ground(&target);
    let src_struct = above_ground(&source);

    // Coarse structure map once; scan the (yaw × translation) grid.
    let coarse_res = params.resolutions.first().copied().unwrap_or(4.0);
    let coarse_map = NdtMap::build(&tgt_struct, coarse_res);
    let src_tiny = subsample(&src_struct, 400);
    let src_sub = subsample(&src_struct, params.max_source_points.min(1500));

    // z seed: difference of ground heights (30th z-percentile).
    let z0 = z_percentile(&target, 0.3) - z_percentile(&source, 0.3);

    // Global init: exhaustive coarse scoring over yaw × (tx, ty). Centroid
    // seeding fails here because each sensor's cloud is densest around its
    // own pole, biasing the centroids in frame-dependent ways.
    // For each yaw hypothesis keep its best translation seed — this
    // guarantees every yaw gets a refinement chance even when another
    // (wrong) yaw dominates the raw coarse scores.
    let t_range = 27.0;
    let t_step = coarse_res * 1.5;
    let steps = (2.0 * t_range / t_step) as i64 + 1;
    let mut per_yaw_seeds: Vec<[f64; 6]> = Vec::new();
    for k in 0..params.yaw_starts {
        let yaw = k as f64 / params.yaw_starts as f64 * std::f64::consts::TAU;
        let mut best_seed = [0.0, 0.0, z0, 0.0, 0.0, yaw];
        let mut best_s = f64::NEG_INFINITY;
        for i in 0..steps {
            for j in 0..steps {
                let tx = -t_range + i as f64 * t_step;
                let ty = -t_range + j as f64 * t_step;
                let x0 = [tx, ty, z0, 0.0, 0.0, yaw];
                let s = score(&coarse_map, &src_tiny, &x0);
                if s > best_s {
                    best_s = s;
                    best_seed = x0;
                }
            }
        }
        per_yaw_seeds.push(best_seed);
    }

    // Quick coarse refinement of every yaw's champion, then rank.
    let mut hypotheses: Vec<([f64; 6], f64)> = Vec::new();
    let quick = NdtParams { max_iters: 25, ..params.clone() };
    for x0 in per_yaw_seeds {
        let (x, s, _) = refine(&coarse_map, &src_sub, x0, &quick);
        hypotheses.push((x, s));
    }
    hypotheses.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut best: Option<NdtResult> = None;
    for (x0, _) in hypotheses.iter().take(5) {
        // Full coarse-to-fine on structure (yaw/xy), then a final pass on
        // the full clouds so the ground plane pins z precisely.
        let r_struct =
            register(&tgt_struct, &src_struct, pose_from_params(x0), params);
        let r = register(&target, &source, r_struct.pose, params);
        if best.as_ref().map(|b| r.score > b.score).unwrap_or(true) {
            best = Some(r);
        }
    }
    let best = best.expect("yaw sweep produced no hypothesis");

    // Sub-voxel polish, split by what constrains each DoF:
    // - x/y/yaw (+roll/pitch) on a finer *structure* map — walls pin the
    //   horizontal DoFs without the ground plane's density-imbalance bias
    //   (each cloud is densest around its own pole, dragging translation);
    // - z on the full cloud — only the ground plane pins height, which
    //   the structure-only view leaves nearly unconstrained.
    let fine_struct = NdtMap::build(&tgt_struct, 1.2);
    let src_fine = subsample(&src_struct, params.max_source_points);
    let (roll, pitch, yaw) = best.pose.rot.to_euler();
    let x = [best.pose.trans.x, best.pose.trans.y, best.pose.trans.z, roll, pitch, yaw];
    let (x, _) = coordinate_polish(
        &fine_struct,
        &src_fine,
        x,
        3,
        &[true, true, false, true, true, true],
    );
    let full_map = NdtMap::build(&target, 2.0);
    let src_full = subsample(&source, params.max_source_points);
    let (x, s) = coordinate_polish(
        &full_map,
        &src_full,
        x,
        2,
        &[false, false, true, false, false, false],
    );
    NdtResult {
        pose: pose_from_params(&x),
        score: s,
        iterations: best.iterations,
    }
}

fn crop(points: &[Point], radius: f64) -> Vec<Point> {
    points
        .iter()
        .filter(|p| {
            !p.is_pad()
                && ((p.x as f64).powi(2) + (p.y as f64).powi(2)).sqrt() < radius
        })
        .copied()
        .collect()
}

fn z_percentile(points: &[Point], q: f64) -> f64 {
    let mut zs: Vec<f32> = points.iter().filter(|p| !p.is_pad()).map(|p| p.z).collect();
    if zs.is_empty() {
        return 0.0;
    }
    zs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((zs.len() as f64 * q) as usize).min(zs.len() - 1);
    zs[idx] as f64
}

/// Drop the dominant ground plane: estimate its height as the 30th
/// z-percentile and keep points well above it.
fn above_ground(points: &[Point]) -> Vec<Point> {
    let ground = z_percentile(points, 0.3) as f32;
    if points.is_empty() {
        return Vec::new();
    }
    points
        .iter()
        .filter(|p| !p.is_pad() && p.z > ground + 0.7)
        .copied()
        .collect()
}

/// Score an arbitrary pose against a target cloud (diagnostics: lets the
/// setup CLI and tests compare the estimate's basin against the truth's).
pub fn score_pose(target: &[Point], source: &[Point], pose: &Pose, resolution: f64) -> f64 {
    let map = NdtMap::build(target, resolution);
    let src = subsample(source, 3000);
    let (roll, pitch, yaw) = pose.rot.to_euler();
    score(&map, &src, &[pose.trans.x, pose.trans.y, pose.trans.z, roll, pitch, yaw])
}

fn centroid(points: &[Point]) -> Vec3 {
    let mut sum = Vec3::ZERO;
    let mut n = 0;
    for p in points {
        if !p.is_pad() {
            sum += Vec3::new(p.x as f64, p.y as f64, p.z as f64);
            n += 1;
        }
    }
    if n == 0 {
        Vec3::ZERO
    } else {
        sum / n as f64
    }
}

fn subsample(points: &[Point], n: usize) -> Vec<Vec3> {
    let valid: Vec<Vec3> = points
        .iter()
        .filter(|p| !p.is_pad())
        .map(|p| Vec3::new(p.x as f64, p.y as f64, p.z as f64))
        .collect();
    if valid.len() <= n {
        return valid;
    }
    // Deterministic stride subsample (stable across runs).
    let stride = valid.len() as f64 / n as f64;
    (0..n).map(|i| valid[(i as f64 * stride) as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Pcg64;

    /// A structured cloud: ground plane patch, two walls of *different*
    /// heights, and two boxes at distinct locations — asymmetric enough
    /// to constrain all 6 DoF uniquely (two uniform perpendicular walls
    /// alone alias under many relative placements, which is also why the
    /// simulator's intersection corners are deliberately asymmetric).
    fn structured_cloud(seed: u64, n: usize) -> Vec<Point> {
        let mut rng = Pcg64::new(seed);
        let mut pts = Vec::with_capacity(n);
        for _ in 0..n {
            let which = rng.below(5);
            let (x, y, z) = match which {
                0 => (rng.range(-15.0, 15.0), rng.range(-15.0, 15.0), 0.0),
                1 => (rng.range(-15.0, 15.0), 10.0, rng.range(0.0, 6.0)),
                2 => (-12.0, rng.range(-15.0, 15.0), rng.range(0.0, 3.5)),
                3 => {
                    // tall box at (5, -5)
                    let face = rng.below(2);
                    if face == 0 {
                        (rng.range(3.0, 7.0), -5.0, rng.range(0.0, 4.5))
                    } else {
                        (5.0, rng.range(-7.0, -3.0), rng.range(0.0, 4.5))
                    }
                }
                _ => {
                    // low kiosk at (-8, 3)
                    let face = rng.below(2);
                    if face == 0 {
                        (rng.range(-9.5, -6.5), 3.0, rng.range(0.0, 2.0))
                    } else {
                        (-8.0, rng.range(1.5, 4.5), rng.range(0.0, 2.0))
                    }
                }
            };
            pts.push(Point::new(
                (x + rng.gauss(0.0, 0.02)) as f32,
                (y + rng.gauss(0.0, 0.02)) as f32,
                (z + rng.gauss(0.0, 0.02)) as f32,
                0.5,
            ));
        }
        pts
    }

    fn transform_cloud(pts: &[Point], pose: &Pose) -> Vec<Point> {
        pts.iter()
            .map(|p| {
                let v = pose.apply(Vec3::new(p.x as f64, p.y as f64, p.z as f64));
                Point::new(v.x as f32, v.y as f32, v.z as f32, p.intensity)
            })
            .collect()
    }

    #[cfg_attr(debug_assertions, ignore = "numerical-gradient NDT is release-speed only; run with --release (make test)")]
    #[test]
    fn register_recovers_small_offset() {
        let target = structured_cloud(1, 12000);
        let true_pose = Pose::from_xyz_rpy(0.8, -0.5, 0.1, 0.0, 0.0, 0.08);
        // source = target viewed from a frame offset by true_pose⁻¹,
        // i.e. applying true_pose to source points reproduces the target.
        let source = transform_cloud(&target, &true_pose.inverse());
        let result =
            register(&target, &source, Pose::IDENTITY, &NdtParams::default());
        let (ang, trans) = result.pose.error_to(&true_pose);
        assert!(trans < 0.25, "translation error {trans}");
        assert!(ang < 0.03, "rotation error {ang}");
    }

    #[cfg_attr(debug_assertions, ignore = "numerical-gradient NDT is release-speed only; run with --release (make test)")]
    #[test]
    fn register_recovers_from_perturbed_init() {
        // Local convergence: init off by 3 m / 0.25 rad must snap back.
        let target = structured_cloud(2, 12000);
        let true_pose = Pose::from_xyz_rpy(12.0, -7.0, 0.6, 0.0, 0.0, 2.4);
        let source = transform_cloud(&target, &true_pose.inverse());
        let init = Pose::from_xyz_rpy(14.2, -5.2, 0.3, 0.0, 0.0, 2.65);
        let result = register(&target, &source, init, &NdtParams::default());
        let (ang, trans) = result.pose.error_to(&true_pose);
        assert!(
            trans < 0.6 && ang < 0.06,
            "error: trans {trans} m, rot {ang} rad; est ({:.2},{:.2},{:.2}) vs truth ({:.2},{:.2},{:.2})",
            result.pose.trans.x,
            result.pose.trans.y,
            result.pose.trans.z,
            true_pose.trans.x,
            true_pose.trans.y,
            true_pose.trans.z
        );
    }

    #[cfg_attr(debug_assertions, ignore = "numerical-gradient NDT is release-speed only; run with --release (make test)")]
    #[test]
    fn calibrate_finds_truth_quality_fit() {
        // Global search on a *minimal* synthetic scene (one ground patch,
        // two walls, two boxes). Such scenes can admit near-symmetric
        // aliases, so the assertion is fit QUALITY: the chosen pose must
        // score at least as well as the ground-truth pose. True-pose
        // recovery on a realistic scene is asserted by the
        // `ndt_calibration_recovers_rig_extrinsics` integration test.
        let target = structured_cloud(2, 12000);
        let true_pose = Pose::from_xyz_rpy(12.0, -7.0, 0.6, 0.0, 0.0, 2.4);
        let source = transform_cloud(&target, &true_pose.inverse());
        let result = calibrate(&target, &source, &NdtParams::default());
        let s_est = score_pose(&target, &source, &result.pose, 2.0);
        let s_truth = score_pose(&target, &source, &true_pose, 2.0);
        assert!(
            s_est > 0.9 * s_truth,
            "calibrate fit quality {s_est:.4} below truth {s_truth:.4}"
        );
    }

    #[cfg_attr(debug_assertions, ignore = "numerical-gradient NDT is release-speed only; run with --release (make test)")]
    #[test]
    fn identity_registration_is_stable() {
        let target = structured_cloud(3, 8000);
        let result = register(&target, &target, Pose::IDENTITY, &NdtParams::default());
        let (ang, trans) = result.pose.error_to(&Pose::IDENTITY);
        assert!(trans < 0.1, "drift {trans}");
        assert!(ang < 0.01, "rotation drift {ang}");
        assert!(result.score > 0.3, "score {}", result.score);
    }
}
