//! NDT voxel map: per-cell Gaussian statistics of the reference cloud.

use crate::geom::{Mat3, Vec3};
use crate::voxel::Point;
use std::collections::HashMap;

/// Gaussian model of one NDT cell.
#[derive(Clone, Debug)]
pub struct GaussianCell {
    pub mean: Vec3,
    /// Inverse covariance (regularized).
    pub cov_inv: Mat3,
    pub n: usize,
}

/// Sparse voxel map of Gaussians at one resolution.
pub struct NdtMap {
    pub cell_size: f64,
    cells: HashMap<(i32, i32, i32), GaussianCell>,
}

/// Minimum points for a cell to contribute a Gaussian.
const MIN_POINTS: usize = 5;

impl NdtMap {
    /// Build the map from the reference cloud.
    pub fn build(points: &[Point], cell_size: f64) -> NdtMap {
        let mut acc: HashMap<(i32, i32, i32), (Vec3, usize)> = HashMap::new();
        let key = |p: &Point| {
            (
                (p.x as f64 / cell_size).floor() as i32,
                (p.y as f64 / cell_size).floor() as i32,
                (p.z as f64 / cell_size).floor() as i32,
            )
        };
        for p in points {
            if p.is_pad() {
                continue;
            }
            let e = acc.entry(key(p)).or_insert((Vec3::ZERO, 0));
            e.0 += Vec3::new(p.x as f64, p.y as f64, p.z as f64);
            e.1 += 1;
        }
        // Second pass: covariance around the mean.
        let mut cov_acc: HashMap<(i32, i32, i32), [[f64; 3]; 3]> = HashMap::new();
        for p in points {
            if p.is_pad() {
                continue;
            }
            let k = key(p);
            let Some(&(sum, n)) = acc.get(&k) else { continue };
            if n < MIN_POINTS {
                continue;
            }
            let mean = sum / n as f64;
            let d = Vec3::new(p.x as f64, p.y as f64, p.z as f64) - mean;
            let m = cov_acc.entry(k).or_insert([[0.0; 3]; 3]);
            let dv = [d.x, d.y, d.z];
            for i in 0..3 {
                for j in 0..3 {
                    m[i][j] += dv[i] * dv[j];
                }
            }
        }
        let mut cells = HashMap::new();
        for (k, cov_sum) in cov_acc {
            let (sum, n) = acc[&k];
            let mean = sum / n as f64;
            let mut cov = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    cov[i][j] = cov_sum[i][j] / (n as f64 - 1.0);
                }
            }
            // Regularize: planar cells (walls/ground) have a near-zero
            // eigenvalue. A fixed tiny epsilon keeps the thin direction so
            // sharp that a decimetre offset already scores zero, flattening
            // the optimization basin (this is why classic NDT clamps
            // eigenvalue ratios). Inflate the diagonal proportionally to
            // the cell's mean variance instead.
            let mean_var = (cov[0][0] + cov[1][1] + cov[2][2]) / 3.0;
            let eps = (0.05 * mean_var).max(1e-3);
            for (i, row) in cov.iter_mut().enumerate() {
                row[i] += eps;
            }
            let cov_m = Mat3 { m: cov };
            if cov_m.det().abs() < 1e-12 {
                continue;
            }
            cells.insert(k, GaussianCell { mean, cov_inv: cov_m.inverse(), n });
        }
        NdtMap { cell_size, cells }
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    /// Gaussian score contribution of a world point: the likelihood under
    /// the Gaussian of its own cell plus face-adjacent cells (smooths the
    /// objective across cell boundaries).
    pub fn point_score(&self, p: Vec3) -> f64 {
        let kx = (p.x / self.cell_size).floor() as i32;
        let ky = (p.y / self.cell_size).floor() as i32;
        let kz = (p.z / self.cell_size).floor() as i32;
        let mut score = 0.0;
        const NB: [(i32, i32, i32); 7] =
            [(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1)];
        for (dx, dy, dz) in NB {
            if let Some(cell) = self.cells.get(&(kx + dx, ky + dy, kz + dz)) {
                let d = p - cell.mean;
                let md = d.dot(cell.cov_inv.apply(d));
                if md < 50.0 {
                    score += (-0.5 * md).exp();
                }
            }
        }
        score
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Pcg64;

    fn plane_cloud(n: usize, seed: u64) -> Vec<Point> {
        // points on z = 0 plane with small noise
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|_| {
                Point::new(
                    rng.range(-10.0, 10.0) as f32,
                    rng.range(-10.0, 10.0) as f32,
                    rng.gauss(0.0, 0.02) as f32,
                    0.5,
                )
            })
            .collect()
    }

    #[test]
    fn builds_cells_for_dense_cloud() {
        let cloud = plane_cloud(5000, 1);
        let map = NdtMap::build(&cloud, 2.0);
        assert!(map.n_cells() >= 80, "{}", map.n_cells());
    }

    #[test]
    fn score_peaks_on_surface() {
        let cloud = plane_cloud(5000, 2);
        let map = NdtMap::build(&cloud, 2.0);
        let on = map.point_score(Vec3::new(1.0, 1.0, 0.0));
        let off = map.point_score(Vec3::new(1.0, 1.0, 1.5));
        assert!(on > off * 2.0, "on={on} off={off}");
    }

    #[test]
    fn sparse_cells_are_skipped() {
        // 3 points in isolation: below MIN_POINTS, no cell
        let cloud = vec![
            Point::new(100.0, 100.0, 0.0, 0.0),
            Point::new(100.1, 100.0, 0.0, 0.0),
            Point::new(100.0, 100.1, 0.0, 0.0),
        ];
        let map = NdtMap::build(&cloud, 2.0);
        assert_eq!(map.n_cells(), 0);
    }

    #[test]
    fn pads_ignored() {
        let mut cloud = plane_cloud(1000, 3);
        let n_before = NdtMap::build(&cloud, 2.0).n_cells();
        cloud.extend(std::iter::repeat(Point::pad()).take(500));
        let n_after = NdtMap::build(&cloud, 2.0).n_cells();
        assert_eq!(n_before, n_after);
    }
}
