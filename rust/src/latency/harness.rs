//! Fig-5 harness: execution-time comparison of SC-MII variants against
//! the edge-only input-integration baseline.
//!
//! Measurement is separated from modeling: the expensive part (running
//! every variant's HLO over validation frames) happens once in
//! [`measure_raw`]; any number of testbed configurations (bandwidth
//! sweeps, device-factor ablations) are then modeled from the same
//! measurements.
//!
//! Timings are taken through the `DetectorSession` serving core (the
//! pipeline frontend drives it synchronously), so the tail/post numbers
//! modeled here come from the same code path that serves TCP traffic —
//! not from a parallel reimplementation.

use crate::cli::Args;
use crate::config::{IntegrationKind, LatencyConfig, Paths};
use crate::coordinator::pipeline::{FrameTiming, PipelineBackend, ScMiiPipeline};
use crate::latency::TestbedModel;
use crate::utils::bench::print_table;
use crate::utils::stats;
use anyhow::Result;

/// Raw per-frame measurements on this machine.
#[derive(Clone, Debug)]
pub struct RawTimings {
    /// Per SC-MII variant: per-frame pipeline timing breakdowns.
    pub scmii: Vec<(IntegrationKind, Vec<FrameTiming>)>,
    /// Edge-only baseline: per-frame full-model seconds.
    pub edge_full_secs: Vec<f64>,
    /// Raw-cloud bytes the edge-only baseline pulls from remote sensors.
    pub remote_raw_bytes: usize,
    pub n_devices: usize,
}

/// Measured + modeled numbers for one method.
#[derive(Clone, Debug)]
pub struct MethodTiming {
    pub name: String,
    /// Modeled end-to-end inference times per frame (seconds).
    pub inference: Vec<f64>,
    /// Modeled per-device edge execution time per frame.
    pub edge_per_device: Vec<Vec<f64>>,
    /// Modeled per-device steady-state cycle of the pipelined device
    /// runtime (`max(head, tx)` per frame; equals the edge time for the
    /// unsplit baseline, which has nothing to overlap).
    pub device_cycle: Vec<Vec<f64>>,
}

/// Execute every configuration over `n_frames` validation frames on the
/// build's default backend.
pub fn measure_raw(paths: &Paths, n_frames: usize) -> Result<RawTimings> {
    measure_raw_with(paths, n_frames, &PipelineBackend::default())
}

/// Execute every configuration on an explicit backend, so Fig-5 numbers
/// can be produced for each substrate (xla vs native) separately.
pub fn measure_raw_with(
    paths: &Paths,
    n_frames: usize,
    be: &PipelineBackend,
) -> Result<RawTimings> {
    let frames = crate::sim::dataset::load_split(&paths.data.join("val"))?;
    let frames: Vec<_> = frames.into_iter().take(n_frames).collect();
    anyhow::ensure!(!frames.is_empty(), "no validation frames");

    let mut base = ScMiiPipeline::load_with(paths, IntegrationKind::Max, be)?;
    base.load_baselines(paths)?;
    let n_devices = base.meta.num_devices;
    let remote_raw_bytes = base.meta.grid.max_points * 16 * (n_devices - 1);
    // Warm-up (compile effects, caches) before measuring.
    let _ = base.infer_input_integration(&frames[0].clouds)?;
    let mut edge_full_secs = Vec::new();
    for f in &frames {
        let (_, secs) = base.infer_input_integration(&f.clouds)?;
        edge_full_secs.push(secs);
    }

    let mut scmii = Vec::new();
    for kind in IntegrationKind::all() {
        let pipeline = ScMiiPipeline::load_with(paths, kind, be)?;
        let _ = pipeline.infer(&frames[0].clouds)?; // warm-up
        let mut timings = Vec::new();
        for f in &frames {
            let (_, t) = pipeline.infer(&f.clouds)?;
            timings.push(t);
        }
        scmii.push((kind, timings));
    }
    Ok(RawTimings { scmii, edge_full_secs, remote_raw_bytes, n_devices })
}

/// Model one testbed configuration from raw measurements.
pub fn model_methods(raw: &RawTimings, lat_cfg: &LatencyConfig) -> Vec<MethodTiming> {
    let model = TestbedModel::new(lat_cfg.clone());
    let mut out = Vec::new();

    let edge_only: Vec<f64> = raw
        .edge_full_secs
        .iter()
        .map(|&s| model.edge_only(s, raw.remote_raw_bytes))
        .collect();
    out.push(MethodTiming {
        name: "Edge-only (input integration)".into(),
        edge_per_device: vec![edge_only.clone(); raw.n_devices],
        device_cycle: vec![edge_only.clone(); raw.n_devices],
        inference: edge_only,
    });

    for (kind, timings) in &raw.scmii {
        let mut inference = Vec::new();
        let mut edge: Vec<Vec<f64>> = vec![Vec::new(); raw.n_devices];
        let mut cycle: Vec<Vec<f64>> = vec![Vec::new(); raw.n_devices];
        for t in timings {
            let b = model.scmii(t);
            inference.push(b.inference);
            let c = b.pipelined_cycle();
            for d in 0..raw.n_devices {
                edge[d].push(b.edge_total[d]);
                cycle[d].push(c[d]);
            }
        }
        out.push(MethodTiming {
            name: format!("SC-MII ({})", pretty(*kind)),
            inference,
            edge_per_device: edge,
            device_cycle: cycle,
        });
    }
    out
}

/// Measurement + modeling in one call (examples / CLI).
pub fn run_exec_time(
    paths: &Paths,
    n_frames: usize,
    lat_cfg: &LatencyConfig,
) -> Result<Vec<MethodTiming>> {
    run_exec_time_with(paths, n_frames, lat_cfg, &PipelineBackend::default())
}

/// Measurement + modeling on an explicit backend.
pub fn run_exec_time_with(
    paths: &Paths,
    n_frames: usize,
    lat_cfg: &LatencyConfig,
    be: &PipelineBackend,
) -> Result<Vec<MethodTiming>> {
    let raw = measure_raw_with(paths, n_frames, be)?;
    Ok(model_methods(&raw, lat_cfg))
}

fn pretty(kind: IntegrationKind) -> &'static str {
    match kind {
        IntegrationKind::Max => "max value selection",
        IntegrationKind::ConvK1 => "conv kernel 1",
        IntegrationKind::ConvK3 => "conv kernel 3",
    }
}

/// Print the Fig-5 tables + headline ratios.
pub fn print_exec_time(methods: &[MethodTiming]) {
    let ms = |v: f64| format!("{:.1}", v * 1e3);
    let rows: Vec<(String, Vec<String>)> = methods
        .iter()
        .map(|m| {
            let mean = stats::mean(&m.inference);
            let max = m.inference.iter().cloned().fold(0.0, f64::max);
            (m.name.clone(), vec![ms(mean), ms(max)])
        })
        .collect();
    print_table("Fig 5a — inference time (ms)", &["mean", "max"], &rows);

    let n_dev = methods.iter().map(|m| m.edge_per_device.len()).max().unwrap_or(0);
    let mut rows = Vec::new();
    for m in methods {
        let mut vals = Vec::new();
        for d in 0..n_dev {
            let xs = m.edge_per_device.get(d).map(|v| v.as_slice()).unwrap_or(&[]);
            vals.push(ms(stats::mean(xs)));
        }
        rows.push((m.name.clone(), vals));
    }
    let cols: Vec<String> = (0..n_dev).map(|d| format!("device {}", d + 1)).collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    print_table("Fig 5b — edge device execution time (ms, mean)", &col_refs, &rows);

    // Sustained-rate view: with the pipelined device runtime, head exec
    // of frame t+1 overlaps tx of frame t, so the cycle is max(head, tx).
    let mut rows = Vec::new();
    for m in methods {
        let mut vals = Vec::new();
        for d in 0..n_dev {
            let xs = m.device_cycle.get(d).map(|v| v.as_slice()).unwrap_or(&[]);
            vals.push(ms(stats::mean(xs)));
        }
        rows.push((m.name.clone(), vals));
    }
    print_table(
        "Steady-state device cycle, pipelined runtime (ms, mean)",
        &col_refs,
        &rows,
    );

    // Headline claims (paper: 2.19x average speedup; 71.6% average edge
    // reduction on the loaded device).
    if let (Some(base), Some(best)) = (methods.first(), methods.last()) {
        let base_mean = stats::mean(&base.inference);
        let speedups: Vec<f64> = methods[1..]
            .iter()
            .map(|m| base_mean / stats::mean(&m.inference))
            .collect();
        if !speedups.is_empty() {
            let best_speedup = speedups.iter().cloned().fold(0.0, f64::max);
            println!(
                "\nspeedup vs edge-only: mean over SC-MII variants {:.2}x, best {:.2}x",
                stats::mean(&speedups),
                best_speedup
            );
        }
        if let (Some(bd), Some(sd)) =
            (base.edge_per_device.last(), best.edge_per_device.last())
        {
            let reduction = 1.0 - stats::mean(sd) / stats::mean(bd);
            println!(
                "edge-device time reduction on device {} (most loaded): {:.1}%",
                base.edge_per_device.len(),
                reduction * 100.0
            );
        }
    }
}

/// `scmii exec-time` CLI entry.
pub fn cmd_exec_time(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts",
        "data",
        "frames",
        "edge-factor",
        "server-factor",
        "bandwidth-gbps",
        "backend",
        "backend-threads",
    ])?;
    let paths = Paths::new(
        &args.str_or("artifacts", "artifacts"),
        &args.str_or("data", "data"),
    );
    let n = args.usize_or("frames", 16)?;
    let mut cfg = LatencyConfig::default();
    cfg.edge_factor = args.f64_or("edge-factor", cfg.edge_factor)?;
    cfg.server_factor = args.f64_or("server-factor", cfg.server_factor)?;
    cfg.bandwidth_bps = args.f64_or("bandwidth-gbps", cfg.bandwidth_bps / 1e9)? * 1e9;
    let be = PipelineBackend::from_args(args)?;
    let methods = run_exec_time_with(&paths, n, &cfg, &be)?;
    print_exec_time(&methods);
    Ok(())
}
