//! Testbed latency model (Fig 5 reproduction).
//!
//! We measure compute on this machine's CPU PJRT backend and scale by
//! device factors to model the paper's heterogeneous testbed (Jetson
//! Orin Nano edge devices + RTX 4090 server + 1 Gbps LAN). Because Fig 5
//! compares *arrangements of the same compute graph*, ratios between
//! arrangements survive the scaling (DESIGN.md §4). A real-wall-clock
//! mode (TCP + bandwidth shaping) cross-checks the ordering.

pub mod harness;

use crate::config::LatencyConfig;
use crate::coordinator::pipeline::FrameTiming;

/// Modeled execution-time breakdown for one SC-MII frame.
#[derive(Clone, Debug)]
pub struct ScMiiBreakdown {
    /// Per device: head compute on the edge device (scaled).
    pub edge_compute: Vec<f64>,
    /// Per device: intermediate-output transmission time.
    pub tx: Vec<f64>,
    /// Per device: "edge device execution time" in the paper's sense —
    /// input to completion of intermediate-output transmission.
    pub edge_total: Vec<f64>,
    /// Server-side tail compute (scaled) + post-processing.
    pub server: f64,
    /// End-to-end inference time: devices run in parallel, the server
    /// starts when the slowest device's features arrive.
    pub inference: f64,
}

impl ScMiiBreakdown {
    /// Per device: the steady-state cycle of the *pipelined* device
    /// runtime, where head execution of frame t+1 overlaps transmission
    /// of frame t — `max(head, tx)` instead of `head + tx`. This bounds
    /// sustained throughput; `edge_total` remains the single-frame
    /// latency (the first frame of a burst still pays head + tx).
    pub fn pipelined_cycle(&self) -> Vec<f64> {
        self.edge_compute.iter().zip(&self.tx).map(|(c, x)| c.max(*x)).collect()
    }
}

/// The latency model.
#[derive(Clone, Debug, Default)]
pub struct TestbedModel {
    pub cfg: LatencyConfig,
}

impl TestbedModel {
    pub fn new(cfg: LatencyConfig) -> TestbedModel {
        TestbedModel { cfg }
    }

    /// Model SC-MII from measured in-process timings.
    pub fn scmii(&self, t: &FrameTiming) -> ScMiiBreakdown {
        let edge_compute: Vec<f64> =
            t.head_secs.iter().map(|s| s * self.cfg.edge_factor).collect();
        let tx: Vec<f64> =
            t.payload_bytes.iter().map(|&b| self.cfg.tx_time(b)).collect();
        let edge_total: Vec<f64> =
            edge_compute.iter().zip(&tx).map(|(c, x)| c + x).collect();
        let server = (t.tail_secs + t.post_secs) * self.cfg.server_factor;
        let slowest_device =
            edge_total.iter().cloned().fold(0.0, f64::max);
        ScMiiBreakdown {
            edge_compute,
            tx,
            edge_total,
            server,
            inference: slowest_device + server,
        }
    }

    /// Model the edge-only baseline: the full model (input point-cloud
    /// integration included) runs on a single Jetson-class device; raw
    /// points from the *other* sensors must first cross the LAN.
    pub fn edge_only(&self, full_model_secs: f64, remote_raw_bytes: usize) -> f64 {
        self.cfg.tx_time(remote_raw_bytes) + full_model_secs * self.cfg.edge_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing() -> FrameTiming {
        FrameTiming {
            head_secs: vec![0.010, 0.012],
            payload_bytes: vec![1 << 20, 1 << 20],
            tail_secs: 0.040,
            post_secs: 0.002,
        }
    }

    #[test]
    fn breakdown_composes() {
        let m = TestbedModel::new(LatencyConfig {
            edge_factor: 6.0,
            server_factor: 0.25,
            bandwidth_bps: 1e9,
            base_rtt: 0.5e-3,
        });
        let b = m.scmii(&timing());
        // device 0: 60 ms compute + ~8.9 ms tx
        assert!((b.edge_compute[0] - 0.060).abs() < 1e-9);
        assert!((b.tx[0] - (0.5e-3 + 8.0 * (1 << 20) as f64 / 1e9)).abs() < 1e-9);
        assert!((b.edge_total[0] - (b.edge_compute[0] + b.tx[0])).abs() < 1e-12);
        // inference gated by the slower device (device 1)
        assert!(b.edge_total[1] > b.edge_total[0]);
        assert!((b.inference - (b.edge_total[1] + b.server)).abs() < 1e-12);
    }

    #[test]
    fn pipelined_cycle_is_max_not_sum() {
        let m = TestbedModel::new(LatencyConfig {
            edge_factor: 6.0,
            server_factor: 0.25,
            bandwidth_bps: 1e9,
            base_rtt: 0.5e-3,
        });
        let b = m.scmii(&timing());
        let cycle = b.pipelined_cycle();
        assert_eq!(cycle.len(), b.edge_compute.len());
        for (i, &c) in cycle.iter().enumerate() {
            let (head, tx) = (b.edge_compute[i], b.tx[i]);
            assert!((c - head.max(tx)).abs() < 1e-12);
            assert!(c < b.edge_total[i], "cycle must beat head + tx");
        }
    }

    #[test]
    fn scmii_beats_edge_only_when_tail_dominates() {
        let m = TestbedModel::default();
        let b = m.scmii(&timing());
        // full model ≈ head + tail on one device
        let edge_only = m.edge_only(0.012 + 0.042, 4096 * 16);
        assert!(
            b.inference < edge_only,
            "scmii {} vs edge-only {}",
            b.inference,
            edge_only
        );
    }

    #[test]
    fn zero_bandwidth_penalizes_scmii() {
        let mut cfg = LatencyConfig::default();
        cfg.bandwidth_bps = 1e6; // 1 Mbps: 1 MiB payload takes ~8.4 s
        let m = TestbedModel::new(cfg);
        let b = m.scmii(&timing());
        assert!(b.inference > 8.0, "{}", b.inference);
    }
}
