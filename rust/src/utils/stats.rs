//! Small statistics helpers shared by metrics, benches and evaluation.

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `q` in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// Percentile on an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Min/max over a slice; `(0, 0)` when empty.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| (lo.min(x), hi.max(x)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
