//! Minimal JSON value model + parser + writer.
//!
//! `serde`/`serde_json` are not available in the offline image; the
//! calibration file (`artifacts/calib.json`), the model metadata emitted
//! by `python/compile/aot.py` (`artifacts/model_meta.json`) and dataset
//! metadata all flow through this module. It supports the full JSON value
//! grammar minus exotic number forms, which is all both sides emit.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name when missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// Array of numbers → Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation (human-inspectable artifacts).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {} in json", p.pos);
    }
    Ok(v)
}

/// Read + parse a JSON file.
pub fn read_file(path: &Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    parse(&text).with_context(|| format!("parse {}", path.display()))
}

/// Pretty-write a JSON file, creating parent dirs.
pub fn write_file(path: &Path, value: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.to_pretty()).with_context(|| format!("write {}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().context("unexpected end of json")
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            _ => self.number(),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            bail!("invalid keyword at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).context("invalid \\u escape")?);
                        }
                        other => bail!("invalid escape \\{}", other as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: back up and take the full char.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().context("bad utf8")?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = s.parse().with_context(|| format!("invalid number {s:?}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut inner = Json::obj();
        inner.set("a", Json::Num(1.5)).set("b", Json::Str("hi \"q\"".into()));
        let mut top = Json::obj();
        top.set("arr", Json::Arr(vec![Json::Num(1.0), Json::Bool(false), Json::Null]))
            .set("obj", inner);
        let text = top.to_string();
        assert_eq!(parse(&text).unwrap(), top);
        let pretty = top.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), top);
    }

    #[test]
    fn parses_python_json_output() {
        // Shape matching what python `json.dumps` emits.
        let text = r#"{"grid": [64, 64, 8], "voxel": [0.8, 0.8, 0.75], "neg": -2.5e-3, "name": "conv_k3", "ok": true}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.req("grid").unwrap().as_usize_vec().unwrap(), vec![64, 64, 8]);
        assert!((v.req("neg").unwrap().as_f64().unwrap() + 0.0025).abs() < 1e-12);
        assert_eq!(v.req("name").unwrap().as_str().unwrap(), "conv_k3");
        assert!(v.req("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn integers_written_without_fraction() {
        let v = Json::Num(64.0);
        assert_eq!(v.to_string(), "64");
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::Str("日本\nlidar\t\"x\"".into());
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn missing_key_error_names_key() {
        let v = parse("{}").unwrap();
        let err = v.req("voxel_size").unwrap_err().to_string();
        assert!(err.contains("voxel_size"));
    }
}
