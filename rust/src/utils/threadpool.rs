//! Fixed-size thread pool (no rayon/tokio in the offline image).
//!
//! Used by the dataset generator (per-frame raycasting fans out across
//! cores) and the evaluation harness. Jobs are `FnOnce` closures; `map`
//! offers a rayon-like structured-parallel map.
//!
//! Workers survive panicking jobs: the panic is caught, counted
//! (`panicked_jobs`), and logged, so one bad closure no longer silently
//! shrinks the pool for the rest of the run.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{lock_or_recover, mpsc, thread, Arc, Mutex};
use std::panic::{catch_unwind, AssertUnwindSafe};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-stealing-free thread pool with a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    panicked: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let panicked = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let panicked = Arc::clone(&panicked);
                thread::spawn_named(&format!("scmii-pool-{i}"), move || loop {
                    let job = { lock_or_recover(&rx).recv() };
                    match job {
                        // A panicking job must not kill its worker — that
                        // silently shrinks the pool for the rest of the
                        // run. Contain it, count it, keep serving.
                        Ok(job) => {
                            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panicked.fetch_add(1, Ordering::SeqCst);
                                log::warn!("thread-pool job panicked; worker continues");
                            }
                        }
                        Err(_) => break,
                    }
                })
                .expect("spawn thread-pool worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers, panicked }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4).min(16);
        Self::new(n)
    }

    /// How many submitted jobs have panicked so far. The panics are
    /// contained (workers keep running); this is the caller's signal
    /// that some results never materialized.
    pub fn panicked_jobs(&self) -> usize {
        self.panicked.load(Ordering::SeqCst)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender.as_ref().expect("pool shut down").send(Box::new(f)).expect("workers died");
    }

    /// Apply `f` to every index 0..n in parallel and collect results in
    /// order. Results must be `Send`; `f` is cloned per job. Panics if
    /// any job panicked (its slot has no result) — use
    /// [`panicked_jobs`](ThreadPool::panicked_jobs) to diagnose.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + Clone + 'static,
    {
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let tx = tx.clone();
            let f = f.clone();
            self.execute(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("worker panicked before sending")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn panicking_job_does_not_shrink_the_pool() {
        // Regression: a panicking job used to kill its worker silently.
        // On a 1-worker pool that left *zero* workers — any later job
        // would hang forever. Now the worker survives: the panic is
        // counted and all 50 follow-up jobs still run to completion.
        let pool = ThreadPool::new(1);
        let panicked = Arc::clone(&pool.panicked);
        pool.execute(|| panic!("deliberate test panic"));
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins: hangs here if the worker died
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert_eq!(panicked.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn map_still_works_after_a_panicked_job() {
        let pool = ThreadPool::new(2);
        let panicked = Arc::clone(&pool.panicked);
        pool.execute(|| panic!("boom"));
        let out = pool.map(16, |i| i * 2);
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
        drop(pool); // joins, so the panic is certainly counted by now
        assert_eq!(panicked.load(Ordering::SeqCst), 1);
    }
}
