//! Fixed-size thread pool (no rayon/tokio in the offline image).
//!
//! Used by the dataset generator (per-frame raycasting fans out across
//! cores) and the evaluation harness. Jobs are `FnOnce` closures; `scope`
//! offers a rayon-like structured-parallel map.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-stealing-free thread pool with a shared queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|v| v.get()).unwrap_or(4).min(16);
        Self::new(n)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender.as_ref().expect("pool shut down").send(Box::new(f)).expect("workers died");
    }

    /// Apply `f` to every index 0..n in parallel and collect results in
    /// order. Results must be `Send`; `f` is cloned per job.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + Clone + 'static,
    {
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let tx = tx.clone();
            let f = f.clone();
            self.execute(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("worker panicked before sending")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }
}
