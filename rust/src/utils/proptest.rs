//! Property-testing driver (proptest/quickcheck are not in the image).
//!
//! Runs a property over many seeded-random cases and, on failure, reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use scmii::utils::proptest::{property, Gen};
//! property("abs is non-negative", 256, |g: &mut Gen| {
//!     let x = g.f64_range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! `SCMII_PROP_SEED` replays a single failing case; `SCMII_PROP_CASES`
//! overrides the case count.

use super::rng::Pcg64;

/// Case-local generator handed to each property invocation.
pub struct Gen {
    rng: Pcg64,
    /// Seed of this case (for failure reports).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::new(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.int_range(lo as i64, hi as i64) as usize
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.int_range(lo, hi)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of f32 drawn uniformly from [lo, hi).
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// Choose an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Access the raw rng (e.g. to fork sub-streams).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases; panics with the failing seed.
pub fn property<F: FnMut(&mut Gen) + std::panic::UnwindSafe + Copy>(
    name: &str,
    cases: usize,
    prop: F,
) {
    if let Ok(seed) = std::env::var("SCMII_PROP_SEED") {
        let seed: u64 = seed.parse().expect("SCMII_PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        let mut p = prop;
        p(&mut g);
        return;
    }
    let cases = std::env::var("SCMII_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(cases);
    for case in 0..cases {
        // Derive a per-case seed from the property name + index so
        // distinct properties explore distinct streams.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let seed = h ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(move || {
            let mut g = Gen::new(seed);
            let mut p = prop;
            p(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case} (replay with \
                 SCMII_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        property("add commutes", 64, |g| {
            let a = g.i64_range(-1000, 1000);
            let b = g.i64_range(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            property("always fails", 8, |_g| {
                panic!("boom");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("SCMII_PROP_SEED="), "{msg}");
    }
}
