//! Deterministic PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! The image ships `rand_core` but not `rand`; the simulator, property
//! tests and workload generators need a seedable, portable RNG, so we
//! implement PCG64 directly. The stream is stable across platforms —
//! dataset generation is reproducible bit-for-bit from a seed, which the
//! training side (python) relies on via the on-disk npy files.

/// PCG64 XSL-RR generator with 128-bit state and a fixed odd increment.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        // SplitMix-style seed expansion into 128-bit state + increment.
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        // Decorrelate the first output from the raw seed.
        rng.next_u64();
        rng
    }

    /// Derive an independent sub-stream (for per-frame / per-object RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal with given mean/stddev.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let v = rng.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_all_residues_unbiased() {
        let mut rng = Pcg64::new(17);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
