//! Micro-benchmark harness (criterion is not in the offline image).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warmup,
//! outlier-robust reporting and throughput accounting, and to print table
//! rows the paper-reproduction benches share.

use super::stats;
use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub times: Vec<f64>,
}

impl Sample {
    pub fn mean(&self) -> f64 {
        stats::mean(&self.times)
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.times, 50.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.times, 99.0)
    }

    pub fn stddev(&self) -> f64 {
        stats::stddev(&self.times)
    }
}

/// Bench runner with a global time budget per case.
pub struct Bench {
    warmup_iters: usize,
    min_iters: usize,
    max_iters: usize,
    budget: Duration,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 2000,
            budget: Duration::from_secs(3),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cheaper settings for CI-ish runs (`SCMII_BENCH_FAST=1`).
    pub fn auto() -> Self {
        if std::env::var("SCMII_BENCH_FAST").is_ok() {
            Bench {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 50,
                budget: Duration::from_millis(500),
                results: Vec::new(),
            }
        } else {
            Self::default()
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    pub fn with_iters(mut self, min: usize, max: usize) -> Self {
        self.min_iters = min;
        self.max_iters = max;
        self
    }

    /// Untimed iterations before measurement starts (`--warmup N`). The
    /// default of 3 settles allocator pools and branch predictors; 0
    /// measures the cold path.
    pub fn with_warmup(mut self, warmup: usize) -> Self {
        self.warmup_iters = warmup;
        self
    }

    /// Time `f` until the budget or `max_iters` is exhausted; prints and
    /// records a summary line.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Sample {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (start.elapsed() < self.budget && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let sample = Sample { name: name.to_string(), times };
        println!(
            "{:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  (n={})",
            sample.name,
            fmt_time(sample.mean()),
            fmt_time(sample.p50()),
            fmt_time(sample.p99()),
            sample.times.len()
        );
        self.results.push(sample);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }

    /// Print a relative comparison against a named baseline case.
    pub fn compare(&self, baseline: &str) {
        let Some(base) = self.results.iter().find(|s| s.name == baseline) else {
            return;
        };
        println!("\nrelative to {baseline}:");
        for s in &self.results {
            println!("  {:<42} {:>6.2}x", s.name, base.mean() / s.mean());
        }
    }
}

/// Human format for seconds.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Pretty-print a table: header + rows of (label, values).
pub fn print_table(title: &str, columns: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n=== {title} ===");
    let label_w = rows.iter().map(|(l, _)| l.len()).chain([16]).max().unwrap();
    print!("{:<w$}", "", w = label_w + 2);
    for c in columns {
        print!("{c:>14}");
    }
    println!();
    for (label, vals) in rows {
        print!("{label:<w$}", w = label_w + 2);
        for v in vals {
            print!("{v:>14}");
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench::new().with_budget(Duration::from_millis(20)).with_iters(3, 10);
        let s = b.run("noop", || {});
        assert!(s.times.len() >= 3);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn warmup_iterations_run_untimed() {
        let mut calls = 0usize;
        let mut b = Bench::new()
            .with_warmup(5)
            .with_budget(Duration::ZERO)
            .with_iters(2, 2);
        let s = b.run("counted", || calls += 1);
        assert_eq!(s.times.len(), 2, "timed iterations are capped by max_iters");
        assert_eq!(calls, 5 + 2, "warmup iterations execute but are not timed");
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
        assert!(fmt_time(2.5e-9).ends_with(" ns"));
    }
}
