//! Self-contained substrates replacing crates unavailable in the offline
//! image (serde/rand/criterion/proptest/clap): npy I/O, a minimal JSON
//! value model, a PCG64 RNG, a micro-bench harness, a property-test
//! driver, logging, and small stats helpers.

pub mod bench;
pub mod json;
pub mod logging;
pub mod npy;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
