//! Self-contained substrates replacing crates unavailable in the offline
//! image (serde/rand/criterion/proptest/clap): npy I/O, a minimal JSON
//! value model, a PCG64 RNG, a micro-bench harness, a property-test
//! driver, logging, and small stats helpers.

pub mod bench;
pub mod json;
pub mod logging;
pub mod npy;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Wall-clock microseconds since the Unix epoch. Used to stamp frame
/// capture on devices so the server (or an in-process scenario harness)
/// can account end-to-end latency; 0 means "no stamp" on the wire, so a
/// pre-epoch clock degrades to the legacy unstamped behavior.
pub fn unix_micros() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}
