//! Message-level link fault injection for the device uplink.
//!
//! The paper's testbed is a clean 1 Gbps wired LAN, but the robustness
//! direction its §IV-E calls out ("systems designed to tolerate partial
//! data loss without retransmission") needs *lossy* links to exercise —
//! that is what drives `FrameSync`'s Drop/ZeroFill policies for real.
//! [`ImpairedLink`] sits between the device worker and its (optionally
//! bandwidth-shaped) socket and injects faults per *message*:
//!
//! - **loss** — data messages are silently discarded, probabilistically
//!   (`loss`) or deterministically (`drop_every`, for reproducible
//!   accounting in tests and CI scenarios);
//! - **delay/jitter** — a fixed + uniformly-jittered latency before each
//!   data message leaves (models switch/queueing delay; running inside
//!   the device's writer thread it delays transmission without blocking
//!   head execution);
//! - **reorder** — a data message is held back and emitted after the
//!   next one, swapping adjacent frames on the wire;
//! - **dup** — a data message is written twice, exercising receiver-side
//!   deduplication (`FrameSync` duplicate accounting on TCP, the
//!   [`dgram`](super::dgram) assembler's `dup` counter on UDP).
//!
//! Control messages (`Hello`, `Subscribe`, `Bye`, …) always pass and
//! flush any held frame first, so handshakes stay intact and `Bye`
//! remains last on the wire.

use super::proto::{encode_frame, Msg};
use crate::utils::rng::Pcg64;
use anyhow::Result;
use std::io::Write;
use std::time::Duration;

/// Per-link fault-injection parameters. Defaults are a clean link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ImpairConfig {
    /// Probability of dropping each data message.
    pub loss: f64,
    /// Deterministic loss: drop every k-th data message (0 = off).
    /// Composes with `loss`; tests and CI gates prefer this knob for
    /// exact sync-stat accounting.
    pub drop_every: u64,
    /// Fixed extra latency per data message.
    pub delay: Duration,
    /// Additional uniform jitter in `[0, jitter)` per data message.
    pub jitter: Duration,
    /// Probability of holding a data message until after the next one.
    pub reorder: f64,
    /// Probability of sending each data message twice (duplication on
    /// the wire — the datagram transport must dedup, TCP's `FrameSync`
    /// counts it as a duplicate arrival).
    pub dup: f64,
    /// RNG seed — runs are reproducible per (seed, message sequence).
    pub seed: u64,
}

impl Default for ImpairConfig {
    fn default() -> Self {
        ImpairConfig {
            loss: 0.0,
            drop_every: 0,
            delay: Duration::ZERO,
            jitter: Duration::ZERO,
            reorder: 0.0,
            dup: 0.0,
            seed: 1,
        }
    }
}

impl ImpairConfig {
    /// Reject out-of-range probabilities at configuration time: a
    /// `--loss 5` meant as "5%" would otherwise silently drop *every*
    /// message, and a negative value silently means a clean link.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.loss),
            "loss probability must be in [0, 1], got {}",
            self.loss
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.reorder),
            "reorder probability must be in [0, 1], got {}",
            self.reorder
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.dup),
            "dup probability must be in [0, 1], got {}",
            self.dup
        );
        Ok(())
    }
}

/// Counters of what the link actually did (scenario reports / tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ImpairStats {
    /// Data messages offered to the link (`Features` / `FeaturesQ`).
    pub data_msgs: u64,
    /// Data messages discarded by loss injection.
    pub dropped: u64,
    /// Data messages that slept a delay/jitter before leaving.
    pub delayed: u64,
    /// Data messages held back past their successor.
    pub reordered: u64,
    /// Data messages sent twice by duplication injection.
    pub duplicated: u64,
}

/// A protocol-message writer with fault injection. `None` config is a
/// transparent pass-through, so the device runtime always writes through
/// one code path.
pub struct ImpairedLink<W: Write> {
    inner: W,
    cfg: Option<ImpairConfig>,
    rng: Pcg64,
    /// A frame held back for reordering, emitted after the next write.
    held: Option<Vec<u8>>,
    stats: ImpairStats,
}

impl<W: Write> ImpairedLink<W> {
    /// Wrap `inner` with fault injection; `None` is a transparent
    /// pass-through.
    pub fn new(inner: W, cfg: Option<ImpairConfig>) -> ImpairedLink<W> {
        let seed = cfg.as_ref().map(|c| c.seed).unwrap_or(0);
        ImpairedLink { inner, cfg, rng: Pcg64::new(seed), held: None, stats: ImpairStats::default() }
    }

    /// What the link has done so far (drop/delay/reorder counters).
    pub fn stats(&self) -> ImpairStats {
        self.stats
    }

    /// The wrapped writer (e.g. to reach socket options).
    pub fn get_mut(&mut self) -> &mut W {
        &mut self.inner
    }

    /// Send one protocol message through the impaired link. Only data
    /// messages (`Features` / `FeaturesQ`) are subject to faults; control
    /// messages always pass, flushing any held frame first.
    pub fn send(&mut self, msg: &Msg) -> Result<()> {
        let frame = encode_frame(msg)?;
        let is_data = matches!(msg, Msg::Features { .. } | Msg::FeaturesQ { .. });
        let Some(cfg) = self.cfg else {
            return self.write_frame(&frame);
        };
        if !is_data {
            self.release_held()?;
            return self.write_frame(&frame);
        }
        self.stats.data_msgs += 1;
        let k = self.stats.data_msgs;
        let deterministic_drop = cfg.drop_every > 0 && k % cfg.drop_every == 0;
        if deterministic_drop || (cfg.loss > 0.0 && self.rng.uniform() < cfg.loss) {
            self.stats.dropped += 1;
            return Ok(());
        }
        if cfg.delay > Duration::ZERO || cfg.jitter > Duration::ZERO {
            let jitter = cfg.jitter.mul_f64(self.rng.uniform());
            std::thread::sleep(cfg.delay + jitter);
            self.stats.delayed += 1;
        }
        if cfg.reorder > 0.0 && self.held.is_none() && self.rng.uniform() < cfg.reorder {
            self.held = Some(frame);
            self.stats.reordered += 1;
            return Ok(());
        }
        self.write_frame(&frame)?;
        if cfg.dup > 0.0 && self.rng.uniform() < cfg.dup {
            self.stats.duplicated += 1;
            self.write_frame(&frame)?;
        }
        self.release_held()
    }

    /// Flush any held (reordered) frame; `send`ing a control message does
    /// this implicitly, but call it before dropping the link if the last
    /// message might be held.
    pub fn finish(&mut self) -> Result<()> {
        self.release_held()
    }

    fn write_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.inner.write_all(frame)?;
        self.inner.flush()?;
        Ok(())
    }

    fn release_held(&mut self) -> Result<()> {
        if let Some(h) = self.held.take() {
            self.inner.write_all(&h)?;
            self.inner.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{read_msg, DEFAULT_SESSION};
    use crate::runtime::HostTensor;

    fn feat(frame_id: u64) -> Msg {
        Msg::Features {
            frame_id,
            device_id: 0,
            tensor: HostTensor::zeros(&[2]),
            session: DEFAULT_SESSION.into(),
            capture_micros: 0,
        }
    }

    fn decode_all(mut buf: &[u8]) -> Vec<Msg> {
        let mut out = Vec::new();
        while !buf.is_empty() {
            out.push(read_msg(&mut buf).unwrap());
        }
        out
    }

    fn frame_ids(msgs: &[Msg]) -> Vec<u64> {
        msgs.iter()
            .filter_map(|m| match m {
                Msg::Features { frame_id, .. } => Some(*frame_id),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn validate_rejects_out_of_range_probabilities() {
        assert!(ImpairConfig::default().validate().is_ok());
        assert!(ImpairConfig { loss: 1.0, reorder: 1.0, ..Default::default() }
            .validate()
            .is_ok());
        assert!(ImpairConfig { loss: 5.0, ..Default::default() }.validate().is_err());
        assert!(ImpairConfig { loss: -0.1, ..Default::default() }.validate().is_err());
        assert!(ImpairConfig { reorder: 1.5, ..Default::default() }.validate().is_err());
        assert!(ImpairConfig { dup: 1.0, ..Default::default() }.validate().is_ok());
        assert!(ImpairConfig { dup: 1.1, ..Default::default() }.validate().is_err());
        assert!(ImpairConfig { dup: -0.5, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn dup_writes_each_data_message_twice() {
        let cfg = ImpairConfig { dup: 1.0, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        link.send(&feat(0)).unwrap();
        link.send(&feat(1)).unwrap();
        link.send(&Msg::Bye).unwrap();
        let msgs = decode_all(link.get_mut());
        assert_eq!(frame_ids(&msgs), vec![0, 0, 1, 1], "each data frame doubled");
        assert_eq!(msgs.last(), Some(&Msg::Bye), "control messages are never duplicated");
        assert_eq!(link.stats().duplicated, 2);
    }

    #[test]
    fn clean_link_is_a_passthrough() {
        let mut link = ImpairedLink::new(Vec::new(), None);
        for i in 0..3 {
            link.send(&feat(i)).unwrap();
        }
        link.send(&Msg::Bye).unwrap();
        let msgs = decode_all(link.get_mut());
        assert_eq!(frame_ids(&msgs), vec![0, 1, 2]);
        assert_eq!(msgs.last(), Some(&Msg::Bye));
        assert_eq!(link.stats(), ImpairStats::default());
    }

    #[test]
    fn drop_every_is_deterministic() {
        let cfg = ImpairConfig { drop_every: 3, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        for i in 0..9 {
            link.send(&feat(i)).unwrap();
        }
        // Messages 3, 6, 9 (1-indexed) dropped → frames 2, 5, 8 missing.
        let msgs = decode_all(link.get_mut());
        assert_eq!(frame_ids(&msgs), vec![0, 1, 3, 4, 6, 7]);
        assert_eq!(link.stats().dropped, 3);
        assert_eq!(link.stats().data_msgs, 9);
    }

    #[test]
    fn full_loss_blacks_out_data_but_not_control() {
        let cfg = ImpairConfig { loss: 1.0, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        link.send(&Msg::Hello { device_id: 4, session: "s".into(), split: String::new() })
            .unwrap();
        for i in 0..5 {
            link.send(&feat(i)).unwrap();
        }
        link.send(&Msg::Bye).unwrap();
        let msgs = decode_all(link.get_mut());
        assert_eq!(msgs.len(), 2, "only Hello and Bye may pass");
        assert!(matches!(msgs[0], Msg::Hello { device_id: 4, .. }));
        assert_eq!(msgs[1], Msg::Bye);
        assert_eq!(link.stats().dropped, 5);
    }

    #[test]
    fn reorder_swaps_adjacent_frames() {
        let cfg = ImpairConfig { reorder: 1.0, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        link.send(&feat(0)).unwrap(); // held
        link.send(&feat(1)).unwrap(); // written, then releases frame 0
        let msgs = decode_all(link.get_mut());
        assert_eq!(frame_ids(&msgs), vec![1, 0], "adjacent frames must swap");
        assert_eq!(link.stats().reordered, 1);
    }

    #[test]
    fn control_message_flushes_held_frame_first() {
        let cfg = ImpairConfig { reorder: 1.0, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        link.send(&feat(7)).unwrap(); // held
        link.send(&Msg::Bye).unwrap(); // must release frame 7 first
        let msgs = decode_all(link.get_mut());
        assert_eq!(frame_ids(&msgs), vec![7]);
        assert_eq!(msgs.last(), Some(&Msg::Bye), "Bye stays last on the wire");
    }

    #[test]
    fn delay_sleeps_before_emitting() {
        let cfg = ImpairConfig { delay: Duration::from_millis(20), ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        let t0 = std::time::Instant::now();
        link.send(&feat(0)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(link.stats().delayed, 1);
        assert_eq!(frame_ids(&decode_all(link.get_mut())), vec![0]);
    }

    #[test]
    fn finish_releases_a_trailing_held_frame() {
        let cfg = ImpairConfig { reorder: 1.0, ..Default::default() };
        let mut link = ImpairedLink::new(Vec::new(), Some(cfg));
        link.send(&feat(3)).unwrap(); // held, nothing follows
        assert!(decode_all(link.get_mut()).is_empty());
        link.finish().unwrap();
        assert_eq!(frame_ids(&decode_all(link.get_mut())), vec![3]);
    }
}
