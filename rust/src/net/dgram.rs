//! Latest-wins datagram transport for the feature uplink.
//!
//! TCP's in-order delivery is the wrong semantic for live LiDAR frames:
//! one lost segment head-of-line-blocks every fresher frame behind the
//! retransmit of a stale one. This module carries the *existing* framed
//! wire form (`encode_frame` output, byte for byte) chunked into
//! ≤[`MAX_DGRAM`]-byte datagrams, so a reassembled frame feeds the same
//! decode path as TCP — the transport changes, the payload bytes do not.
//!
//! Three pieces:
//!
//! * [`chunk_frame`] — split one framed message into data datagrams
//!   (plus one XOR-parity datagram per `fec_k`-chunk group when FEC is
//!   on);
//! * [`DgramAssembler`] — per-(session, device) reassembly with
//!   **latest-wins** replacement: a newer frame supersedes any
//!   partially-assembled older one, stale datagrams are counted and
//!   dropped (never delivered), duplicates are counted and ignored, and
//!   a single lost chunk per parity group is recovered from the parity
//!   datagram without retransmit;
//! * [`DgramImpairer`] — datagram-level loss/delay/reorder/duplication
//!   injection, the UDP counterpart of [`ImpairedLink`](super::ImpairedLink).
//!
//! The datagram header layout is normative in
//! `docs/WIRE_PROTOCOL.md` ("Datagram transport" + the machine-readable
//! table between the `dgram-spec` markers); `cargo run -p xtask -- lint`
//! cross-checks [`put_header_fields`] against that table field for
//! field, exactly as it does for `encode_payload`.

use crate::net::impair::{ImpairConfig, ImpairStats};
use crate::utils::rng::Pcg64;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::time::Duration;

/// Datagram magic, distinct from the stream framing's `"SCMI"` so a
/// datagram accidentally fed to the TCP assembler (or vice versa) is an
/// immediate, explicit error instead of a silent mis-parse.
pub const DGRAM_MAGIC: [u8; 4] = *b"SCMD";

/// Header version byte; any other value is dropped as malformed.
pub const DGRAM_VERSION: u8 = 1;

/// `kind` byte of a data chunk.
pub const KIND_DATA: u8 = 0;

/// `kind` byte of an XOR-parity datagram.
pub const KIND_PARITY: u8 = 1;

/// Upper bound on one datagram (header + payload) — chosen to fit a
/// 1500-byte Ethernet MTU with IP/UDP headers and tunnel headroom.
pub const MAX_DGRAM: usize = 1400;

/// Framed-message bytes carried per data chunk. Fixed by the protocol:
/// every chunk of a frame except the last carries exactly this many
/// bytes, which is what lets the receiver compute any chunk's exact
/// length from `frame_len` alone (XOR recovery needs the lost chunk's
/// true length). 1100 leaves room for the worst-case header (41 bytes
/// fixed + 1 + 255-byte session name) within [`MAX_DGRAM`].
pub const CHUNK_PAYLOAD: usize = 1100;

/// Largest framed message a datagram stream may carry: the TCP
/// `MAX_PAYLOAD` bound plus the 9-byte frame header.
const MAX_FRAME: usize = (256 << 20) + 9;

/// Parsed datagram header (everything before the payload bytes).
///
/// All integers little-endian on the wire; the session string is the
/// same `len(u8) | utf-8` encoding the stream protocol uses, but
/// **required** here — every datagram is self-describing because any
/// one of them may be the first to arrive.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DgramHeader {
    /// [`KIND_DATA`] or [`KIND_PARITY`].
    pub kind: u8,
    /// Sending device's slot.
    pub device_id: u32,
    /// Frame sequence number (the `Msg` frame id): orders frames for
    /// latest-wins replacement.
    pub frame_seq: u64,
    /// Data: index of this chunk in `[0, chunk_count)`. Parity: the
    /// parity-group id it protects (same value as `fec_group`).
    pub chunk_index: u32,
    /// Total data chunks of this frame.
    pub chunk_count: u32,
    /// Total framed-message bytes (all chunks concatenated).
    pub frame_len: u32,
    /// FEC group size `k` (0 = FEC off; parity datagrams require > 0).
    pub fec_k: u32,
    /// Parity-group id: `chunk_index / fec_k` for data chunks, the
    /// protected group for parity datagrams.
    pub fec_group: u32,
    /// Payload bytes following the header.
    pub payload_len: u16,
    /// Addressed session (required, non-empty).
    pub session: String,
}

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_session(buf: &mut Vec<u8>, session: &str) {
    let bytes = session.as_bytes();
    assert!(!bytes.is_empty() && bytes.len() <= 255, "session name must be 1..=255 bytes");
    buf.push(bytes.len() as u8);
    buf.extend_from_slice(bytes);
}

/// Serialize the header fields after the magic.
///
/// Must stay a flat, ordered sequence of `put_*(buf, field)` calls
/// (after the destructuring `let`s): `xtask lint` parses this function
/// and cross-checks field order and encodings against the dgram spec
/// table in `docs/WIRE_PROTOCOL.md`, exactly as it does for
/// `encode_payload` in `proto.rs`.
fn put_header_fields(buf: &mut Vec<u8>, h: &DgramHeader) {
    let DgramHeader {
        kind,
        device_id,
        frame_seq,
        chunk_index,
        chunk_count,
        frame_len,
        fec_k,
        fec_group,
        payload_len,
        session,
    } = h;
    let ver = DGRAM_VERSION;
    put_u8(buf, ver);
    put_u8(buf, *kind);
    put_u32(buf, *device_id);
    put_u64(buf, *frame_seq);
    put_u32(buf, *chunk_index);
    put_u32(buf, *chunk_count);
    put_u32(buf, *frame_len);
    put_u32(buf, *fec_k);
    put_u32(buf, *fec_group);
    put_u16(buf, *payload_len);
    put_session(buf, session);
}

/// Serialize one complete datagram (magic + header + payload).
pub fn encode_dgram(h: &DgramHeader, payload: &[u8]) -> Vec<u8> {
    assert_eq!(h.payload_len as usize, payload.len(), "payload_len must match payload");
    let mut buf = Vec::with_capacity(MAX_DGRAM);
    buf.extend_from_slice(&DGRAM_MAGIC);
    put_header_fields(&mut buf, h);
    buf.extend_from_slice(payload);
    debug_assert!(buf.len() <= MAX_DGRAM, "datagram over MAX_DGRAM: {}", buf.len());
    buf
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated datagram");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Parse one datagram into its header and payload slice.
///
/// Purely structural validation (magic, version, kind, field bounds,
/// exact payload length, no trailing bytes, never over-reads); the
/// cross-datagram semantic checks (chunk geometry vs `frame_len`, FEC
/// consistency) live in [`DgramAssembler::feed`], which is also where
/// malformed datagrams are *counted* rather than surfaced as errors.
pub fn parse_dgram(dgram: &[u8]) -> Result<(DgramHeader, &[u8])> {
    let mut c = Cursor { buf: dgram, pos: 0 };
    if c.take(4)? != DGRAM_MAGIC {
        bail!("bad datagram magic");
    }
    let ver = c.u8()?;
    ensure!(ver == DGRAM_VERSION, "unknown datagram version {ver}");
    let kind = c.u8()?;
    ensure!(kind == KIND_DATA || kind == KIND_PARITY, "unknown datagram kind {kind}");
    let device_id = c.u32()?;
    let frame_seq = c.u64()?;
    let chunk_index = c.u32()?;
    let chunk_count = c.u32()?;
    let frame_len = c.u32()?;
    let fec_k = c.u32()?;
    let fec_group = c.u32()?;
    let payload_len = c.u16()?;
    let slen = c.u8()? as usize;
    ensure!(slen > 0, "empty session name");
    let sbytes = c.take(slen)?;
    let session = std::str::from_utf8(sbytes)
        .map_err(|_| anyhow::anyhow!("session name not utf-8"))?
        .to_string();
    let payload = c.take(payload_len as usize)?;
    ensure!(c.pos == dgram.len(), "{} trailing bytes in datagram", dgram.len() - c.pos);
    let h = DgramHeader {
        kind,
        device_id,
        frame_seq,
        chunk_index,
        chunk_count,
        frame_len,
        fec_k,
        fec_group,
        payload_len,
        session,
    };
    Ok((h, payload))
}

/// Data chunks a frame of `frame_len` bytes splits into.
pub fn expected_chunks(frame_len: usize) -> usize {
    frame_len.div_ceil(CHUNK_PAYLOAD).max(1)
}

/// Exact byte length of chunk `index` of a `frame_len`-byte frame:
/// every chunk is [`CHUNK_PAYLOAD`] except the last, which carries the
/// remainder. This determinism is what makes single-loss XOR recovery
/// exact — the receiver knows the lost chunk's length without it.
pub fn chunk_len(frame_len: usize, index: usize, chunk_count: usize) -> usize {
    if index + 1 < chunk_count {
        CHUNK_PAYLOAD
    } else {
        frame_len - CHUNK_PAYLOAD * (chunk_count - 1)
    }
}

/// Longest chunk in parity group `g` (the parity payload length).
fn group_parity_len(frame_len: usize, chunk_count: usize, fec_k: usize, g: usize) -> usize {
    let lo = g * fec_k;
    let hi = ((g + 1) * fec_k).min(chunk_count);
    (lo..hi).map(|i| chunk_len(frame_len, i, chunk_count)).max().unwrap_or(0)
}

/// Split one framed message (`encode_frame` output) into datagrams.
///
/// Returns the data chunks in order; with `fec_k > 0`, each group of
/// `fec_k` consecutive chunks is followed by one parity datagram whose
/// payload is the XOR of the group's chunks zero-padded to the group's
/// longest chunk — any *single* lost chunk per group is recoverable at
/// the receiver without retransmit.
pub fn chunk_frame(
    frame: &[u8],
    session: &str,
    device_id: u32,
    frame_seq: u64,
    fec_k: u32,
) -> Result<Vec<Vec<u8>>> {
    ensure!(!session.is_empty() && session.len() <= 255, "session name must be 1..=255 bytes");
    ensure!(frame.len() >= 9, "frame shorter than the 9-byte SCMI header");
    ensure!(frame.len() <= MAX_FRAME, "frame too large: {}", frame.len());
    let chunk_count = expected_chunks(frame.len());
    let mut out = Vec::with_capacity(chunk_count + 1);
    let header = |kind: u8, chunk_index: u32, fec_group: u32, payload_len: usize| DgramHeader {
        kind,
        device_id,
        frame_seq,
        chunk_index,
        chunk_count: chunk_count as u32,
        frame_len: frame.len() as u32,
        fec_k,
        fec_group,
        payload_len: payload_len as u16,
        session: session.to_string(),
    };
    for i in 0..chunk_count {
        let lo = i * CHUNK_PAYLOAD;
        let hi = (lo + CHUNK_PAYLOAD).min(frame.len());
        let group = if fec_k > 0 { i as u32 / fec_k } else { 0 };
        out.push(encode_dgram(&header(KIND_DATA, i as u32, group, hi - lo), &frame[lo..hi]));
    }
    if fec_k > 0 {
        let k = fec_k as usize;
        let groups = chunk_count.div_ceil(k);
        for g in 0..groups {
            let plen = group_parity_len(frame.len(), chunk_count, k, g);
            let mut parity = vec![0u8; plen];
            for i in g * k..((g + 1) * k).min(chunk_count) {
                let lo = i * CHUNK_PAYLOAD;
                let hi = (lo + CHUNK_PAYLOAD).min(frame.len());
                for (p, &b) in parity.iter_mut().zip(&frame[lo..hi]) {
                    *p ^= b;
                }
            }
            out.push(encode_dgram(&header(KIND_PARITY, g as u32, g as u32), &parity));
        }
    }
    Ok(out)
}

/// One frame reassembled from datagrams, byte-identical to the sender's
/// `encode_frame` output.
#[derive(Clone, Debug, PartialEq)]
pub struct AssembledFrame {
    /// Session every datagram of the frame addressed.
    pub session: String,
    /// Sending device's slot.
    pub device_id: u32,
    /// Frame sequence number.
    pub frame_seq: u64,
    /// The complete framed wire form (`SCMI` magic onward).
    pub frame: Vec<u8>,
}

/// Assembler counters. The event-loop server mirrors these into its
/// metrics (`dgram_rx`, `dgram_stale_dropped`, `fec_recovered`,
/// `dgram_dup`) after each receive round; tests assert them exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DgramStats {
    /// Datagrams offered to [`DgramAssembler::feed`].
    pub rx: u64,
    /// Frames fully reassembled and delivered.
    pub delivered: u64,
    /// Stale traffic dropped under latest-wins: datagrams for a frame
    /// at or below the stream's newest delivered (or behind its current
    /// partial), plus one count per partially-assembled frame a newer
    /// frame superseded. Never integrated.
    pub stale_dropped: u64,
    /// Chunks reconstructed from XOR parity (one per recovered chunk).
    pub fec_recovered: u64,
    /// Duplicate datagrams ignored (chunk or parity already held).
    pub dup: u64,
    /// Datagrams dropped as unparseable or internally inconsistent.
    pub malformed: u64,
}

/// In-flight reassembly of one frame.
struct Partial {
    frame_seq: u64,
    chunk_count: usize,
    frame_len: usize,
    fec_k: u32,
    chunks: Vec<Option<Vec<u8>>>,
    /// Parity payload per group id.
    parity: HashMap<u32, Vec<u8>>,
}

impl Partial {
    fn new(h: &DgramHeader) -> Partial {
        Partial {
            frame_seq: h.frame_seq,
            chunk_count: h.chunk_count as usize,
            frame_len: h.frame_len as usize,
            fec_k: h.fec_k,
            chunks: vec![None; h.chunk_count as usize],
            parity: HashMap::new(),
        }
    }

    /// Geometry fields every datagram of one frame must agree on.
    fn consistent_with(&self, h: &DgramHeader) -> bool {
        self.chunk_count == h.chunk_count as usize
            && self.frame_len == h.frame_len as usize
            && self.fec_k == h.fec_k
    }

    /// Whether every missing chunk is recoverable (its group holds
    /// parity and it is the group's only gap). Recovery is deferred
    /// until it is decisive, so `fec_recovered` counts exactly the
    /// chunks that parity — not a late arrival — reconstructed.
    fn try_complete(&mut self, stats: &mut DgramStats) -> Option<Vec<u8>> {
        let k = self.fec_k as usize;
        let missing: Vec<usize> =
            (0..self.chunk_count).filter(|&i| self.chunks[i].is_none()).collect();
        if !missing.is_empty() {
            if k == 0 {
                return None;
            }
            for &m in &missing {
                let g = m / k;
                if !self.parity.contains_key(&(g as u32)) {
                    return None;
                }
                // Recoverable only as the group's single gap.
                if missing.iter().filter(|&&o| o / k == g).count() > 1 {
                    return None;
                }
            }
            for m in missing {
                let g = (m / k) as u32;
                let mut rec = self.parity[&g].clone();
                let lo = (g as usize) * k;
                let hi = (lo + k).min(self.chunk_count);
                for i in lo..hi {
                    if let Some(c) = &self.chunks[i] {
                        for (r, &b) in rec.iter_mut().zip(c) {
                            *r ^= b;
                        }
                    }
                }
                rec.truncate(chunk_len(self.frame_len, m, self.chunk_count));
                self.chunks[m] = Some(rec);
                stats.fec_recovered += 1;
            }
        }
        let mut frame = Vec::with_capacity(self.frame_len);
        for c in &self.chunks {
            frame.extend_from_slice(c.as_ref().expect("all chunks present"));
        }
        debug_assert_eq!(frame.len(), self.frame_len);
        Some(frame)
    }
}

#[derive(Default)]
struct StreamState {
    /// Newest frame sequence delivered on this stream; anything at or
    /// below it is stale by definition.
    newest_delivered: Option<u64>,
    partial: Option<Partial>,
}

/// Per-(session, device) datagram reassembly with latest-wins
/// replacement and single-loss XOR recovery.
///
/// Feed raw datagrams as they arrive — any order, duplicated, with
/// gaps; completed frames come back byte-identical to the sender's
/// framed form. Delivery per stream is strictly monotonic in
/// `frame_seq`: once a frame is delivered, no older frame of that
/// stream will ever be, and a newer frame's first datagram supersedes
/// (discards) any partially-assembled older frame. Malformed input is
/// dropped and counted, never panics, and never reads past the
/// datagram.
#[derive(Default)]
pub struct DgramAssembler {
    streams: HashMap<(String, u32), StreamState>,
    stats: DgramStats,
}

impl DgramAssembler {
    /// An empty assembler.
    pub fn new() -> DgramAssembler {
        DgramAssembler::default()
    }

    /// Counters of everything the assembler has done.
    pub fn stats(&self) -> DgramStats {
        self.stats
    }

    /// Frames currently partially assembled (observability / tests).
    pub fn partial_len(&self) -> usize {
        self.streams.values().filter(|s| s.partial.is_some()).count()
    }

    /// Offer one datagram; returns a frame when it completes one.
    pub fn feed(&mut self, dgram: &[u8]) -> Option<AssembledFrame> {
        self.stats.rx += 1;
        let (h, payload) = match parse_dgram(dgram) {
            Ok(p) => p,
            Err(_) => {
                self.stats.malformed += 1;
                return None;
            }
        };
        if !self.semantically_valid(&h) {
            self.stats.malformed += 1;
            return None;
        }

        let stream = self.streams.entry((h.session.clone(), h.device_id)).or_default();
        if stream.newest_delivered.is_some_and(|n| h.frame_seq <= n) {
            self.stats.stale_dropped += 1;
            return None;
        }
        match &stream.partial {
            Some(p) if p.frame_seq > h.frame_seq => {
                // Older than the frame being assembled: stale.
                self.stats.stale_dropped += 1;
                return None;
            }
            Some(p) if p.frame_seq < h.frame_seq => {
                // Latest wins: the superseded partial is counted as one
                // stale drop and discarded, never delivered.
                self.stats.stale_dropped += 1;
                stream.partial = Some(Partial::new(&h));
            }
            Some(p) if !p.consistent_with(&h) => {
                self.stats.malformed += 1;
                return None;
            }
            Some(_) => {}
            None => stream.partial = Some(Partial::new(&h)),
        }
        let partial = stream.partial.as_mut().expect("ensured above");

        if h.kind == KIND_PARITY {
            if partial.parity.contains_key(&h.fec_group) {
                self.stats.dup += 1;
                return None;
            }
            partial.parity.insert(h.fec_group, payload.to_vec());
        } else {
            let i = h.chunk_index as usize;
            if partial.chunks[i].is_some() {
                self.stats.dup += 1;
                return None;
            }
            partial.chunks[i] = Some(payload.to_vec());
        }

        let frame = partial.try_complete(&mut self.stats)?;
        let frame_seq = partial.frame_seq;
        stream.partial = None;
        stream.newest_delivered = Some(frame_seq);
        self.stats.delivered += 1;
        Some(AssembledFrame { session: h.session, device_id: h.device_id, frame_seq, frame })
    }

    /// Cross-field checks a well-formed sender can never violate:
    /// chunk geometry must match `frame_len`, FEC fields must agree.
    fn semantically_valid(&self, h: &DgramHeader) -> bool {
        let frame_len = h.frame_len as usize;
        let chunk_count = h.chunk_count as usize;
        if frame_len < 9 || frame_len > MAX_FRAME {
            return false;
        }
        if chunk_count != expected_chunks(frame_len) {
            return false;
        }
        if h.kind == KIND_PARITY {
            if h.fec_k == 0 {
                return false;
            }
            let groups = chunk_count.div_ceil(h.fec_k as usize);
            if h.fec_group as usize >= groups || h.chunk_index != h.fec_group {
                return false;
            }
            let plen = group_parity_len(frame_len, chunk_count, h.fec_k as usize, h.fec_group as usize);
            if h.payload_len as usize != plen {
                return false;
            }
        } else {
            let i = h.chunk_index as usize;
            if i >= chunk_count {
                return false;
            }
            if h.payload_len as usize != chunk_len(frame_len, i, chunk_count) {
                return false;
            }
            let want_group = if h.fec_k > 0 { h.chunk_index / h.fec_k } else { 0 };
            if h.fec_group != want_group {
                return false;
            }
        }
        true
    }
}

/// Datagram-level fault injection for the UDP uplink — the counterpart
/// of [`ImpairedLink`](super::ImpairedLink), operating on whole
/// datagrams instead of whole messages. Loss/`drop_every`, delay +
/// jitter, hold-one reorder, and duplication share the message-level
/// semantics; a `None` config is a transparent pass-through.
pub struct DgramImpairer {
    cfg: Option<ImpairConfig>,
    rng: Pcg64,
    /// A datagram held back for reordering, emitted after the next one.
    held: Option<Vec<u8>>,
    stats: ImpairStats,
}

impl DgramImpairer {
    /// Build an impairer; `None` passes every datagram through.
    pub fn new(cfg: Option<ImpairConfig>) -> DgramImpairer {
        let seed = cfg.as_ref().map(|c| c.seed).unwrap_or(0);
        DgramImpairer { cfg, rng: Pcg64::new(seed), held: None, stats: ImpairStats::default() }
    }

    /// What the impairer has done so far.
    pub fn stats(&self) -> ImpairStats {
        self.stats
    }

    /// Offer one datagram; `tx` is called zero, one or two times with
    /// the datagrams that actually reach the wire (in wire order).
    pub fn send(&mut self, dgram: Vec<u8>, tx: &mut dyn FnMut(&[u8]) -> Result<()>) -> Result<()> {
        let Some(cfg) = self.cfg else {
            return tx(&dgram);
        };
        self.stats.data_msgs += 1;
        let k = self.stats.data_msgs;
        let deterministic_drop = cfg.drop_every > 0 && k % cfg.drop_every == 0;
        if deterministic_drop || (cfg.loss > 0.0 && self.rng.uniform() < cfg.loss) {
            self.stats.dropped += 1;
            return Ok(());
        }
        if cfg.delay > Duration::ZERO || cfg.jitter > Duration::ZERO {
            let jitter = cfg.jitter.mul_f64(self.rng.uniform());
            std::thread::sleep(cfg.delay + jitter);
            self.stats.delayed += 1;
        }
        let duplicate = cfg.dup > 0.0 && self.rng.uniform() < cfg.dup;
        if cfg.reorder > 0.0 && self.held.is_none() && self.rng.uniform() < cfg.reorder {
            self.held = Some(dgram);
            self.stats.reordered += 1;
            return Ok(());
        }
        tx(&dgram)?;
        if duplicate {
            self.stats.duplicated += 1;
            tx(&dgram)?;
        }
        if let Some(h) = self.held.take() {
            tx(&h)?;
        }
        Ok(())
    }

    /// Flush a trailing held (reordered) datagram, if any.
    pub fn finish(&mut self, tx: &mut dyn FnMut(&[u8]) -> Result<()>) -> Result<()> {
        if let Some(h) = self.held.take() {
            tx(&h)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deterministic fake frame: SCMI header + patterned payload.
    fn frame_of(len: usize) -> Vec<u8> {
        assert!(len >= 9);
        let mut f = Vec::with_capacity(len);
        f.extend_from_slice(b"SCMI");
        f.push(2);
        f.extend_from_slice(&((len - 9) as u32).to_le_bytes());
        f.extend((9..len).map(|i| (i * 31 % 251) as u8));
        f
    }

    fn feed_all(asm: &mut DgramAssembler, dgrams: &[Vec<u8>]) -> Vec<AssembledFrame> {
        dgrams.iter().filter_map(|d| asm.feed(d)).collect()
    }

    #[test]
    fn header_roundtrip() {
        let h = DgramHeader {
            kind: KIND_DATA,
            device_id: 3,
            frame_seq: 42,
            chunk_index: 1,
            chunk_count: 2,
            frame_len: 1200,
            fec_k: 2,
            fec_group: 0,
            payload_len: 100,
            session: "north-7".into(),
        };
        let d = encode_dgram(&h, &[7u8; 100]);
        let (back, payload) = parse_dgram(&d).unwrap();
        assert_eq!(back, h);
        assert_eq!(payload, &[7u8; 100][..]);
    }

    #[test]
    fn datagrams_fit_the_mtu_budget_at_max_session_len() {
        let frame = frame_of(10 * CHUNK_PAYLOAD);
        let session = "s".repeat(255);
        for d in chunk_frame(&frame, &session, 0, 1, 4).unwrap() {
            assert!(d.len() <= MAX_DGRAM, "{} > {MAX_DGRAM}", d.len());
        }
    }

    #[test]
    fn in_order_reassembly_is_byte_identical() {
        for len in [9, 100, CHUNK_PAYLOAD, CHUNK_PAYLOAD + 1, 3 * CHUNK_PAYLOAD + 77] {
            let frame = frame_of(len);
            let dgrams = chunk_frame(&frame, "s", 1, 5, 0).unwrap();
            assert_eq!(dgrams.len(), expected_chunks(len));
            let mut asm = DgramAssembler::new();
            let got = feed_all(&mut asm, &dgrams);
            assert_eq!(got.len(), 1, "len {len}");
            assert_eq!(got[0].frame, frame, "len {len}");
            assert_eq!(got[0].frame_seq, 5);
            assert_eq!(asm.stats().delivered, 1);
            assert_eq!(asm.stats().malformed, 0);
        }
    }

    #[test]
    fn parity_recovers_any_single_chunk_loss() {
        let frame = frame_of(4 * CHUNK_PAYLOAD + 13);
        let k = 2u32;
        let dgrams = chunk_frame(&frame, "s", 0, 9, k).unwrap();
        let n_data = expected_chunks(frame.len());
        assert_eq!(dgrams.len(), n_data + n_data.div_ceil(k as usize));
        for drop in 0..n_data {
            let kept: Vec<Vec<u8>> = dgrams
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, d)| d.clone())
                .collect();
            let mut asm = DgramAssembler::new();
            let got = feed_all(&mut asm, &kept);
            assert_eq!(got.len(), 1, "dropped chunk {drop}");
            assert_eq!(got[0].frame, frame, "dropped chunk {drop}");
            assert_eq!(asm.stats().fec_recovered, 1, "dropped chunk {drop}");
        }
    }

    #[test]
    fn two_losses_in_one_group_never_complete_or_corrupt() {
        let frame = frame_of(4 * CHUNK_PAYLOAD);
        let dgrams = chunk_frame(&frame, "s", 0, 1, 4).unwrap();
        // Chunks 0 and 1 share the single k=4 group: unrecoverable.
        let kept: Vec<Vec<u8>> = dgrams[2..].to_vec();
        let mut asm = DgramAssembler::new();
        assert!(feed_all(&mut asm, &kept).is_empty());
        assert_eq!(asm.stats().fec_recovered, 0);
        assert_eq!(asm.stats().delivered, 0);
        assert_eq!(asm.partial_len(), 1, "stays partial, not corrupt");
    }

    #[test]
    fn newer_frame_supersedes_partial_and_stale_is_counted() {
        let f1 = frame_of(2 * CHUNK_PAYLOAD);
        let f2 = frame_of(2 * CHUNK_PAYLOAD + 5);
        let d1 = chunk_frame(&f1, "s", 0, 1, 0).unwrap();
        let d2 = chunk_frame(&f2, "s", 0, 2, 0).unwrap();
        let mut asm = DgramAssembler::new();
        assert!(asm.feed(&d1[0]).is_none());
        // First datagram of frame 2 discards the frame-1 partial.
        assert!(asm.feed(&d2[0]).is_none());
        assert_eq!(asm.stats().stale_dropped, 1, "superseded partial counted");
        // Late frame-1 traffic is stale, even though it was never done.
        assert!(asm.feed(&d1[1]).is_none());
        assert_eq!(asm.stats().stale_dropped, 2);
        let got = asm.feed(&d2[1]).unwrap();
        assert_eq!(got.frame, f2);
        // Anything at or below the delivered seq is stale.
        assert!(asm.feed(&d1[0]).is_none());
        assert!(asm.feed(&d2[0]).is_none());
        assert_eq!(asm.stats().stale_dropped, 4);
        assert_eq!(asm.stats().delivered, 1);
    }

    #[test]
    fn duplicates_are_counted_and_ignored() {
        let frame = frame_of(2 * CHUNK_PAYLOAD + 3);
        let dgrams = chunk_frame(&frame, "s", 0, 1, 2).unwrap();
        let mut asm = DgramAssembler::new();
        let mut doubled = Vec::new();
        for d in &dgrams {
            doubled.push(d.clone());
            doubled.push(d.clone());
        }
        let got = feed_all(&mut asm, &doubled);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].frame, frame);
        // Every second copy is a dup until the frame completes; copies
        // after completion are stale instead.
        assert_eq!(asm.stats().dup + asm.stats().stale_dropped, dgrams.len() as u64);
        assert_eq!(asm.stats().fec_recovered, 0, "dups must not trigger recovery");
    }

    #[test]
    fn streams_are_independent_per_session_and_device() {
        let f = frame_of(CHUNK_PAYLOAD + 1);
        let a = chunk_frame(&f, "a", 0, 1, 0).unwrap();
        let b = chunk_frame(&f, "b", 0, 1, 0).unwrap();
        let c = chunk_frame(&f, "a", 1, 1, 0).unwrap();
        let mut asm = DgramAssembler::new();
        let mut mixed = Vec::new();
        for i in 0..a.len() {
            mixed.extend([a[i].clone(), b[i].clone(), c[i].clone()]);
        }
        let got = feed_all(&mut asm, &mixed);
        assert_eq!(got.len(), 3);
        let mut keys: Vec<(String, u32)> =
            got.iter().map(|g| (g.session.clone(), g.device_id)).collect();
        keys.sort();
        assert_eq!(keys, vec![("a".into(), 0), ("a".into(), 1), ("b".into(), 0)]);
    }

    #[test]
    fn malformed_datagrams_are_counted_never_panic() {
        let frame = frame_of(2 * CHUNK_PAYLOAD);
        let dgrams = chunk_frame(&frame, "s", 0, 7, 2).unwrap();
        let mut asm = DgramAssembler::new();
        // Truncations of a valid datagram at every length.
        for cut in 0..dgrams[0].len() {
            assert!(asm.feed(&dgrams[0][..cut]).is_none());
        }
        // Bad magic / version / kind.
        for (at, v) in [(0usize, b'X'), (4, 99u8), (5, 7u8)] {
            let mut d = dgrams[0].clone();
            d[at] = v;
            assert!(asm.feed(&d).is_none());
        }
        let malformed_so_far = asm.stats().malformed;
        assert_eq!(malformed_so_far, dgrams[0].len() as u64 + 3);
        // The stream still works after the garbage.
        let got = feed_all(&mut asm, &dgrams);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].frame, frame);
    }

    #[test]
    fn inconsistent_geometry_is_malformed() {
        let frame = frame_of(3 * CHUNK_PAYLOAD);
        let dgrams = chunk_frame(&frame, "s", 0, 1, 0).unwrap();
        let mut asm = DgramAssembler::new();
        assert!(asm.feed(&dgrams[0]).is_none());
        // Re-encode chunk 1 claiming a different frame_len: same seq,
        // conflicting geometry.
        let (mut h, payload) = parse_dgram(&dgrams[1]).unwrap();
        h.frame_len += CHUNK_PAYLOAD as u32;
        h.chunk_count += 1;
        let forged = encode_dgram(&h, payload);
        assert!(asm.feed(&forged).is_none());
        assert_eq!(asm.stats().malformed, 1);
        // The honest remainder still completes the frame.
        let got = feed_all(&mut asm, &dgrams[1..]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].frame, frame);
    }

    #[test]
    fn impairer_duplicates_and_reorders_deterministically() {
        let mk = |i: u8| vec![i; 4];
        let mut out: Vec<Vec<u8>> = Vec::new();
        let mut tx = |d: &[u8]| {
            out.push(d.to_vec());
            Ok(())
        };
        let cfg = ImpairConfig { dup: 1.0, ..Default::default() };
        let mut imp = DgramImpairer::new(Some(cfg));
        imp.send(mk(1), &mut tx).unwrap();
        imp.send(mk(2), &mut tx).unwrap();
        assert_eq!(out, vec![mk(1), mk(1), mk(2), mk(2)]);
        assert_eq!(imp.stats().duplicated, 2);

        let mut out: Vec<Vec<u8>> = Vec::new();
        let mut tx = |d: &[u8]| {
            out.push(d.to_vec());
            Ok(())
        };
        let cfg = ImpairConfig { reorder: 1.0, ..Default::default() };
        let mut imp = DgramImpairer::new(Some(cfg));
        imp.send(mk(1), &mut tx).unwrap(); // held
        imp.send(mk(2), &mut tx).unwrap(); // sent, then releases 1
        imp.finish(&mut tx).unwrap();
        assert_eq!(out, vec![mk(2), mk(1)]);
        assert_eq!(imp.stats().reordered, 1);
    }

    #[test]
    fn impairer_drop_every_is_deterministic() {
        let mut n = 0usize;
        let mut tx = |_: &[u8]| {
            n += 1;
            Ok(())
        };
        let cfg = ImpairConfig { drop_every: 3, ..Default::default() };
        let mut imp = DgramImpairer::new(Some(cfg));
        for i in 0..9u8 {
            imp.send(vec![i], &mut tx).unwrap();
        }
        assert_eq!(n, 6);
        assert_eq!(imp.stats().dropped, 3);
    }
}
