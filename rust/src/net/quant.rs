//! Compressed intermediate outputs (paper §IV-E future work, implemented):
//! affine u8 quantization of feature maps before transmission — 4× less
//! wire time for the 1 MiB intermediate output at a bounded precision
//! cost (the stem features pass through a ReLU, so the range is one-sided
//! and quantizes well).

use crate::runtime::HostTensor;
use anyhow::Result;

/// A u8-quantized tensor: `value ≈ scale * q + min`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    pub shape: Vec<usize>,
    pub min: f32,
    pub scale: f32,
    pub data: Vec<u8>,
}

impl QuantTensor {
    pub fn byte_len(&self) -> usize {
        self.data.len() + self.shape.len() * 8 + 16
    }
}

/// Quantize a feature tensor to u8 with per-tensor affine mapping.
pub fn quantize(t: &HostTensor) -> QuantTensor {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in &t.data {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || lo == hi {
        // constant / empty tensor: scale 0 encodes "all = min"
        return QuantTensor {
            shape: t.shape.clone(),
            min: if lo.is_finite() { lo } else { 0.0 },
            scale: 0.0,
            data: vec![0; t.data.len()],
        };
    }
    let scale = (hi - lo) / 255.0;
    let inv = 1.0 / scale;
    let data = t
        .data
        .iter()
        .map(|&v| (((v - lo) * inv) + 0.5).clamp(0.0, 255.0) as u8)
        .collect();
    QuantTensor { shape: t.shape.clone(), min: lo, scale, data }
}

/// Reconstruct the f32 tensor.
pub fn dequantize(q: &QuantTensor) -> Result<HostTensor> {
    let data = q.data.iter().map(|&b| q.min + q.scale * b as f32).collect();
    HostTensor::new(q.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let t = HostTensor::new(vec![1000], data.clone()).unwrap();
        let q = quantize(&t);
        let back = dequantize(&q).unwrap();
        let max_err = data
            .iter()
            .zip(&back.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= q.scale * 0.5 + 1e-6, "err {max_err} vs step {}", q.scale);
    }

    #[test]
    fn relu_features_quantize_tightly() {
        // one-sided (post-ReLU) data with many zeros, like stem features
        let data: Vec<f32> =
            (0..4096).map(|i| if i % 7 == 0 { (i % 100) as f32 * 0.01 } else { 0.0 }).collect();
        let t = HostTensor::new(vec![4096], data.clone()).unwrap();
        let q = quantize(&t);
        let back = dequantize(&q).unwrap();
        // zeros must come back (almost) exactly: min == 0 -> q == 0 -> 0.0
        for (a, b) in data.iter().zip(&back.data) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn constant_tensor() {
        let t = HostTensor::new(vec![8], vec![2.5; 8]).unwrap();
        let q = quantize(&t);
        assert_eq!(q.scale, 0.0);
        let back = dequantize(&q).unwrap();
        assert!(back.data.iter().all(|&v| (v - 2.5).abs() < 1e-6));
    }

    #[test]
    fn payload_is_quarter_of_f32() {
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let q = quantize(&t);
        assert!(q.byte_len() * 4 < t.byte_len() * 11 / 10);
    }
}
