//! Compressed intermediate outputs (paper §IV-E future work, implemented):
//! affine u8 quantization of feature maps before transmission — 4× less
//! wire time for the 1 MiB intermediate output at a bounded precision
//! cost (the stem features pass through a ReLU, so the range is one-sided
//! and quantizes well).

use crate::runtime::HostTensor;
use anyhow::Result;

/// A u8-quantized tensor: `value ≈ scale * q + min`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantTensor {
    /// Dimensions, outermost first (matches the f32 tensor's).
    pub shape: Vec<usize>,
    /// Value decoded by code 0.
    pub min: f32,
    /// Step between adjacent codes (0 encodes a constant tensor).
    pub scale: f32,
    /// One u8 code per element, row-major.
    pub data: Vec<u8>,
}

impl QuantTensor {
    /// Approximate serialized size in bytes (payload accounting).
    pub fn byte_len(&self) -> usize {
        self.data.len() + self.shape.len() * 8 + 16
    }
}

/// Quantize a feature tensor to u8 with per-tensor affine mapping.
///
/// Non-finite inputs must not poison the mapping for the rest of the
/// tensor: the range is computed over *finite* values only (one stray
/// ±inf used to collapse the whole tensor onto the constant-encode
/// path), NaN encodes as the min code, and ±inf saturate to the range
/// ends.
pub fn quantize(t: &HostTensor) -> QuantTensor {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in &t.data {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() || !hi.is_finite() || lo == hi {
        // constant / empty / all-non-finite tensor: scale 0 encodes
        // "all = min"
        return QuantTensor {
            shape: t.shape.clone(),
            min: if lo.is_finite() { lo } else { 0.0 },
            scale: 0.0,
            data: vec![0; t.data.len()],
        };
    }
    let scale = (hi - lo) / 255.0;
    let inv = 1.0 / scale;
    let data = t
        .data
        .iter()
        .map(|&v| {
            if v.is_nan() {
                0
            } else {
                // ±inf saturate through the clamp to code 0 / 255.
                (((v - lo) * inv) + 0.5).clamp(0.0, 255.0) as u8
            }
        })
        .collect();
    QuantTensor { shape: t.shape.clone(), min: lo, scale, data }
}

/// Reconstruct the f32 tensor.
pub fn dequantize(q: &QuantTensor) -> Result<HostTensor> {
    let data = q.data.iter().map(|&b| q.min + q.scale * b as f32).collect();
    HostTensor::new(q.shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let t = HostTensor::new(vec![1000], data.clone()).unwrap();
        let q = quantize(&t);
        let back = dequantize(&q).unwrap();
        let max_err = data
            .iter()
            .zip(&back.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= q.scale * 0.5 + 1e-6, "err {max_err} vs step {}", q.scale);
    }

    #[test]
    fn relu_features_quantize_tightly() {
        // one-sided (post-ReLU) data with many zeros, like stem features
        let data: Vec<f32> =
            (0..4096).map(|i| if i % 7 == 0 { (i % 100) as f32 * 0.01 } else { 0.0 }).collect();
        let t = HostTensor::new(vec![4096], data.clone()).unwrap();
        let q = quantize(&t);
        let back = dequantize(&q).unwrap();
        // zeros must come back (almost) exactly: min == 0 -> q == 0 -> 0.0
        for (a, b) in data.iter().zip(&back.data) {
            if *a == 0.0 {
                assert_eq!(*b, 0.0);
            }
        }
    }

    #[test]
    fn constant_tensor() {
        let t = HostTensor::new(vec![8], vec![2.5; 8]).unwrap();
        let q = quantize(&t);
        assert_eq!(q.scale, 0.0);
        let back = dequantize(&q).unwrap();
        assert!(back.data.iter().all(|&v| (v - 2.5).abs() < 1e-6));
    }

    #[test]
    fn nan_inputs_encode_as_min_without_corrupting_the_range() {
        // Regression: NaN/inf feature values used to be able to poison
        // the min/max range; finite values must quantize exactly as if
        // the NaN were absent, and the NaN slot must decode to min.
        let clean = vec![0.0f32, 1.0, 2.0, 4.0];
        let dirty = vec![0.0f32, 1.0, f32::NAN, 2.0, 4.0];
        let q_clean = quantize(&HostTensor::new(vec![4], clean.clone()).unwrap());
        let q_dirty = quantize(&HostTensor::new(vec![5], dirty).unwrap());
        assert_eq!(q_dirty.min, q_clean.min);
        assert_eq!(q_dirty.scale, q_clean.scale);
        // Same codes for the shared finite values.
        assert_eq!(q_dirty.data[0], q_clean.data[0]);
        assert_eq!(q_dirty.data[1], q_clean.data[1]);
        assert_eq!(q_dirty.data[3], q_clean.data[2]);
        assert_eq!(q_dirty.data[4], q_clean.data[3]);
        // NaN slot carries the min code and decodes to min.
        assert_eq!(q_dirty.data[2], 0);
        let back = dequantize(&q_dirty).unwrap();
        assert_eq!(back.data[2], q_dirty.min);
    }

    #[test]
    fn infinity_saturates_instead_of_collapsing_range() {
        // Regression: one +inf made hi non-finite and collapsed the whole
        // tensor to the constant-encode path (everything decoded as min).
        let t = HostTensor::new(vec![4], vec![0.0, f32::INFINITY, 1.0, f32::NEG_INFINITY])
            .unwrap();
        let q = quantize(&t);
        assert!(q.scale > 0.0, "finite values must still define a range");
        assert_eq!(q.data[1], 255, "+inf saturates high");
        assert_eq!(q.data[3], 0, "-inf saturates low");
        let back = dequantize(&q).unwrap();
        assert!((back.data[0] - 0.0).abs() <= q.scale * 0.5 + 1e-6);
        assert!((back.data[2] - 1.0).abs() <= q.scale * 0.5 + 1e-6);
    }

    #[test]
    fn all_nan_tensor_encodes_as_constant_zero() {
        let t = HostTensor::new(vec![3], vec![f32::NAN; 3]).unwrap();
        let q = quantize(&t);
        assert_eq!(q.scale, 0.0);
        assert_eq!(q.min, 0.0);
        assert!(q.data.iter().all(|&b| b == 0));
        let back = dequantize(&q).unwrap();
        assert!(back.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn payload_is_quarter_of_f32() {
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let q = quantize(&t);
        assert!(q.byte_len() * 4 < t.byte_len() * 11 / 10);
    }
}
