//! Wire protocol between edge devices and the edge server, plus the
//! 1 Gbps-LAN bandwidth shaper used to emulate the paper's testbed link
//! on localhost TCP.

mod proto;
mod quant;
mod shaper;

pub use proto::{read_msg, write_msg, Msg, WireDetection, DEFAULT_SESSION, MAX_SESSION_NAME};
pub use quant::{dequantize, quantize, QuantTensor};
pub use shaper::ShapedWriter;
