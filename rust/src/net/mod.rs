//! Wire protocol between edge devices and the edge server, the
//! 1 Gbps-LAN bandwidth shaper used to emulate the paper's testbed link
//! on localhost TCP, the message-level fault-injection layer
//! ([`ImpairedLink`]) that lossy scenarios run their uplinks through,
//! the readiness [`poll`] layer the event-loop server stands on, and
//! the latest-wins [`dgram`] transport that carries feature frames over
//! UDP with optional XOR-parity FEC.

pub mod dgram;
mod impair;
pub mod poll;
mod proto;
mod quant;
mod shaper;
pub mod spec;

pub use dgram::{
    chunk_frame, AssembledFrame, DgramAssembler, DgramImpairer, DgramStats, CHUNK_PAYLOAD,
    MAX_DGRAM,
};
pub use impair::{ImpairConfig, ImpairStats, ImpairedLink};
pub use proto::{
    encode_frame, read_msg, write_msg, FrameAssembler, Msg, RawFrame, WireDetection,
    DEFAULT_SESSION, MAX_SESSION_NAME,
};
pub use quant::{dequantize, quantize, QuantTensor};
pub use shaper::ShapedWriter;
