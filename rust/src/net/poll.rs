//! Level-triggered readiness polling on std, no async runtime.
//!
//! The event-loop server (`coordinator/server.rs`) multiplexes every
//! connection on one thread. This module is the small OS-facing layer it
//! stands on:
//!
//! * [`Poller`] — a registration table of raw fds flattened into a
//!   `pollfd` array for `poll(2)` each iteration. Level-triggered: a
//!   socket with unread bytes (or writable space, if asked) reports
//!   ready on every call until the condition clears, so a loop that
//!   processes *some* of the data never loses the rest.
//! * [`Waker`] — the self-pipe. Worker threads (and the external stop
//!   handle) hold the write end of a `UnixStream` pair; one byte written
//!   there makes the read end — always in the poll set — readable and
//!   the poll call return immediately. This is what bounds stop latency
//!   and completion pickup by one poll wake instead of a sleep window.
//! * [`ReadyQueue`] — a mutex-protected queue with an *enqueue, then
//!   wake* discipline, paired with the consumer's *drain pipe, then
//!   drain queue* discipline. Ordered that way, a push between the
//!   consumer's queue drain and its next poll always leaves the pipe
//!   readable, so the wakeup cannot be lost (the loom model in
//!   `tests/loom.rs` explores exactly this handoff).
//! * [`TimerWheel`] — coarse tick-bucketed timers for things like the
//!   recurring session-deadline sweep; [`TimerWheel::next_timeout`]
//!   feeds the poll timeout so timers fire without a busy sleep.
//!
//! `poll(2)` is declared directly (std already links libc on unix; this
//! crate adds no dependencies), and the fd table is rebuilt per call —
//! O(connections) per iteration, which is the right trade below ~10k
//! fds and needs no epoll/kqueue portability shims.

use crate::sync::time::Instant;
use crate::sync::{lock_or_recover, Arc, Mutex};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::Read;
use std::io::Write;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::time::Duration;

/// Raw `poll(2)` binding. std links libc on every unix target, so the
/// symbol resolves without adding a crate dependency.
mod ffi {
    /// Matches C `struct pollfd` field-for-field.
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[cfg(target_os = "linux")]
    pub type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    pub type NfdsT = u32;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: std::os::raw::c_int) -> std::os::raw::c_int;
    }
}

/// Which readiness conditions a registration asks to be told about.
/// Hangup/error are always reported regardless.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Report when a read would make progress.
    pub readable: bool,
    /// Report when a write would make progress.
    pub writable: bool,
}

impl Interest {
    /// Read-readiness only — the steady state of every connection.
    pub const READ: Interest = Interest { readable: true, writable: false };
    /// Read- and write-readiness — a connection with queued outbound
    /// bytes that last hit `WouldBlock`.
    pub const READ_WRITE: Interest = Interest { readable: true, writable: true };
}

/// One readiness report from [`Poller::poll`].
#[derive(Copy, Clone, Debug)]
pub struct Event {
    /// The caller-chosen registration token.
    pub token: usize,
    /// A read would make progress.
    pub readable: bool,
    /// A write would make progress.
    pub writable: bool,
    /// Peer hung up, the fd errored, or the fd is invalid. The owner
    /// should read to EOF / close.
    pub hangup: bool,
}

struct Slot {
    token: usize,
    fd: RawFd,
    interest: Interest,
}

/// A level-triggered readiness poller over `poll(2)` with a built-in
/// self-pipe wake channel. Not thread-safe by design: it lives on the
/// event-loop thread, and other threads reach it only through the
/// [`Waker`] returned by [`Poller::new`].
pub struct Poller {
    slots: Vec<Slot>,
    wake_rx: UnixStream,
    /// Scratch `pollfd` array reused across calls.
    pollfds: Vec<ffi::PollFd>,
    /// Every [`Waker`] write end has been dropped; stop polling the pipe
    /// so its EOF cannot spin the loop.
    wake_closed: bool,
}

impl Poller {
    /// Build a poller and the [`Waker`] other threads use to interrupt
    /// it. Both pipe ends are nonblocking: a full pipe on wake is fine
    /// (the poller is already due to wake), and draining stops at
    /// `WouldBlock`.
    pub fn new() -> Result<(Poller, Waker)> {
        let (wake_rx, wake_tx) = UnixStream::pair().context("self-pipe pair")?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        let poller = Poller {
            slots: Vec::new(),
            wake_rx,
            pollfds: Vec::new(),
            wake_closed: false,
        };
        Ok((poller, Waker { tx: Arc::new(wake_tx) }))
    }

    /// Start watching `fd` under `token`. Tokens are caller-chosen and
    /// must be unique among live registrations.
    pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> Result<()> {
        anyhow::ensure!(
            !self.slots.iter().any(|s| s.token == token),
            "poller token {token} already registered"
        );
        self.slots.push(Slot { token, fd, interest });
        Ok(())
    }

    /// Change what `token`'s fd is watched for. Returns `false` if the
    /// token is not registered.
    pub fn set_interest(&mut self, token: usize, interest: Interest) -> bool {
        match self.slots.iter_mut().find(|s| s.token == token) {
            Some(s) => {
                s.interest = interest;
                true
            }
            None => false,
        }
    }

    /// Stop watching `token`. Returns `false` if it was not registered.
    pub fn deregister(&mut self, token: usize) -> bool {
        let before = self.slots.len();
        self.slots.retain(|s| s.token != token);
        self.slots.len() != before
    }

    /// Number of live registrations (excluding the wake pipe).
    pub fn registered(&self) -> usize {
        self.slots.len()
    }

    /// Block until an fd is ready, the wake pipe is written, or
    /// `timeout` elapses (`None` = wait indefinitely). Readiness lands
    /// in `events` (cleared first); the return value says whether a
    /// [`Waker`] fired, after draining the pipe so the level-triggered
    /// readable state clears.
    pub fn poll(&mut self, timeout: Option<Duration>, events: &mut Vec<Event>) -> Result<bool> {
        events.clear();
        self.pollfds.clear();
        let wake_in_set = !self.wake_closed;
        if wake_in_set {
            self.pollfds.push(ffi::PollFd {
                fd: self.wake_rx.as_raw_fd(),
                events: ffi::POLLIN,
                revents: 0,
            });
        }
        for s in &self.slots {
            let mut ev = 0i16;
            if s.interest.readable {
                ev |= ffi::POLLIN;
            }
            if s.interest.writable {
                ev |= ffi::POLLOUT;
            }
            self.pollfds.push(ffi::PollFd { fd: s.fd, events: ev, revents: 0 });
        }

        let timeout_ms = match timeout {
            None => -1,
            Some(d) => {
                // Round up so a sub-millisecond deadline sleeps 1 ms
                // instead of spinning at timeout 0.
                let mut ms = d.as_millis();
                if Duration::from_millis(ms as u64) < d {
                    ms += 1;
                }
                ms.min(i32::MAX as u128) as i32
            }
        };

        let rc = loop {
            // SAFETY: `pollfds` is a live, correctly-sized array of
            // `#[repr(C)]` pollfd structs; the kernel only writes the
            // `revents` fields within bounds.
            let rc = unsafe {
                ffi::poll(
                    self.pollfds.as_mut_ptr(),
                    self.pollfds.len() as ffi::NfdsT,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                break rc;
            }
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                continue; // EINTR: retry (worst case extends the timeout)
            }
            return Err(err).context("poll(2)");
        };
        if rc == 0 {
            return Ok(false); // timeout
        }

        let mut woken = false;
        if wake_in_set && self.pollfds[0].revents != 0 {
            woken = true;
            self.drain_wake_pipe();
        }
        let offset = if wake_in_set { 1 } else { 0 };
        for (slot, pfd) in self.slots.iter().zip(&self.pollfds[offset..]) {
            let re = pfd.revents;
            if re == 0 {
                continue;
            }
            events.push(Event {
                token: slot.token,
                readable: re & ffi::POLLIN != 0,
                writable: re & ffi::POLLOUT != 0,
                hangup: re & (ffi::POLLERR | ffi::POLLHUP | ffi::POLLNVAL) != 0,
            });
        }
        Ok(woken)
    }

    /// Consume queued wake bytes so the pipe's level-triggered readable
    /// state clears. Many wakes coalesce into one drain — the consumer
    /// re-checks all of its queues on any wake, so collapsing is safe.
    fn drain_wake_pipe(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => {
                    // Every write end dropped: EOF is permanent, so stop
                    // polling the pipe or it would report readable forever.
                    self.wake_closed = true;
                    return;
                }
                Ok(_) => continue,
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("wake pipe read failed: {e}");
                    self.wake_closed = true;
                    return;
                }
            }
        }
    }
}

/// The write end of a [`Poller`]'s self-pipe. Cheap to clone, safe to
/// use from any thread; [`Waker::wake`] never blocks.
#[derive(Clone)]
pub struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    /// Make the paired [`Poller::poll`] return now (or immediately on
    /// its next call). Best-effort by design: a full pipe means a wake
    /// is already pending, and a closed pipe means the poller is gone —
    /// neither is an error the caller can act on.
    pub fn wake(&self) {
        match (&*self.tx).write(&[1]) {
            Ok(_) => {}
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => log::debug!("waker write failed (poller gone?): {e}"),
        }
    }
}

/// Something that can interrupt a blocked consumer. [`Waker`] is the
/// production implementation; loom models substitute a modeled flag so
/// the wake/ready-queue handoff can be explored without real fds.
pub trait WakeSignal: Send + Sync {
    /// Nudge the consumer; must never block.
    fn wake(&self);
}

impl WakeSignal for Waker {
    fn wake(&self) {
        Waker::wake(self);
    }
}

/// A multi-producer queue whose pushes wake a polling consumer.
///
/// Protocol (loom-verified in `tests/loom.rs`):
/// * producer: enqueue the item **then** fire the signal;
/// * consumer: clear the signal (drain the pipe) **then** drain the
///   queue, and poll again only after both.
///
/// Any push that the consumer's drain misses therefore happened after
/// the drain began — which means its signal fired after the pipe was
/// cleared and is still pending, so the next poll wakes immediately.
/// No interleaving strands an item behind a sleeping consumer.
pub struct ReadyQueue<T> {
    items: Mutex<VecDeque<T>>,
    signal: Arc<dyn WakeSignal>,
}

impl<T> ReadyQueue<T> {
    /// A queue that fires `signal` after every push.
    pub fn new(signal: Arc<dyn WakeSignal>) -> ReadyQueue<T> {
        ReadyQueue { items: Mutex::new(VecDeque::new()), signal }
    }

    /// Enqueue `item`, then wake the consumer (in that order — the
    /// ordering is the no-lost-wakeup protocol, see the type docs).
    pub fn push(&self, item: T) {
        lock_or_recover(&self.items).push_back(item);
        self.signal.wake();
    }

    /// Move every queued item into `out` (appended in push order).
    /// Returns how many were taken.
    pub fn drain_into(&self, out: &mut Vec<T>) -> usize {
        let mut q = lock_or_recover(&self.items);
        let n = q.len();
        out.extend(q.drain(..));
        n
    }

    /// Queued item count right now (racy by nature; for stats only).
    pub fn len(&self) -> usize {
        lock_or_recover(&self.items).len()
    }

    /// Whether the queue is empty right now (racy by nature).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One scheduled timer: fires once, `rounds` full wheel revolutions
/// from now, when the cursor reaches its slot.
struct TimerEntry {
    rounds: u64,
    token: usize,
}

/// A coarse hashed timer wheel: `nslots` buckets of `tick` width.
/// Scheduling is O(1); [`TimerWheel::advance`] walks the buckets the
/// elapsed time covers. Resolution is one tick — deliberately coarse,
/// this drives 20 ms-scale deadline sweeps, not microsecond timers.
pub struct TimerWheel {
    tick: Duration,
    slots: Vec<Vec<TimerEntry>>,
    cursor: usize,
    /// When the slot under `cursor` expires.
    next_tick_at: Instant,
    armed: usize,
}

impl TimerWheel {
    /// A wheel of `nslots` buckets (clamped ≥ 1) of `tick` width each,
    /// with its clock origin at `now`.
    pub fn new(tick: Duration, nslots: usize, now: Instant) -> TimerWheel {
        let nslots = nslots.max(1);
        let tick = tick.max(Duration::from_millis(1));
        TimerWheel {
            tick,
            slots: (0..nslots).map(|_| Vec::new()).collect(),
            cursor: 0,
            next_tick_at: now + tick,
            armed: 0,
        }
    }

    /// Arm `token` to fire once, `after` from now (rounded up to the
    /// next tick; an `after` of zero still waits one tick).
    pub fn schedule(&mut self, after: Duration, token: usize) {
        let tick_ns = self.tick.as_nanos().max(1);
        let after_ns = after.as_nanos();
        let mut ticks = (after_ns / tick_ns) as u64;
        if after_ns % tick_ns != 0 {
            ticks += 1;
        }
        let ticks = ticks.max(1);
        let n = self.slots.len() as u64;
        let slot = (self.cursor as u64 + ticks) % n;
        let rounds = (ticks - 1) / n;
        self.slots[slot as usize].push(TimerEntry { rounds, token });
        self.armed += 1;
    }

    /// How long [`Poller::poll`] may sleep without missing a timer:
    /// time to the next tick boundary while any timer is armed, `None`
    /// (sleep on fds alone) when the wheel is empty.
    pub fn next_timeout(&self, now: Instant) -> Option<Duration> {
        if self.armed == 0 {
            return None;
        }
        Some(self.next_tick_at.saturating_duration_since(now).max(Duration::from_micros(100)))
    }

    /// Advance the wheel to `now`, appending every fired token to
    /// `fired` (slot order; ordering within one tick is unspecified).
    pub fn advance(&mut self, now: Instant, fired: &mut Vec<usize>) {
        while now >= self.next_tick_at {
            self.cursor = (self.cursor + 1) % self.slots.len();
            self.next_tick_at = self.next_tick_at + self.tick;
            let before = fired.len();
            let slot = &mut self.slots[self.cursor];
            slot.retain_mut(|e| {
                if e.rounds == 0 {
                    fired.push(e.token);
                    false
                } else {
                    e.rounds -= 1;
                    true
                }
            });
            let newly = fired.len() - before;
            self.armed -= newly.min(self.armed);
            if self.armed == 0 {
                // Idle wheel: snap the clock forward so a long quiet
                // period doesn't replay every missed tick one by one.
                while now >= self.next_tick_at {
                    self.next_tick_at = self.next_tick_at + self.tick;
                    self.cursor = (self.cursor + 1) % self.slots.len();
                }
                return;
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn waker_interrupts_a_long_poll() {
        let (mut poller, waker) = Poller::new().unwrap();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let t0 = std::time::Instant::now();
        let woken = poller.poll(Some(Duration::from_secs(5)), &mut events).unwrap();
        assert!(woken, "wake must be reported");
        assert!(events.is_empty(), "the wake pipe is not a caller event");
        assert!(t0.elapsed() < Duration::from_secs(2), "wake must cut the sleep short");
        t.join().unwrap();
    }

    #[test]
    fn poll_times_out_without_activity() {
        let (mut poller, _waker) = Poller::new().unwrap();
        let mut events = Vec::new();
        let woken = poller.poll(Some(Duration::from_millis(10)), &mut events).unwrap();
        assert!(!woken);
        assert!(events.is_empty());
    }

    #[test]
    fn readable_event_fires_on_data() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        let (mut poller, _waker) = Poller::new().unwrap();
        poller.register(server_side.as_raw_fd(), 7, Interest::READ).unwrap();
        let mut events = Vec::new();

        // Quiet socket: nothing readable.
        poller.poll(Some(Duration::from_millis(10)), &mut events).unwrap();
        assert!(events.is_empty());

        client.write_all(b"x").unwrap();
        poller.poll(Some(Duration::from_secs(5)), &mut events).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Level-triggered: unread data keeps reporting.
        poller.poll(Some(Duration::from_millis(10)), &mut events).unwrap();
        assert_eq!(events.len(), 1, "level-triggered readiness must persist");

        assert!(poller.deregister(7));
        poller.poll(Some(Duration::from_millis(10)), &mut events).unwrap();
        assert!(events.is_empty(), "deregistered fd must stop reporting");
        assert!(!poller.deregister(7), "double deregister reports absence");
    }

    #[test]
    fn writable_interest_reports_immediately_on_fresh_stream() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let _server_side = listener.accept().unwrap();

        let (mut poller, _waker) = Poller::new().unwrap();
        poller.register(client.as_raw_fd(), 3, Interest::READ_WRITE).unwrap();
        let mut events = Vec::new();
        poller.poll(Some(Duration::from_secs(5)), &mut events).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].writable, "fresh socket has send-buffer space");
        assert!(!events[0].readable);

        // Dropping write interest silences it again.
        assert!(poller.set_interest(3, Interest::READ));
        poller.poll(Some(Duration::from_millis(10)), &mut events).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn duplicate_tokens_rejected() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let (mut poller, _waker) = Poller::new().unwrap();
        poller.register(listener.as_raw_fd(), 1, Interest::READ).unwrap();
        assert!(poller.register(listener.as_raw_fd(), 1, Interest::READ).is_err());
    }

    #[test]
    fn ready_queue_delivers_and_signals() {
        struct Flag(std::sync::atomic::AtomicUsize);
        impl WakeSignal for Flag {
            fn wake(&self) {
                self.0.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            }
        }
        let flag = Arc::new(Flag(std::sync::atomic::AtomicUsize::new(0)));
        let q: ReadyQueue<u32> = ReadyQueue::new(flag.clone());
        assert!(q.is_empty());
        q.push(1);
        q.push(2);
        assert_eq!(flag.0.load(std::sync::atomic::Ordering::SeqCst), 2);
        assert_eq!(q.len(), 2);
        let mut out = Vec::new();
        assert_eq!(q.drain_into(&mut out), 2);
        assert_eq!(out, vec![1, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn timer_wheel_fires_in_order_and_disarms() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(10), 8, t0);
        assert!(wheel.next_timeout(t0).is_none(), "empty wheel sets no poll bound");
        wheel.schedule(Duration::from_millis(15), 100); // → 2 ticks
        wheel.schedule(Duration::from_millis(95), 200); // → 10 ticks (wraps + 1 round)
        assert!(wheel.next_timeout(t0).is_some());

        let mut fired = Vec::new();
        wheel.advance(t0 + Duration::from_millis(10), &mut fired);
        assert!(fired.is_empty(), "one tick is too early for either timer");
        wheel.advance(t0 + Duration::from_millis(20), &mut fired);
        assert_eq!(fired, vec![100]);

        fired.clear();
        wheel.advance(t0 + Duration::from_millis(80), &mut fired);
        assert!(fired.is_empty(), "wrapped timer must survive its first pass");
        wheel.advance(t0 + Duration::from_millis(100), &mut fired);
        assert_eq!(fired, vec![200]);
        assert!(wheel.next_timeout(t0 + Duration::from_millis(100)).is_none());
    }

    #[test]
    fn timer_wheel_zero_delay_waits_one_tick() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(10), 4, t0);
        wheel.schedule(Duration::ZERO, 1);
        let mut fired = Vec::new();
        wheel.advance(t0, &mut fired);
        assert!(fired.is_empty());
        wheel.advance(t0 + Duration::from_millis(10), &mut fired);
        assert_eq!(fired, vec![1]);
    }

    #[test]
    fn timer_wheel_rearm_supports_recurring_use() {
        // The server re-arms its deadline sweep after every fire; make
        // sure a schedule-from-advance cadence holds across wraps.
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(Duration::from_millis(10), 4, t0);
        wheel.schedule(Duration::from_millis(10), 9);
        let mut fired = Vec::new();
        let mut fires = 0;
        for step in 1..=12 {
            wheel.advance(t0 + Duration::from_millis(10 * step), &mut fired);
            for &t in &fired {
                assert_eq!(t, 9);
                fires += 1;
                wheel.schedule(Duration::from_millis(10), 9);
            }
            fired.clear();
        }
        assert_eq!(fires, 12, "a re-armed timer must fire once per period");
    }
}
