//! Parser for the machine-readable wire-protocol field table
//! (`docs/WIRE_PROTOCOL.md`, Appendix A).
//!
//! The table is the single source of truth for message field order and
//! the optional-trailing-field compatibility rules, and this parser is
//! deliberately shared verbatim by two adversarial consumers:
//!
//! * `cargo run -p xtask -- lint` includes this file via `#[path]` and
//!   cross-checks every row against the `encode_payload` /
//!   `Msg::type_byte` source in `net/proto.rs` — the table cannot drift
//!   from the code;
//! * `tests/wire_spec.rs` generates encode/decode round-trip property
//!   tests from the same rows across every legal optional-field
//!   combination — the code cannot drift from the table.
//!
//! Self-contained on purpose: no `crate::` paths, no external
//! dependencies, `String` errors — so the `xtask` crate (which must not
//! depend on the `scmii` library it lints) can compile it stand-alone.

/// Marker opening the machine-readable region of the protocol doc.
pub const SPEC_BEGIN: &str = "<!-- wire-spec-begin -->";
/// Marker closing the machine-readable region of the protocol doc.
pub const SPEC_END: &str = "<!-- wire-spec-end -->";

/// Every encoding name a table row may use. Each maps 1:1 to a
/// `put_<encoding>` helper in `net/proto.rs`.
pub const ENCODINGS: &[&str] =
    &["u32", "u64", "tensor", "qtensor", "detections", "session", "capture", "split"];

/// Marker opening the machine-readable datagram-header table.
pub const DGRAM_SPEC_BEGIN: &str = "<!-- dgram-spec-begin -->";
/// Marker closing the machine-readable datagram-header table.
pub const DGRAM_SPEC_END: &str = "<!-- dgram-spec-end -->";

/// Encodings the datagram-header table may use. Each maps 1:1 to a
/// `put_<encoding>` helper in `net/dgram.rs`. Every header field is
/// required — datagrams are self-describing, so the table carries no
/// presence column.
pub const DGRAM_ENCODINGS: &[&str] = &["u8", "u16", "u32", "u64", "session"];

/// One field row of the datagram-header table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DgramFieldSpec {
    /// Field name, matching the local the encoder passes to `put_*`.
    pub name: String,
    /// Encoding name (one of [`DGRAM_ENCODINGS`]).
    pub encoding: String,
}

/// Parse the datagram-header field table out of the protocol document.
///
/// Same contract as [`parse_spec_table`], for the datagram header: the
/// table between [`DGRAM_SPEC_BEGIN`]/[`DGRAM_SPEC_END`] is the single
/// source of truth for header field order, cross-checked against the
/// `put_header_fields` sequence in `net/dgram.rs` by the xtask lint and
/// exercised by `tests/wire_spec.rs` round-trips.
pub fn parse_dgram_spec(doc: &str) -> Result<Vec<DgramFieldSpec>, String> {
    let begin = doc
        .find(DGRAM_SPEC_BEGIN)
        .ok_or_else(|| format!("spec marker {DGRAM_SPEC_BEGIN:?} not found in document"))?;
    let rest = &doc[begin + DGRAM_SPEC_BEGIN.len()..];
    let end = rest.find(DGRAM_SPEC_END).ok_or_else(|| {
        format!("spec marker {DGRAM_SPEC_END:?} not found after {DGRAM_SPEC_BEGIN:?}")
    })?;
    let region = &rest[..end];

    let mut rows = region.lines().map(str::trim).filter(|l| l.starts_with('|'));
    let header = rows.next().ok_or("dgram spec region contains no table")?;
    let head_cells = cells(header);
    let want = ["field", "encoding"];
    if head_cells.iter().map(String::as_str).collect::<Vec<_>>() != want {
        return Err(format!("dgram spec table header must be {want:?}, got {head_cells:?}"));
    }
    let separator = rows.next().ok_or("dgram spec table missing separator row")?;
    if !cells(separator).iter().all(|c| !c.is_empty() && c.chars().all(|ch| ch == '-' || ch == ':'))
    {
        return Err(format!("second dgram spec row must be the |---| separator, got {separator:?}"));
    }

    let mut fields: Vec<DgramFieldSpec> = Vec::new();
    for row in rows {
        let c = cells(row);
        if c.len() != 2 {
            return Err(format!("dgram spec row must have 2 columns, got {} in {row:?}", c.len()));
        }
        let (name, encoding) = (&c[0], &c[1]);
        if name.is_empty() {
            return Err(format!("empty field name in dgram spec row {row:?}"));
        }
        if !DGRAM_ENCODINGS.contains(&encoding.as_str()) {
            return Err(format!(
                "unknown encoding {encoding:?} for dgram field {name} \
                 (want one of {DGRAM_ENCODINGS:?})"
            ));
        }
        if fields.iter().any(|f| f.name == *name) {
            return Err(format!("duplicate field {name:?} in dgram spec table"));
        }
        fields.push(DgramFieldSpec { name: name.clone(), encoding: encoding.clone() });
    }

    if fields.is_empty() {
        return Err("dgram spec table has no field rows".into());
    }
    Ok(fields)
}

/// Whether (and how) a field may be absent from a payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Presence {
    /// Always encoded; a payload ending before it is an error.
    Required,
    /// Trailing optional: always encoded by current writers, defaulted
    /// when an (older) writer's payload ends before it.
    Optional,
    /// Trailing optional that is additionally *omitted on encode* when
    /// its value is zero, keeping legacy payloads byte-identical.
    OptionalOmitZero,
}

impl Presence {
    /// Table-cell spelling of this presence class.
    pub fn as_str(&self) -> &'static str {
        match self {
            Presence::Required => "required",
            Presence::Optional => "optional",
            Presence::OptionalOmitZero => "optional-omit-zero",
        }
    }

    fn parse(s: &str) -> Result<Presence, String> {
        match s {
            "required" => Ok(Presence::Required),
            "optional" => Ok(Presence::Optional),
            "optional-omit-zero" => Ok(Presence::OptionalOmitZero),
            other => Err(format!(
                "unknown presence {other:?} (want required | optional | optional-omit-zero)"
            )),
        }
    }
}

/// One field row of the spec table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FieldSpec {
    /// Field name, matching the `Msg` variant's field identifier.
    pub name: String,
    /// Encoding name (one of [`ENCODINGS`]).
    pub encoding: String,
    /// Presence class.
    pub presence: Presence,
}

/// One wire message: its name, frame type byte, and ordered fields.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MessageSpec {
    /// Variant name, matching the `Msg` enum (`Hello`, `Features`, ...).
    pub name: String,
    /// The `type(1)` byte identifying this message in the frame header.
    pub type_byte: u8,
    /// Payload fields in encode order. Empty for payload-less messages.
    pub fields: Vec<FieldSpec>,
}

impl MessageSpec {
    /// The trailing optional fields, in order.
    pub fn optional_fields(&self) -> Vec<&FieldSpec> {
        self.fields.iter().filter(|f| f.presence != Presence::Required).collect()
    }
}

/// Split one `| a | b | c |` table row into trimmed cells.
fn cells(row: &str) -> Vec<String> {
    let row = row.trim();
    let row = row.strip_prefix('|').unwrap_or(row);
    let row = row.strip_suffix('|').unwrap_or(row);
    row.split('|').map(|c| c.trim().to_string()).collect()
}

/// Parse the spec table out of the full protocol document.
///
/// Beyond shape errors, this enforces the evolution invariants the
/// table exists to protect: messages are contiguous, type bytes are
/// unique and consistent, and within a message every optional field
/// trails every required one (optionals are append-only by
/// construction — a required field after an optional could never be
/// decoded compatibly).
pub fn parse_spec_table(doc: &str) -> Result<Vec<MessageSpec>, String> {
    let begin = doc
        .find(SPEC_BEGIN)
        .ok_or_else(|| format!("spec marker {SPEC_BEGIN:?} not found in document"))?;
    let rest = &doc[begin + SPEC_BEGIN.len()..];
    let end = rest
        .find(SPEC_END)
        .ok_or_else(|| format!("spec marker {SPEC_END:?} not found after {SPEC_BEGIN:?}"))?;
    let region = &rest[..end];

    let mut rows = region.lines().map(str::trim).filter(|l| l.starts_with('|'));
    let header = rows.next().ok_or("spec region contains no table")?;
    let head_cells = cells(header);
    let want = ["message", "type", "field", "encoding", "presence"];
    if head_cells.iter().map(String::as_str).collect::<Vec<_>>() != want {
        return Err(format!("spec table header must be {want:?}, got {head_cells:?}"));
    }
    let separator = rows.next().ok_or("spec table missing separator row")?;
    if !cells(separator).iter().all(|c| !c.is_empty() && c.chars().all(|ch| ch == '-' || ch == ':'))
    {
        return Err(format!("second spec row must be the |---| separator, got {separator:?}"));
    }

    let mut messages: Vec<MessageSpec> = Vec::new();
    for row in rows {
        let c = cells(row);
        if c.len() != 5 {
            return Err(format!("spec row must have 5 columns, got {} in {row:?}", c.len()));
        }
        let (name, ty, field, encoding, presence) = (&c[0], &c[1], &c[2], &c[3], &c[4]);
        if name.is_empty() {
            return Err(format!("empty message name in spec row {row:?}"));
        }
        let type_byte: u8 = ty
            .parse()
            .map_err(|_| format!("bad type byte {ty:?} for message {name:?}"))?;

        let is_new = match messages.last() {
            Some(last) if last.name == *name => {
                if last.type_byte != type_byte {
                    return Err(format!(
                        "message {name:?} listed with two type bytes ({} and {type_byte})",
                        last.type_byte
                    ));
                }
                false
            }
            _ => true,
        };
        if is_new {
            if messages.iter().any(|m| m.name == *name) {
                return Err(format!("rows of message {name:?} must be contiguous"));
            }
            if let Some(m) = messages.iter().find(|m| m.type_byte == type_byte) {
                return Err(format!(
                    "type byte {type_byte} used by both {:?} and {name:?}",
                    m.name
                ));
            }
            messages.push(MessageSpec { name: name.clone(), type_byte, fields: Vec::new() });
        }
        let msg = messages.last_mut().expect("just pushed or matched");

        // `-` in the field column declares a payload-less message.
        if field == "-" {
            if encoding != "-" || presence != "-" || !msg.fields.is_empty() {
                return Err(format!(
                    "payload-less marker row for {name:?} must be its only row, with `-` cells"
                ));
            }
            continue;
        }
        if !ENCODINGS.contains(&encoding.as_str()) {
            return Err(format!(
                "unknown encoding {encoding:?} for {name}.{field} (want one of {ENCODINGS:?})"
            ));
        }
        let presence = Presence::parse(presence)
            .map_err(|e| format!("{name}.{field}: {e}"))?;
        if msg.fields.iter().any(|f| f.name == *field) {
            return Err(format!("duplicate field {field:?} in message {name:?}"));
        }
        if presence == Presence::Required
            && msg.fields.iter().any(|f| f.presence != Presence::Required)
        {
            return Err(format!(
                "required field {name}.{field} after an optional field: optionals must trail \
                 (they are append-only)"
            ));
        }
        msg.fields.push(FieldSpec { name: field.clone(), encoding: encoding.clone(), presence });
    }

    if messages.is_empty() {
        return Err("spec table has no message rows".into());
    }
    Ok(messages)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(rows: &str) -> String {
        format!(
            "intro text\n{SPEC_BEGIN}\n\
             | message | type | field | encoding | presence |\n\
             |---|---|---|---|---|\n\
             {rows}\n{SPEC_END}\ntrailing text\n"
        )
    }

    #[test]
    fn parses_a_minimal_table() {
        let doc = table(
            "| Hello | 1 | device_id | u32 | required |\n\
             | Hello | 1 | session | session | optional |\n\
             | Bye | 5 | - | - | - |",
        );
        let spec = parse_spec_table(&doc).unwrap();
        assert_eq!(spec.len(), 2);
        assert_eq!(spec[0].name, "Hello");
        assert_eq!(spec[0].type_byte, 1);
        assert_eq!(spec[0].fields.len(), 2);
        assert_eq!(spec[0].fields[1].presence, Presence::Optional);
        assert_eq!(spec[0].optional_fields().len(), 1);
        assert_eq!(spec[1].name, "Bye");
        assert!(spec[1].fields.is_empty());
    }

    #[test]
    fn rejects_required_after_optional() {
        let doc = table(
            "| M | 1 | a | session | optional |\n\
             | M | 1 | b | u32 | required |",
        );
        let err = parse_spec_table(&doc).unwrap_err();
        assert!(err.contains("append-only"), "got: {err}");
    }

    #[test]
    fn rejects_reused_type_byte_and_split_messages() {
        let doc = table(
            "| A | 1 | x | u32 | required |\n\
             | B | 1 | y | u32 | required |",
        );
        assert!(parse_spec_table(&doc).unwrap_err().contains("type byte"));

        let doc = table(
            "| A | 1 | x | u32 | required |\n\
             | B | 2 | y | u32 | required |\n\
             | A | 1 | z | u32 | required |",
        );
        assert!(parse_spec_table(&doc).unwrap_err().contains("contiguous"));
    }

    #[test]
    fn rejects_unknown_encoding_and_presence() {
        let doc = table("| A | 1 | x | u16 | required |");
        assert!(parse_spec_table(&doc).unwrap_err().contains("unknown encoding"));
        let doc = table("| A | 1 | x | u32 | sometimes |");
        assert!(parse_spec_table(&doc).unwrap_err().contains("unknown presence"));
    }

    #[test]
    fn rejects_missing_markers() {
        assert!(parse_spec_table("no markers here").is_err());
        let doc = format!("{SPEC_BEGIN}\n| message | type | field | encoding | presence |\n");
        assert!(parse_spec_table(&doc).unwrap_err().contains("wire-spec-end"));
    }

    fn dgram_table(rows: &str) -> String {
        format!(
            "intro text\n{DGRAM_SPEC_BEGIN}\n\
             | field | encoding |\n\
             |---|---|\n\
             {rows}\n{DGRAM_SPEC_END}\ntrailing text\n"
        )
    }

    #[test]
    fn parses_a_minimal_dgram_table() {
        let doc = dgram_table(
            "| ver | u8 |\n\
             | frame_seq | u64 |\n\
             | session | session |",
        );
        let fields = parse_dgram_spec(&doc).unwrap();
        assert_eq!(fields.len(), 3);
        assert_eq!(fields[0], DgramFieldSpec { name: "ver".into(), encoding: "u8".into() });
        assert_eq!(fields[2].encoding, "session");
    }

    #[test]
    fn dgram_table_rejects_bad_rows() {
        let doc = dgram_table("| x | tensor |");
        assert!(parse_dgram_spec(&doc).unwrap_err().contains("unknown encoding"));
        let doc = dgram_table("| x | u8 |\n| x | u16 |");
        assert!(parse_dgram_spec(&doc).unwrap_err().contains("duplicate field"));
        assert!(parse_dgram_spec("no markers").unwrap_err().contains("dgram-spec-begin"));
        let doc = format!("{DGRAM_SPEC_BEGIN}\n| field | encoding |\n");
        assert!(parse_dgram_spec(&doc).unwrap_err().contains("dgram-spec-end"));
    }

    #[test]
    fn dgram_tables_do_not_collide_with_the_message_table() {
        let msg = table("| Hello | 1 | device_id | u32 | required |");
        let dg = dgram_table("| ver | u8 |");
        let doc = format!("{msg}\n{dg}");
        assert!(parse_spec_table(&doc).is_ok());
        assert!(parse_dgram_spec(&doc).is_ok());
    }
}
