//! Length-prefixed binary framing (serde is not in the image).
//!
//! Frame layout: `MAGIC(4) | type(1) | payload_len(4, LE) | payload`.
//! Tensors: `ndim(1) | dims(u32 LE each) | f32 LE data`.

use crate::runtime::HostTensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

const MAGIC: [u8; 4] = *b"SCMI";
/// Upper bound on a frame payload (guards against protocol desync).
const MAX_PAYLOAD: usize = 256 << 20;

/// A detection on the wire (matches `model::Detection`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireDetection {
    pub bbox: [f32; 7],
    pub score: f32,
    pub class_id: u32,
}

/// Protocol messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Device announces itself after connecting.
    Hello { device_id: u32 },
    /// Head-model output for one frame.
    Features { frame_id: u64, device_id: u32, tensor: HostTensor },
    /// u8-quantized head output (paper §IV-E compressed intermediate
    /// outputs — 4× smaller payload).
    FeaturesQ { frame_id: u64, device_id: u32, tensor: super::QuantTensor },
    /// Final detections for one frame (server → subscriber).
    Result { frame_id: u64, detections: Vec<WireDetection>, server_micros: u64 },
    /// A subscriber asks to receive `Result`s.
    Subscribe,
    /// Graceful shutdown.
    Bye,
}

impl Msg {
    fn type_byte(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Features { .. } => 2,
            Msg::Result { .. } => 3,
            Msg::Subscribe => 4,
            Msg::Bye => 5,
            Msg::FeaturesQ { .. } => 6,
        }
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &HostTensor) {
    buf.push(t.shape.len() as u8);
    for &d in &t.shape {
        put_u32(buf, d as u32);
    }
    // bulk-copy f32 data as LE bytes
    buf.reserve(t.data.len() * 4);
    for &v in &t.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated message");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn tensor(&mut self) -> Result<HostTensor> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        HostTensor::new(shape, data)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Serialize a message to its payload bytes (without framing).
pub fn encode_payload(msg: &Msg) -> Vec<u8> {
    let mut buf = Vec::new();
    match msg {
        Msg::Hello { device_id } => put_u32(&mut buf, *device_id),
        Msg::Features { frame_id, device_id, tensor } => {
            put_u64(&mut buf, *frame_id);
            put_u32(&mut buf, *device_id);
            put_tensor(&mut buf, tensor);
        }
        Msg::Result { frame_id, detections, server_micros } => {
            put_u64(&mut buf, *frame_id);
            put_u64(&mut buf, *server_micros);
            put_u32(&mut buf, detections.len() as u32);
            for d in detections {
                for v in d.bbox {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
                buf.extend_from_slice(&d.score.to_le_bytes());
                put_u32(&mut buf, d.class_id);
            }
        }
        Msg::FeaturesQ { frame_id, device_id, tensor } => {
            put_u64(&mut buf, *frame_id);
            put_u32(&mut buf, *device_id);
            buf.push(tensor.shape.len() as u8);
            for &d in &tensor.shape {
                put_u32(&mut buf, d as u32);
            }
            buf.extend_from_slice(&tensor.min.to_le_bytes());
            buf.extend_from_slice(&tensor.scale.to_le_bytes());
            buf.extend_from_slice(&tensor.data);
        }
        Msg::Subscribe | Msg::Bye => {}
    }
    buf
}

fn decode_payload(ty: u8, payload: &[u8]) -> Result<Msg> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let msg = match ty {
        1 => Msg::Hello { device_id: c.u32()? },
        2 => {
            let frame_id = c.u64()?;
            let device_id = c.u32()?;
            let tensor = c.tensor()?;
            Msg::Features { frame_id, device_id, tensor }
        }
        3 => {
            let frame_id = c.u64()?;
            let server_micros = c.u64()?;
            let n = c.u32()? as usize;
            if n > 100_000 {
                bail!("implausible detection count {n}");
            }
            let mut detections = Vec::with_capacity(n);
            for _ in 0..n {
                let mut bbox = [0.0f32; 7];
                for b in &mut bbox {
                    *b = c.f32()?;
                }
                let score = c.f32()?;
                let class_id = c.u32()?;
                detections.push(WireDetection { bbox, score, class_id });
            }
            Msg::Result { frame_id, detections, server_micros }
        }
        4 => Msg::Subscribe,
        5 => Msg::Bye,
        6 => {
            let frame_id = c.u64()?;
            let device_id = c.u32()?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let min = c.f32()?;
            let scale = c.f32()?;
            let n: usize = shape.iter().product();
            let data = c.take(n)?.to_vec();
            Msg::FeaturesQ {
                frame_id,
                device_id,
                tensor: super::QuantTensor { shape, min, scale, data },
            }
        }
        other => bail!("unknown message type {other}"),
    };
    c.done()?;
    Ok(msg)
}

/// Write one framed message.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let payload = encode_payload(msg);
    w.write_all(&MAGIC)?;
    w.write_all(&[msg.type_byte()])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read one framed message (blocking).
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let mut head = [0u8; 9];
    r.read_exact(&mut head).context("read frame header")?;
    if head[0..4] != MAGIC {
        bail!("bad magic {:?}", &head[0..4]);
    }
    let ty = head[4];
    let len = u32::from_le_bytes(head[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        bail!("payload too large: {len}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("read frame payload")?;
    decode_payload(ty, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let back = read_msg(&mut buf.as_slice()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn roundtrip_all_messages() {
        roundtrip(Msg::Hello { device_id: 3 });
        roundtrip(Msg::Subscribe);
        roundtrip(Msg::Bye);
        roundtrip(Msg::Features {
            frame_id: 42,
            device_id: 1,
            tensor: HostTensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap(),
        });
        roundtrip(Msg::FeaturesQ {
            frame_id: 43,
            device_id: 0,
            tensor: crate::net::QuantTensor {
                shape: vec![2, 2],
                min: -1.5,
                scale: 0.01,
                data: vec![0, 127, 200, 255],
            },
        });
        roundtrip(Msg::Result {
            frame_id: 7,
            server_micros: 1234,
            detections: vec![WireDetection {
                bbox: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.5],
                score: 0.9,
                class_id: 1,
            }],
        });
    }

    #[test]
    fn multiple_messages_in_stream() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Hello { device_id: 1 }).unwrap();
        write_msg(&mut buf, &Msg::Bye).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_msg(&mut r).unwrap(), Msg::Hello { device_id: 1 });
        assert_eq!(read_msg(&mut r).unwrap(), Msg::Bye);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Bye).unwrap();
        buf[0] = b'X';
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::Features {
                frame_id: 1,
                device_id: 0,
                tensor: HostTensor::zeros(&[4]),
            },
        )
        .unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_trailing_garbage_inside_payload() {
        // craft: Bye with nonzero payload
        let mut buf = Vec::new();
        buf.extend_from_slice(b"SCMI");
        buf.push(5);
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0, 0]);
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn feature_payload_size_matches_design() {
        // The 64x64x8x8 intermediate output should serialize to ~1 MiB.
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let payload = encode_payload(&Msg::Features { frame_id: 0, device_id: 0, tensor: t });
        assert!(payload.len() > (1 << 20) && payload.len() < (1 << 20) + 64);
    }

    #[test]
    fn quantized_payload_is_4x_smaller() {
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let full = encode_payload(&Msg::Features {
            frame_id: 0,
            device_id: 0,
            tensor: t.clone(),
        })
        .len();
        let q = crate::net::quantize(&t);
        let small =
            encode_payload(&Msg::FeaturesQ { frame_id: 0, device_id: 0, tensor: q }).len();
        assert!(small * 4 < full + 128, "quant {small} vs full {full}");
    }
}
