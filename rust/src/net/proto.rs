//! Length-prefixed binary framing (serde is not in the image).
//!
//! Frame layout: `MAGIC(4) | type(1) | payload_len(4, LE) | payload`.
//! Tensors: `ndim(1) | dims(u32 LE each) | f32 LE data`.
//!
//! ## Sessions on the wire
//!
//! `Hello`, `Features`, `FeaturesQ` and `Subscribe` carry the name of the
//! [`DetectorSession`](crate::coordinator::session::DetectorSession) they
//! address, encoded as a trailing `len(u8) | utf-8 bytes` string. The
//! field is *optional on decode*: a payload that ends before it yields
//! [`DEFAULT_SESSION`], so pre-session clients keep working against new
//! servers unchanged. (New clients always encode it, so new-client →
//! old-server is not supported — the compat direction the rollout needs.)
//!
//! ## Split depths
//!
//! `Hello` additionally carries a trailing `split` string naming the
//! split depth the device's head was cut at (see
//! `docs/WIRE_PROTOCOL.md` §"Split negotiation"). The field is optional
//! in *both* directions: absent on decode ⇒ `""` = "the default
//! depth", and an empty split is **omitted on encode**, so
//! default-depth devices produce `Hello` payloads byte-identical to the
//! pre-split wire form — legacy servers keep accepting them.
//!
//! ## Capture timestamps
//!
//! `Features`/`FeaturesQ` additionally carry a trailing `capture_micros`
//! (u64 LE, wall-clock µs of the device's frame capture), and `Result`
//! echoes the earliest stamp of the frame it resolves — the plumbing the
//! end-to-end latency accounting rides on. The field is optional in
//! *both* directions: absent on decode ⇒ 0 = "unstamped", and a zero
//! stamp is **omitted on encode**, so frames from unstamped (legacy)
//! devices produce `Result` payloads that are byte-identical to the
//! pre-stamp wire form — old subscribers keep decoding them. Only a
//! fleet whose devices actually stamp requires its subscribers to be
//! stamp-aware.

use crate::runtime::HostTensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

const MAGIC: [u8; 4] = *b"SCMI";
/// Upper bound on a frame payload (guards against protocol desync).
const MAX_PAYLOAD: usize = 256 << 20;

/// Session addressed by messages that omit the wire `session` field.
pub const DEFAULT_SESSION: &str = "default";

/// Longest session name accepted on the wire (u8 length prefix).
pub const MAX_SESSION_NAME: usize = 255;

/// A detection on the wire (matches `model::Detection`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireDetection {
    /// Box as `[x, y, z, dx, dy, dz, yaw]` in the common frame.
    pub bbox: [f32; 7],
    /// Classification confidence after sigmoid.
    pub score: f32,
    /// Class index into the model's anchor/class table.
    pub class_id: u32,
}

/// Protocol messages. The full byte-level layout — field order, the
/// optional-trailing-field compatibility rules, quantization encoding —
/// is specified in `docs/WIRE_PROTOCOL.md`.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Device announces itself after connecting.
    Hello {
        /// The device slot this worker claims.
        device_id: u32,
        /// Session the device will feed ([`DEFAULT_SESSION`] for legacy
        /// clients).
        session: String,
        /// Split depth the device's head was cut at (`""` — the field's
        /// omitted-on-wire zero value — means the server's default
        /// depth; legacy clients land there).
        split: String,
    },
    /// Head-model output for one frame.
    Features {
        /// Frame id the device stamped on this capture.
        frame_id: u64,
        /// Sending device's slot.
        device_id: u32,
        /// Full-precision intermediate output.
        tensor: HostTensor,
        /// Addressed session.
        session: String,
        /// Wall-clock frame-capture stamp in µs (0 = unstamped legacy
        /// client).
        capture_micros: u64,
    },
    /// u8-quantized head output (paper §IV-E compressed intermediate
    /// outputs — 4× smaller payload).
    FeaturesQ {
        /// Frame id the device stamped on this capture.
        frame_id: u64,
        /// Sending device's slot.
        device_id: u32,
        /// Quantized intermediate output.
        tensor: super::QuantTensor,
        /// Addressed session.
        session: String,
        /// Wall-clock frame-capture stamp in µs (0 = unstamped).
        capture_micros: u64,
    },
    /// Final detections for one frame (server → subscriber).
    Result {
        /// Frame these detections resolve.
        frame_id: u64,
        /// Decoded, NMS-filtered detections.
        detections: Vec<WireDetection>,
        /// Server-side tail-stage latency in µs (tail execution plus any
        /// micro-batching coalescing wait).
        server_micros: u64,
        /// Echo of the earliest device capture stamp of the frame (0
        /// when no device stamped it), so subscribers on the same clock
        /// domain can account capture → delivery latency.
        capture_micros: u64,
    },
    /// A subscriber asks to receive `Result`s for one session.
    Subscribe {
        /// Session to subscribe to.
        session: String,
    },
    /// Graceful shutdown.
    Bye,
}

impl Msg {
    /// The session this message addresses, if it carries one.
    fn session(&self) -> Option<&str> {
        match self {
            Msg::Hello { session, .. }
            | Msg::Features { session, .. }
            | Msg::FeaturesQ { session, .. }
            | Msg::Subscribe { session } => Some(session),
            Msg::Result { .. } | Msg::Bye => None,
        }
    }

    /// Check the message is encodable to a decodable wire form (the
    /// decoder rejects empty and >255-byte session names).
    pub fn validate(&self) -> Result<()> {
        if let Some(session) = self.session() {
            anyhow::ensure!(!session.is_empty(), "session name must be non-empty");
            anyhow::ensure!(
                session.len() <= MAX_SESSION_NAME,
                "session name longer than {MAX_SESSION_NAME} bytes"
            );
        }
        if let Msg::Hello { split, .. } = self {
            // Empty is legal here: it is the omitted-on-encode zero
            // value ("use the server's default depth").
            anyhow::ensure!(
                split.len() <= MAX_SESSION_NAME,
                "split name longer than {MAX_SESSION_NAME} bytes"
            );
        }
        Ok(())
    }

    fn type_byte(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Features { .. } => 2,
            Msg::Result { .. } => 3,
            Msg::Subscribe { .. } => 4,
            Msg::Bye => 5,
            Msg::FeaturesQ { .. } => 6,
        }
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Trailing capture stamp: omitted when 0 so unstamped messages stay
/// byte-identical to the pre-stamp wire form (legacy decoders reject
/// trailing bytes they don't know).
fn put_capture(buf: &mut Vec<u8>, capture_micros: u64) {
    if capture_micros > 0 {
        put_u64(buf, capture_micros);
    }
}

/// Trailing split-depth name: omitted when empty (= "default depth"),
/// so default-depth `Hello`s stay byte-identical to the pre-split wire
/// form (legacy decoders reject trailing bytes they don't know).
fn put_split(buf: &mut Vec<u8>, split: &str) {
    if split.is_empty() {
        return;
    }
    let bytes = split.as_bytes();
    // write_msg validates via Msg::validate; this assert only backstops
    // direct encode_payload callers.
    assert!(bytes.len() <= MAX_SESSION_NAME, "split name longer than 255 bytes");
    buf.push(bytes.len() as u8);
    buf.extend_from_slice(bytes);
}

fn put_session(buf: &mut Vec<u8>, session: &str) {
    let bytes = session.as_bytes();
    // write_msg validates via Msg::validate; this assert only backstops
    // direct encode_payload callers.
    assert!(bytes.len() <= MAX_SESSION_NAME, "session name longer than 255 bytes");
    buf.push(bytes.len() as u8);
    buf.extend_from_slice(bytes);
}

fn put_tensor(buf: &mut Vec<u8>, t: &HostTensor) {
    buf.push(t.shape.len() as u8);
    for &d in &t.shape {
        put_u32(buf, d as u32);
    }
    // bulk-copy f32 data as LE bytes
    buf.reserve(t.data.len() * 4);
    for &v in &t.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Quantized tensor: `ndim(1) | dims(u32 LE each) | min(f32) | scale(f32)
/// | u8 data`.
fn put_qtensor(buf: &mut Vec<u8>, t: &super::QuantTensor) {
    buf.push(t.shape.len() as u8);
    for &d in &t.shape {
        put_u32(buf, d as u32);
    }
    buf.extend_from_slice(&t.min.to_le_bytes());
    buf.extend_from_slice(&t.scale.to_le_bytes());
    buf.extend_from_slice(&t.data);
}

/// Detection list: `count(u32 LE)` then, per detection,
/// `bbox(7 × f32 LE) | score(f32 LE) | class_id(u32 LE)`.
fn put_detections(buf: &mut Vec<u8>, detections: &[WireDetection]) {
    put_u32(buf, detections.len() as u32);
    for d in detections {
        for v in d.bbox {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&d.score.to_le_bytes());
        put_u32(buf, d.class_id);
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated message");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn tensor(&mut self) -> Result<HostTensor> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = self.take(n * 4)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        HostTensor::new(shape, data)
    }

    /// Trailing session name; a payload ending here is a pre-session
    /// client and addresses [`DEFAULT_SESSION`].
    fn session_or_default(&mut self) -> Result<String> {
        if self.pos == self.buf.len() {
            return Ok(DEFAULT_SESSION.to_string());
        }
        let len = self.u8()? as usize;
        let bytes = self.take(len)?;
        let s = std::str::from_utf8(bytes).map_err(|_| anyhow::anyhow!("session name not utf-8"))?;
        if s.is_empty() {
            bail!("empty session name");
        }
        Ok(s.to_string())
    }

    /// Trailing split-depth name; a payload ending here is a pre-split
    /// (or default-depth) client and decodes as `""` = "default depth".
    /// An explicit zero-length name is rejected — the default depth is
    /// spelled by omitting the field, keeping the encoding canonical.
    fn split_or_empty(&mut self) -> Result<String> {
        if self.pos == self.buf.len() {
            return Ok(String::new());
        }
        let len = self.u8()? as usize;
        if len == 0 {
            bail!("empty split name (omit the field to request the default depth)");
        }
        let bytes = self.take(len)?;
        let s = std::str::from_utf8(bytes).map_err(|_| anyhow::anyhow!("split name not utf-8"))?;
        Ok(s.to_string())
    }

    /// Trailing capture timestamp; a payload ending here predates the
    /// stamp and decodes as 0 ("unstamped").
    fn capture_or_zero(&mut self) -> Result<u64> {
        if self.pos == self.buf.len() {
            return Ok(0);
        }
        self.u64()
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Serialize a message to its payload bytes (without framing).
///
/// Every match arm below must be a flat, ordered sequence of
/// `put_*(&mut buf, field)` calls: `xtask lint` parses this function and
/// cross-checks each arm's field order and encodings against the
/// machine-readable spec table in `docs/WIRE_PROTOCOL.md`. Inlining an
/// encoding here (instead of adding a `put_*` helper and a spec row)
/// fails the lint by design — the spec cannot describe what it cannot
/// see.
pub fn encode_payload(msg: &Msg) -> Vec<u8> {
    let mut buf = Vec::new();
    match msg {
        Msg::Hello { device_id, session, split } => {
            put_u32(&mut buf, *device_id);
            put_session(&mut buf, session);
            put_split(&mut buf, split);
        }
        Msg::Features { frame_id, device_id, tensor, session, capture_micros } => {
            put_u64(&mut buf, *frame_id);
            put_u32(&mut buf, *device_id);
            put_tensor(&mut buf, tensor);
            put_session(&mut buf, session);
            put_capture(&mut buf, *capture_micros);
        }
        Msg::Result { frame_id, detections, server_micros, capture_micros } => {
            put_u64(&mut buf, *frame_id);
            put_u64(&mut buf, *server_micros);
            put_detections(&mut buf, detections);
            put_capture(&mut buf, *capture_micros);
        }
        Msg::FeaturesQ { frame_id, device_id, tensor, session, capture_micros } => {
            put_u64(&mut buf, *frame_id);
            put_u32(&mut buf, *device_id);
            put_qtensor(&mut buf, tensor);
            put_session(&mut buf, session);
            put_capture(&mut buf, *capture_micros);
        }
        Msg::Subscribe { session } => {
            put_session(&mut buf, session);
        }
        Msg::Bye => {}
    }
    buf
}

fn decode_payload(ty: u8, payload: &[u8]) -> Result<Msg> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let msg = match ty {
        1 => {
            let device_id = c.u32()?;
            let session = c.session_or_default()?;
            let split = c.split_or_empty()?;
            Msg::Hello { device_id, session, split }
        }
        2 => {
            let frame_id = c.u64()?;
            let device_id = c.u32()?;
            let tensor = c.tensor()?;
            let session = c.session_or_default()?;
            let capture_micros = c.capture_or_zero()?;
            Msg::Features { frame_id, device_id, tensor, session, capture_micros }
        }
        3 => {
            let frame_id = c.u64()?;
            let server_micros = c.u64()?;
            let n = c.u32()? as usize;
            if n > 100_000 {
                bail!("implausible detection count {n}");
            }
            let mut detections = Vec::with_capacity(n);
            for _ in 0..n {
                let mut bbox = [0.0f32; 7];
                for b in &mut bbox {
                    *b = c.f32()?;
                }
                let score = c.f32()?;
                let class_id = c.u32()?;
                detections.push(WireDetection { bbox, score, class_id });
            }
            let capture_micros = c.capture_or_zero()?;
            Msg::Result { frame_id, detections, server_micros, capture_micros }
        }
        4 => Msg::Subscribe { session: c.session_or_default()? },
        5 => Msg::Bye,
        6 => {
            let frame_id = c.u64()?;
            let device_id = c.u32()?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let min = c.f32()?;
            let scale = c.f32()?;
            let n: usize = shape.iter().product();
            let data = c.take(n)?.to_vec();
            let session = c.session_or_default()?;
            let capture_micros = c.capture_or_zero()?;
            Msg::FeaturesQ {
                frame_id,
                device_id,
                tensor: super::QuantTensor { shape, min, scale, data },
                session,
                capture_micros,
            }
        }
        other => bail!("unknown message type {other}"),
    };
    c.done()?;
    Ok(msg)
}

/// Serialize one message to its complete framed wire form (magic + type +
/// length + payload). Fails on messages the peer could not decode, e.g.
/// an empty or oversized session name. The fault-injection layer
/// ([`ImpairedLink`](super::ImpairedLink)) uses this to hold/reorder
/// whole frames.
pub fn encode_frame(msg: &Msg) -> Result<Vec<u8>> {
    msg.validate()?;
    let payload = encode_payload(msg);
    let mut buf = Vec::with_capacity(payload.len() + 9);
    buf.extend_from_slice(&MAGIC);
    buf.push(msg.type_byte());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// Write one framed message. Fails (without writing) on messages the
/// peer could not decode, e.g. an empty or oversized session name.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    let frame = encode_frame(msg)?;
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Fill `buf` from `r`. With `idle_ok`, a timeout (`WouldBlock` /
/// `TimedOut`) before the first byte propagates so idle pollers can back
/// off and re-check shutdown flags. Once any byte of the frame has been
/// consumed — or when `idle_ok` is false (payload follows a header) —
/// timeouts are retried with a bounded budget, so a slow link (e.g. a
/// bandwidth-shaped 1 MiB feature map spanning many read-timeout
/// windows) cannot desync the stream mid-message.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8], idle_ok: bool, what: &str) -> Result<()> {
    let mut filled = 0usize;
    let mut stalls = 0u32;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => bail!(
                "connection closed while reading {what} ({filled}/{} bytes)",
                buf.len()
            ),
            Ok(n) => {
                filled += n;
                stalls = 0;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if filled == 0 && idle_ok {
                    return Err(e.into());
                }
                stalls += 1;
                // ~40 read-timeout windows (≥10 s at the server's 250 ms
                // read timeout): the peer stalled mid-frame; give up
                // rather than wait forever.
                if stalls > 40 {
                    bail!("peer stalled mid-{what} ({filled}/{} bytes)", buf.len());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).with_context(|| format!("read {what}")),
        }
    }
    Ok(())
}

/// Read one framed message (blocking; timeout-tolerant mid-frame).
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    let mut head = [0u8; 9];
    read_full(r, &mut head, true, "frame header")?;
    if head[0..4] != MAGIC {
        bail!("bad magic {:?}", &head[0..4]);
    }
    let ty = head[4];
    let len = u32::from_le_bytes(head[5..9].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        bail!("payload too large: {len}");
    }
    let mut payload = vec![0u8; len];
    read_full(r, &mut payload, false, "frame payload")?;
    decode_payload(ty, &payload)
}

/// One complete frame lifted off the wire but not yet decoded: the type
/// byte plus the raw payload. Produced by [`FrameAssembler`]; the
/// event-loop server hands these to worker threads so tensor decoding
/// happens off the loop, and tees them into trace captures byte-for-byte
/// (no decode/re-encode round trip).
#[derive(Clone, Debug, PartialEq)]
pub struct RawFrame {
    /// Wire type byte (see `docs/WIRE_PROTOCOL.md`).
    pub ty: u8,
    /// Payload bytes exactly as received.
    pub payload: Vec<u8>,
}

impl RawFrame {
    /// Decode the payload into a [`Msg`] (same validation as
    /// [`read_msg`]).
    pub fn decode(&self) -> Result<Msg> {
        decode_payload(self.ty, &self.payload)
    }

    /// Whether this is an intermediate-output frame (`Features` /
    /// `FeaturesQ`) — the heavyweight kind the server decodes on worker
    /// threads and tees into trace captures.
    pub fn is_features(&self) -> bool {
        matches!(self.ty, 2 | 6)
    }

    /// The complete framed wire form (magic + type + length + payload),
    /// byte-identical to what the peer sent.
    pub fn framed_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.payload.len() + 9);
        buf.extend_from_slice(&MAGIC);
        buf.push(self.ty);
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }
}

/// Incremental frame reassembly for nonblocking sockets.
///
/// [`read_msg`] owns the blocking path (read exactly one frame, waiting
/// as needed); this is its event-loop counterpart: [`feed`] whatever
/// bytes a readiness-driven read produced — any split, down to one byte
/// at a time — then pull zero or more complete [`RawFrame`]s with
/// [`next_frame`]. Validation (magic, type-agnostic length bound) is the
/// same as the blocking path; an error means the stream desynced and the
/// connection must be dropped, exactly as a `read_msg` error does.
///
/// [`feed`]: FrameAssembler::feed
/// [`next_frame`]: FrameAssembler::next_frame
#[derive(Default)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed by yielded frames; compacted on
    /// the next `feed` so parsing never re-copies per frame.
    pos: usize,
}

impl FrameAssembler {
    /// An empty assembler.
    pub fn new() -> FrameAssembler {
        FrameAssembler::default()
    }

    /// Append bytes read from the socket.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    /// An `Err` is a protocol desync (bad magic / oversized payload):
    /// the stream cannot be trusted past this point.
    pub fn next_frame(&mut self) -> Result<Option<RawFrame>> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 9 {
            return Ok(None);
        }
        if avail[0..4] != MAGIC {
            bail!("bad magic {:?}", &avail[0..4]);
        }
        let ty = avail[4];
        let len = u32::from_le_bytes(avail[5..9].try_into().unwrap()) as usize;
        if len > MAX_PAYLOAD {
            bail!("payload too large: {len}");
        }
        if avail.len() < 9 + len {
            return Ok(None);
        }
        let payload = avail[9..9 + len].to_vec();
        self.pos += 9 + len;
        Ok(Some(RawFrame { ty, payload }))
    }

    /// Bytes buffered but not yet yielded as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let back = read_msg(&mut buf.as_slice()).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn roundtrip_all_messages() {
        roundtrip(Msg::Hello {
            device_id: 3,
            session: DEFAULT_SESSION.into(),
            split: String::new(),
        });
        roundtrip(Msg::Hello {
            device_id: 3,
            session: "intersection-7".into(),
            split: String::new(),
        });
        roundtrip(Msg::Hello {
            device_id: 1,
            session: "intersection-7".into(),
            split: "split-deep".into(),
        });
        roundtrip(Msg::Subscribe { session: DEFAULT_SESSION.into() });
        roundtrip(Msg::Subscribe { session: "aux".into() });
        roundtrip(Msg::Bye);
        roundtrip(Msg::Features {
            frame_id: 42,
            device_id: 1,
            tensor: HostTensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap(),
            session: "intersection-7".into(),
            capture_micros: 1_700_000_000_000_001,
        });
        roundtrip(Msg::FeaturesQ {
            frame_id: 43,
            device_id: 0,
            tensor: crate::net::QuantTensor {
                shape: vec![2, 2],
                min: -1.5,
                scale: 0.01,
                data: vec![0, 127, 200, 255],
            },
            session: DEFAULT_SESSION.into(),
            capture_micros: 0,
        });
        roundtrip(Msg::Result {
            frame_id: 7,
            server_micros: 1234,
            detections: vec![WireDetection {
                bbox: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.5],
                score: 0.9,
                class_id: 1,
            }],
            capture_micros: 99,
        });
        roundtrip(Msg::Result {
            frame_id: 8,
            server_micros: 0,
            detections: vec![],
            capture_micros: 0,
        });
    }

    #[test]
    fn multiple_messages_in_stream() {
        let hello =
            Msg::Hello { device_id: 1, session: DEFAULT_SESSION.into(), split: String::new() };
        let mut buf = Vec::new();
        write_msg(&mut buf, &hello).unwrap();
        write_msg(&mut buf, &Msg::Bye).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_msg(&mut r).unwrap(), hello);
        assert_eq!(read_msg(&mut r).unwrap(), Msg::Bye);
    }

    #[test]
    fn assembler_matches_blocking_reader() {
        let msgs = vec![
            Msg::Hello { device_id: 2, session: "north".into(), split: "split-shallow".into() },
            Msg::Features {
                frame_id: 9,
                device_id: 0,
                tensor: HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
                session: DEFAULT_SESSION.into(),
                capture_micros: 777,
            },
            Msg::Bye,
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_msg(&mut wire, m).unwrap();
        }
        let mut asm = FrameAssembler::new();
        asm.feed(&wire);
        for expect in &msgs {
            let frame = asm.next_frame().unwrap().expect("complete frame buffered");
            assert_eq!(&frame.decode().unwrap(), expect);
        }
        assert!(asm.next_frame().unwrap().is_none());
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn assembler_survives_byte_at_a_time_delivery() {
        let msg = Msg::Features {
            frame_id: 5,
            device_id: 1,
            tensor: HostTensor::new(vec![3], vec![0.5, -0.5, 9.0]).unwrap(),
            session: "s".into(),
            capture_micros: 0,
        };
        let mut wire = Vec::new();
        write_msg(&mut wire, &msg).unwrap();
        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for b in &wire {
            asm.feed(std::slice::from_ref(b));
            while let Some(f) = asm.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].decode().unwrap(), msg);
    }

    #[test]
    fn assembler_framed_bytes_are_byte_identical() {
        let msg = Msg::FeaturesQ {
            frame_id: 11,
            device_id: 1,
            tensor: crate::net::QuantTensor {
                shape: vec![2],
                min: 0.0,
                scale: 0.5,
                data: vec![7, 9],
            },
            session: "tee".into(),
            capture_micros: 123,
        };
        let wire = encode_frame(&msg).unwrap();
        let mut asm = FrameAssembler::new();
        asm.feed(&wire);
        let frame = asm.next_frame().unwrap().unwrap();
        assert!(frame.is_features());
        assert_eq!(frame.framed_bytes(), wire, "trace tee must reproduce the wire exactly");
    }

    #[test]
    fn assembler_rejects_desynced_streams() {
        let mut asm = FrameAssembler::new();
        asm.feed(b"XXXXHELLO-not-a-frame");
        assert!(asm.next_frame().is_err(), "bad magic must error, not scan forward");

        let mut asm = FrameAssembler::new();
        let mut head = Vec::new();
        head.extend_from_slice(&MAGIC);
        head.push(3);
        head.extend_from_slice(&(u32::MAX).to_le_bytes());
        asm.feed(&head);
        assert!(asm.next_frame().is_err(), "oversized payload length must error");
    }

    /// Hand-build a frame the way pre-session clients did (payload
    /// without the trailing session string).
    fn legacy_frame(ty: u8, payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(ty);
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        buf
    }

    #[test]
    fn legacy_messages_decode_to_default_session() {
        // Hello: just the device id.
        let buf = legacy_frame(1, &5u32.to_le_bytes());
        assert_eq!(
            read_msg(&mut buf.as_slice()).unwrap(),
            Msg::Hello { device_id: 5, session: DEFAULT_SESSION.into(), split: String::new() }
        );

        // Subscribe: empty payload.
        let buf = legacy_frame(4, &[]);
        assert_eq!(
            read_msg(&mut buf.as_slice()).unwrap(),
            Msg::Subscribe { session: DEFAULT_SESSION.into() }
        );

        // Features: frame id, device id, tensor — nothing after the data.
        let tensor = HostTensor::new(vec![2, 2], vec![0.5, -0.5, 1.0, 0.0]).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&9u64.to_le_bytes());
        payload.extend_from_slice(&1u32.to_le_bytes());
        put_tensor(&mut payload, &tensor);
        let buf = legacy_frame(2, &payload);
        assert_eq!(
            read_msg(&mut buf.as_slice()).unwrap(),
            Msg::Features {
                frame_id: 9,
                device_id: 1,
                tensor,
                session: DEFAULT_SESSION.into(),
                capture_micros: 0,
            }
        );

        // FeaturesQ: quant tensor with no trailing session.
        let q = crate::net::QuantTensor {
            shape: vec![3],
            min: 0.0,
            scale: 0.5,
            data: vec![0, 1, 2],
        };
        let mut payload = Vec::new();
        payload.extend_from_slice(&11u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.push(1);
        payload.extend_from_slice(&3u32.to_le_bytes());
        payload.extend_from_slice(&q.min.to_le_bytes());
        payload.extend_from_slice(&q.scale.to_le_bytes());
        payload.extend_from_slice(&q.data);
        let buf = legacy_frame(6, &payload);
        assert_eq!(
            read_msg(&mut buf.as_slice()).unwrap(),
            Msg::FeaturesQ {
                frame_id: 11,
                device_id: 0,
                tensor: q,
                session: DEFAULT_SESSION.into(),
                capture_micros: 0,
            }
        );
    }

    #[test]
    fn unstamped_result_is_byte_identical_to_legacy_form() {
        // The server->subscriber direction must stay decodable by old
        // subscribers when no device stamped the frame: a zero stamp is
        // omitted on encode, leaving the pre-stamp byte layout (whose
        // strict done() check rejects unknown trailing bytes).
        let msg = Msg::Result {
            frame_id: 5,
            server_micros: 77,
            detections: vec![],
            capture_micros: 0,
        };
        let payload = encode_payload(&msg);
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&5u64.to_le_bytes());
        legacy.extend_from_slice(&77u64.to_le_bytes());
        legacy.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(payload, legacy, "zero stamp must not add trailing bytes");

        // A stamped Result round-trips with the stamp intact.
        let stamped = Msg::Result {
            frame_id: 5,
            server_micros: 77,
            detections: vec![],
            capture_micros: 123_456,
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &stamped).unwrap();
        assert_eq!(read_msg(&mut buf.as_slice()).unwrap(), stamped);
    }

    #[test]
    fn session_without_capture_stamp_decodes_to_zero() {
        // A PR1/PR2-era payload: session present, no trailing timestamp.
        let tensor = HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let mut payload = Vec::new();
        payload.extend_from_slice(&3u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        put_tensor(&mut payload, &tensor);
        put_session(&mut payload, "mid");
        let buf = legacy_frame(2, &payload);
        assert_eq!(
            read_msg(&mut buf.as_slice()).unwrap(),
            Msg::Features {
                frame_id: 3,
                device_id: 0,
                tensor,
                session: "mid".into(),
                capture_micros: 0,
            }
        );
    }

    #[test]
    fn default_split_hello_is_byte_identical_to_legacy_form() {
        // A default-depth Hello must not grow trailing bytes: legacy
        // servers' strict done() check rejects fields they don't know.
        let msg = Msg::Hello { device_id: 2, session: "s7".into(), split: String::new() };
        let payload = encode_payload(&msg);
        let mut legacy = Vec::new();
        legacy.extend_from_slice(&2u32.to_le_bytes());
        put_session(&mut legacy, "s7");
        assert_eq!(payload, legacy, "empty split must not add trailing bytes");

        // The same bytes decode back to the default depth (the
        // pre-split-client arity).
        let buf = legacy_frame(1, &payload);
        assert_eq!(read_msg(&mut buf.as_slice()).unwrap(), msg);
    }

    #[test]
    fn split_hello_rejects_malformed_names() {
        // Explicit zero-length split: the default depth is spelled by
        // omitting the field, so a 0 length byte is a desync.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        put_session(&mut payload, "s");
        payload.push(0);
        let buf = legacy_frame(1, &payload);
        assert!(read_msg(&mut buf.as_slice()).is_err());

        // A split length byte promising more bytes than remain.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        put_session(&mut payload, "s");
        payload.push(9);
        payload.extend_from_slice(b"abc");
        let buf = legacy_frame(1, &payload);
        assert!(read_msg(&mut buf.as_slice()).is_err());

        // Oversized split names fail validation before reaching the wire.
        let mut buf = Vec::new();
        let msg =
            Msg::Hello { device_id: 0, session: "s".into(), split: "x".repeat(300) };
        assert!(write_msg(&mut buf, &msg).is_err());
        assert!(buf.is_empty(), "nothing may reach the wire on validation failure");
    }

    #[test]
    fn quantized_features_roundtrip_within_half_step() {
        // quantize → serialize → deserialize → dequantize: the wire must
        // not add error beyond the quantizer's half-step bound.
        let data: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.173).sin() * 2.5).collect();
        let t = HostTensor::new(vec![8, 8, 8], data.clone()).unwrap();
        let q = crate::net::quantize(&t);
        let step = q.scale;
        let msg = Msg::FeaturesQ {
            frame_id: 1,
            device_id: 0,
            tensor: q,
            session: "x".into(),
            capture_micros: 7,
        };
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let back = match read_msg(&mut buf.as_slice()).unwrap() {
            Msg::FeaturesQ { tensor, .. } => crate::net::dequantize(&tensor).unwrap(),
            other => panic!("unexpected message {other:?}"),
        };
        assert_eq!(back.shape, vec![8, 8, 8]);
        let max_err = data
            .iter()
            .zip(&back.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= step * 0.5 + 1e-6, "wire error {max_err} vs half-step {}", step * 0.5);
    }

    #[test]
    fn write_msg_rejects_undecodable_session_names() {
        let mut buf = Vec::new();
        assert!(write_msg(&mut buf, &Msg::Subscribe { session: String::new() }).is_err());
        assert!(write_msg(&mut buf, &Msg::Subscribe { session: "x".repeat(300) }).is_err());
        assert!(buf.is_empty(), "nothing may reach the wire on validation failure");
        assert!(write_msg(&mut buf, &Msg::Subscribe { session: "ok".into() }).is_ok());
    }

    #[test]
    fn rejects_short_header() {
        // Fewer bytes than the 9-byte frame header: must error, not hang
        // or panic.
        let buf = [b'S', b'C', b'M'];
        assert!(read_msg(&mut buf.as_slice()).is_err());
        let buf: [u8; 0] = [];
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_truncated_session_suffix() {
        // A session length byte promising more bytes than remain.
        let mut payload = Vec::new();
        payload.extend_from_slice(&7u32.to_le_bytes());
        payload.push(9); // claims a 9-byte name, none follow
        let buf = legacy_frame(1, &payload);
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    /// Yields the stream in 5-byte chunks with a timeout error between
    /// every chunk — a bandwidth-shaped link as the server's read loop
    /// sees it.
    struct StutterReader {
        data: Vec<u8>,
        pos: usize,
        timeout_next: bool,
    }

    impl Read for StutterReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.timeout_next {
                self.timeout_next = false;
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "timeout"));
            }
            self.timeout_next = true;
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            let n = 5.min(self.data.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn slow_link_does_not_desync_stream() {
        // Two messages trickling in with timeouts between every 5 bytes:
        // the reader must retry mid-frame instead of discarding partial
        // bytes, and both messages must decode cleanly.
        let msg1 = Msg::Features {
            frame_id: 1,
            device_id: 0,
            tensor: HostTensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap(),
            session: "slow".into(),
            capture_micros: 0,
        };
        let msg2 = Msg::Bye;
        let mut data = Vec::new();
        write_msg(&mut data, &msg1).unwrap();
        write_msg(&mut data, &msg2).unwrap();
        let mut r = StutterReader { data, pos: 0, timeout_next: false };

        let mut got = Vec::new();
        while got.len() < 2 {
            match read_msg(&mut r) {
                Ok(m) => got.push(m),
                Err(e) => {
                    // Idle timeout between frames: retry, like the server.
                    let timed_out = e
                        .downcast_ref::<std::io::Error>()
                        .map_or(false, |io| io.kind() == std::io::ErrorKind::WouldBlock);
                    assert!(timed_out, "unexpected error on slow link: {e:#}");
                }
            }
        }
        assert_eq!(got[0], msg1);
        assert_eq!(got[1], msg2);
    }

    #[test]
    fn idle_timeout_surfaces_before_first_byte() {
        // No bytes at all: the timeout must propagate (so pollers can
        // re-check shutdown flags) rather than being swallowed.
        let mut r = StutterReader { data: Vec::new(), pos: 0, timeout_next: true };
        let err = read_msg(&mut r).unwrap_err();
        let io = err.downcast_ref::<std::io::Error>().expect("io error");
        assert_eq!(io.kind(), std::io::ErrorKind::WouldBlock);
    }

    #[test]
    fn rejects_oversized_payload_header() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.push(5);
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = Vec::new();
        write_msg(&mut buf, &Msg::Bye).unwrap();
        buf[0] = b'X';
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let mut buf = Vec::new();
        write_msg(
            &mut buf,
            &Msg::Features {
                frame_id: 1,
                device_id: 0,
                tensor: HostTensor::zeros(&[4]),
                session: DEFAULT_SESSION.into(),
                capture_micros: 0,
            },
        )
        .unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_trailing_garbage_inside_payload() {
        // craft: Bye with nonzero payload
        let mut buf = Vec::new();
        buf.extend_from_slice(b"SCMI");
        buf.push(5);
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0, 0]);
        assert!(read_msg(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn feature_payload_size_matches_design() {
        // The 64x64x8x8 intermediate output should serialize to ~1 MiB.
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let payload = encode_payload(&Msg::Features {
            frame_id: 0,
            device_id: 0,
            tensor: t,
            session: DEFAULT_SESSION.into(),
            capture_micros: 0,
        });
        assert!(payload.len() > (1 << 20) && payload.len() < (1 << 20) + 64);
    }

    #[test]
    fn quantized_payload_is_4x_smaller() {
        let t = HostTensor::zeros(&[8, 64, 64, 8]);
        let full = encode_payload(&Msg::Features {
            frame_id: 0,
            device_id: 0,
            tensor: t.clone(),
            session: DEFAULT_SESSION.into(),
            capture_micros: 0,
        })
        .len();
        let q = crate::net::quantize(&t);
        let small = encode_payload(&Msg::FeaturesQ {
            frame_id: 0,
            device_id: 0,
            tensor: q,
            session: DEFAULT_SESSION.into(),
            capture_micros: 0,
        })
        .len();
        assert!(small * 4 < full + 128, "quant {small} vs full {full}");
    }
}
