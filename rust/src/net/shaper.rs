//! Token-bucket bandwidth shaping for localhost TCP.
//!
//! The paper's testbed uses a 1 Gbps wired LAN (Table I); loopback is
//! orders of magnitude faster, so the distributed demo wraps its sockets
//! in a [`ShapedWriter`] that paces writes to the configured line rate —
//! transmission time then matches `bytes·8 / bandwidth` like the real
//! link.

use crate::sync::thread;
use crate::sync::time::Instant;
use std::io::{self, Write};
use std::time::Duration;

/// A writer that caps sustained throughput at `bytes_per_sec`.
pub struct ShapedWriter<W: Write> {
    inner: W,
    bytes_per_sec: f64,
    /// Time before which we must not send more (accumulated pacing debt).
    next_free: Instant,
    /// Max chunk written between sleeps (keeps pacing smooth).
    chunk: usize,
}

impl<W: Write> ShapedWriter<W> {
    /// Wrap `inner`, pacing sustained writes to `bits_per_sec`.
    pub fn new(inner: W, bits_per_sec: f64) -> ShapedWriter<W> {
        ShapedWriter {
            inner,
            bytes_per_sec: bits_per_sec / 8.0,
            next_free: Instant::now(),
            chunk: 64 * 1024,
        }
    }

    /// Unshaped writer (infinite bandwidth).
    pub fn unshaped(inner: W) -> ShapedWriter<W> {
        ShapedWriter { inner, bytes_per_sec: f64::INFINITY, next_free: Instant::now(), chunk: usize::MAX }
    }

    /// The wrapped writer (e.g. to reach socket options).
    pub fn get_mut(&mut self) -> &mut W {
        &mut self.inner
    }
}

impl<W: Write> Write for ShapedWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if self.bytes_per_sec.is_infinite() {
            return self.inner.write(buf);
        }
        let n = buf.len().min(self.chunk);
        let now = Instant::now();
        if self.next_free > now {
            thread::sleep(self.next_free - now);
        }
        let written = self.inner.write(&buf[..n])?;
        let cost = Duration::from_secs_f64(written as f64 / self.bytes_per_sec);
        // Cap accumulated pacing credit at 5 ms so an idle link doesn't
        // bank an unshaped burst. `checked_sub` because early in process
        // life `Instant::now()` can be within 5 ms of the clock's origin
        // on some platforms, and bare subtraction would panic.
        let after = Instant::now();
        let floor = after.checked_sub(Duration::from_millis(5)).unwrap_or(after);
        self.next_free = self.next_free.max(floor) + cost;
        Ok(written)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shaped_write_takes_expected_time() {
        // 8 Mbit/s -> 1 MB/s; writing 200 KB should take ~0.2 s
        let sink: Vec<u8> = Vec::new();
        let mut w = ShapedWriter::new(sink, 8e6);
        let data = vec![0u8; 200 * 1024];
        let t0 = Instant::now();
        w.write_all(&data).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        assert!(secs > 0.12 && secs < 0.5, "took {secs}s");
        assert_eq!(w.get_mut().len(), data.len());
    }

    #[test]
    fn unshaped_is_fast() {
        let sink: Vec<u8> = Vec::new();
        let mut w = ShapedWriter::unshaped(sink);
        let data = vec![0u8; 4 << 20];
        let t0 = Instant::now();
        w.write_all(&data).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 0.5);
    }

    #[test]
    fn first_write_does_not_panic_and_idle_gap_banks_no_credit() {
        // Regression for the Instant-underflow panic: the very first
        // write computes `now - 5ms`, which must go through checked_sub.
        let mut w = ShapedWriter::new(Vec::new(), 8e6); // 1 MB/s
        w.write_all(&[0u8; 512]).unwrap();

        // Pacing-debt cap behavior must survive the fix: a long idle gap
        // banks at most ~5 ms of credit, so a burst after it still paces
        // at the configured rate.
        std::thread::sleep(Duration::from_millis(60));
        let t0 = Instant::now();
        w.write_all(&vec![0u8; 100 * 1024]).unwrap(); // ~0.1 s at 1 MB/s
        let secs = t0.elapsed().as_secs_f64();
        assert!(secs > 0.06, "idle gap must not grant pacing credit, took {secs}s");
    }

    #[test]
    fn small_writes_accumulate_debt() {
        // 1000 writes of 1 KB at 8 Mbit/s = 1 MB total ≈ 1 s... use less:
        // 100 KB total ≈ 0.1 s
        let sink: Vec<u8> = Vec::new();
        let mut w = ShapedWriter::new(sink, 8e6);
        let t0 = Instant::now();
        for _ in 0..100 {
            w.write_all(&[0u8; 1024]).unwrap();
        }
        let secs = t0.elapsed().as_secs_f64();
        assert!(secs > 0.06, "took {secs}s");
    }
}
