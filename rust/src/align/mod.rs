//! Coordinate transformation of intermediate outputs (paper §III-A.2).
//!
//! Voxel indices of a device's feature map are converted to physical
//! coordinates (scaled by the effective voxel size, shifted by the grid
//! origin), pushed through the rigid calibration transform, and converted
//! back to voxel indices in the common grid, rounded to nearest and
//! clipped to the integration range. Because the transform is fixed after
//! setup, the whole chain collapses to a **static gather index map**
//! computed once per (device, transform) pair — this module builds that
//! map and applies it. `python/compile/align.py` builds the identical map
//! for training and in-model alignment; a pytest cross-checks the two.

use crate::config::GridConfig;
use crate::geom::Pose;
use crate::voxel::FeatureMap;

/// Precomputed gather map: for each output voxel (in the common grid),
/// the flat index of the source voxel in the device's local grid, or -1
/// when the source location falls outside the device grid.
#[derive(Clone, Debug)]
pub struct AlignMap {
    /// One entry per output voxel, layout (D, H, W) flattened.
    pub src_flat: Vec<i64>,
    pub dims: [usize; 3], // W, H, D
}

impl AlignMap {
    /// Build the map for a device whose local frame maps to the common
    /// frame via `device_to_common`. Both grids share `grid`'s geometry
    /// (paper's common-resolution/common-origin assumption). The
    /// `stride` accounts for spatial downscaling between voxelization and
    /// the split point (1 for SC-MII's split after the first s=1 conv).
    pub fn build(grid: &GridConfig, device_to_common: &Pose, stride: usize) -> AlignMap {
        let common_to_device = device_to_common.inverse();
        let [w, h, d] = grid.dims;
        let (w_s, h_s, d_s) = (w / stride, h / stride, d / stride);
        let eff = [
            grid.voxel[0] * stride as f64,
            grid.voxel[1] * stride as f64,
            grid.voxel[2] * stride as f64,
        ];
        let mut src_flat = Vec::with_capacity(d_s * h_s * w_s);
        for iz in 0..d_s {
            for iy in 0..h_s {
                for ix in 0..w_s {
                    // Voxel center in common-frame physical coordinates.
                    let px = grid.range_min[0] + (ix as f64 + 0.5) * eff[0];
                    let py = grid.range_min[1] + (iy as f64 + 0.5) * eff[1];
                    let pz = grid.range_min[2] + (iz as f64 + 0.5) * eff[2];
                    // Into the device's local frame.
                    let local = common_to_device.apply(crate::geom::Vec3::new(px, py, pz));
                    // Back to (rounded) voxel indices on the device grid.
                    let fx = (local.x - grid.range_min[0]) / eff[0] - 0.5;
                    let fy = (local.y - grid.range_min[1]) / eff[1] - 0.5;
                    let fz = (local.z - grid.range_min[2]) / eff[2] - 0.5;
                    let jx = fx.round() as i64;
                    let jy = fy.round() as i64;
                    let jz = fz.round() as i64;
                    let flat = if jx >= 0
                        && jx < w_s as i64
                        && jy >= 0
                        && jy < h_s as i64
                        && jz >= 0
                        && jz < d_s as i64
                    {
                        (jz * h_s as i64 + jy) * w_s as i64 + jx
                    } else {
                        -1
                    };
                    src_flat.push(flat);
                }
            }
        }
        AlignMap { src_flat, dims: [w_s, h_s, d_s] }
    }

    /// Identity map (device 0 — the reference sensor).
    pub fn identity(grid: &GridConfig, stride: usize) -> AlignMap {
        Self::build(grid, &Pose::IDENTITY, stride)
    }

    /// Fraction of output voxels with a valid source (coverage diagnostics).
    pub fn coverage(&self) -> f64 {
        let valid = self.src_flat.iter().filter(|&&v| v >= 0).count();
        valid as f64 / self.src_flat.len().max(1) as f64
    }

    /// Apply the gather to a feature map: out[v] = src[map[v]] (zeros when
    /// unmapped). This is the rust-native mirror of the in-HLO gather.
    pub fn apply(&self, src: &FeatureMap) -> FeatureMap {
        let [w, h, d] = self.dims;
        let mut out = FeatureMap::zeros(d, h, w, src.c);
        self.apply_into(src, &mut out.data);
        out
    }

    /// [`apply`](Self::apply) into a caller-provided backing slice
    /// (typically checked out of the tail's
    /// [`Arena`](crate::runtime::arena::Arena)). The slice **must come in
    /// zeroed**: unmapped voxels are skipped, not cleared — that contract
    /// is what lets the gather loop touch only mapped entries.
    pub fn apply_into(&self, src: &FeatureMap, out: &mut [f32]) {
        let [w, h, d] = self.dims;
        assert_eq!([src.w, src.h, src.d], [w, h, d], "grid mismatch");
        let c = src.c;
        assert_eq!(out.len(), src.data.len(), "gather output length mismatch");
        for (vox, &s) in self.src_flat.iter().enumerate() {
            if s >= 0 {
                let src_base = s as usize * c;
                let dst_base = vox * c;
                out[dst_base..dst_base + c]
                    .copy_from_slice(&src.data[src_base..src_base + c]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::Vec3;

    fn grid() -> GridConfig {
        GridConfig::default()
    }

    #[test]
    fn identity_map_is_identity() {
        let g = grid();
        let m = AlignMap::identity(&g, 1);
        assert!((m.coverage() - 1.0).abs() < 1e-12);
        for (i, &s) in m.src_flat.iter().enumerate() {
            assert_eq!(s, i as i64);
        }
        // applying to a random map returns it unchanged
        let mut src = FeatureMap::zeros(g.dims[2], g.dims[1], g.dims[0], 2);
        for (i, v) in src.data.iter_mut().enumerate() {
            *v = (i % 97) as f32 * 0.1;
        }
        let out = m.apply(&src);
        assert_eq!(out.data, src.data);
    }

    #[test]
    fn pure_translation_shifts_indices() {
        let g = grid();
        // device frame = common frame shifted +1 voxel in x (0.8 m):
        // a feature at device voxel (ix) appears at common voxel (ix+1).
        let t = Pose::from_xyz_rpy(0.8, 0.0, 0.0, 0.0, 0.0, 0.0);
        let m = AlignMap::build(&g, &t, 1);
        let [w, h, _] = m.dims;
        // output voxel (1,0,0) should source device voxel (0,0,0)
        let out_idx = 0 * h * w + 0 * w + 1;
        assert_eq!(m.src_flat[out_idx], 0);
        // leftmost column has no source
        assert_eq!(m.src_flat[0], -1);
        let _ = h;
    }

    #[test]
    fn rotation_preserves_occupancy_roughly() {
        let g = grid();
        let t = Pose::from_xyz_rpy(3.0, -2.0, 0.0, 0.0, 0.0, 0.9);
        let m = AlignMap::build(&g, &t, 1);
        // coverage limited but substantial for an in-range transform
        assert!(m.coverage() > 0.3, "coverage {}", m.coverage());
        // all source indices in range
        let n = (g.dims[0] * g.dims[1] * g.dims[2]) as i64;
        for &s in &m.src_flat {
            assert!(s >= -1 && s < n);
        }
    }

    #[test]
    fn feature_value_follows_transform() {
        let g = grid();
        let t = Pose::from_xyz_rpy(1.6, 0.8, 0.0, 0.0, 0.0, 0.0); // +2 x, +1 y voxels
        let m = AlignMap::build(&g, &t, 1);
        let [w, h, d] = m.dims;
        let mut src = FeatureMap::zeros(d, h, w, 1);
        src.set(3, 10, 10, 0, 5.0);
        let out = m.apply(&src);
        assert_eq!(out.get(3, 11, 12, 0), 5.0);
        assert_eq!(out.get(3, 10, 10, 0), 0.0);
    }

    #[test]
    fn physical_point_consistency() {
        // A feature at the device voxel containing physical point P (in
        // device frame) must land at the common voxel containing T(P).
        let g = grid();
        let t = Pose::from_xyz_rpy(4.3, -1.7, 0.4, 0.0, 0.0, 0.35);
        let m = AlignMap::build(&g, &t, 1);
        let p_dev = Vec3::new(10.0, 5.0, -3.0);
        let [ix, iy, iz] = g.voxel_of(p_dev.x, p_dev.y, p_dev.z).unwrap();
        let p_common = t.apply(p_dev);
        if let Some([ox, oy, oz]) = g.voxel_of(p_common.x, p_common.y, p_common.z) {
            let [w, h, _] = m.dims;
            let out_flat = (oz * h + oy) * w + ox;
            let src = m.src_flat[out_flat];
            assert!(src >= 0);
            let (sz, rem) = ((src as usize) / (h * w), (src as usize) % (h * w));
            let (sy, sx) = (rem / w, rem % w);
            // rounding can move one voxel; allow ±1 in each axis
            assert!((sx as i64 - ix as i64).abs() <= 1, "x {sx} vs {ix}");
            assert!((sy as i64 - iy as i64).abs() <= 1, "y {sy} vs {iy}");
            assert!((sz as i64 - iz as i64).abs() <= 1, "z {sz} vs {iz}");
        }
    }

    #[test]
    fn stride_halves_dims() {
        let g = grid();
        let m = AlignMap::identity(&g, 2);
        assert_eq!(m.dims, [32, 32, 4]);
        assert_eq!(m.src_flat.len(), 32 * 32 * 4);
    }
}
