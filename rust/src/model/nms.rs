//! Rotated (BEV) non-maximum suppression.

use super::Detection;
use crate::geom::bev_iou;

/// Greedy NMS over score-sorted detections using rotated BEV IoU.
/// Input need not be sorted; output is sorted by descending score.
pub fn rotated_nms(mut dets: Vec<Detection>, iou_threshold: f64, max_keep: usize) -> Vec<Detection> {
    // Drop NaN scores up front: in the descending total order +NaN would
    // rank first and suppress every overlapping real detection. total_cmp
    // then keeps the sort panic-free (the old partial_cmp().unwrap()
    // panicked mid-serve).
    dets.retain(|d| !d.score.is_nan());
    dets.sort_unstable_by(|a, b| b.score.total_cmp(&a.score));
    let mut kept: Vec<Detection> = Vec::new();
    for d in dets {
        if kept.len() >= max_keep {
            break;
        }
        let suppressed = kept.iter().any(|k| bev_iou(&k.bbox, &d.bbox) > iou_threshold);
        if !suppressed {
            kept.push(d);
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::{Box3, Vec3};

    fn det(x: f64, y: f64, yaw: f64, score: f32) -> Detection {
        Detection {
            bbox: Box3::new(Vec3::new(x, y, 0.0), Vec3::new(4.5, 1.9, 1.6), yaw),
            score,
            class_id: 0,
        }
    }

    #[test]
    fn keeps_highest_of_overlapping_pair() {
        let dets = vec![det(0.0, 0.0, 0.0, 0.6), det(0.5, 0.0, 0.0, 0.9)];
        let kept = rotated_nms(dets, 0.3, 10);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.9);
    }

    #[test]
    fn keeps_disjoint_detections() {
        let dets = vec![det(0.0, 0.0, 0.0, 0.9), det(20.0, 0.0, 0.0, 0.8), det(0.0, 20.0, 1.0, 0.7)];
        let kept = rotated_nms(dets, 0.3, 10);
        assert_eq!(kept.len(), 3);
    }

    #[test]
    fn respects_max_keep() {
        let dets: Vec<Detection> =
            (0..20).map(|i| det(i as f64 * 10.0, 0.0, 0.0, 1.0 - i as f32 * 0.01)).collect();
        let kept = rotated_nms(dets, 0.3, 5);
        assert_eq!(kept.len(), 5);
        assert!(kept[0].score >= kept[4].score);
    }

    #[test]
    fn rotated_overlap_detected() {
        // same center, crossed at 90°: inter = 1.9² = 3.61,
        // union = 2·8.55 − 3.61 = 13.49 → IoU ≈ 0.268
        let dets = vec![det(0.0, 0.0, 0.0, 0.9), det(0.0, 0.0, std::f64::consts::FRAC_PI_2, 0.8)];
        let kept = rotated_nms(dets, 0.25, 10);
        assert_eq!(kept.len(), 1);
        let kept2 = rotated_nms(
            vec![det(0.0, 0.0, 0.0, 0.9), det(0.0, 0.0, std::f64::consts::FRAC_PI_2, 0.8)],
            0.3,
            10,
        );
        assert_eq!(kept2.len(), 2, "looser threshold keeps both");
    }

    #[test]
    fn empty_input() {
        assert!(rotated_nms(Vec::new(), 0.3, 10).is_empty());
    }

    #[test]
    fn nan_scores_are_dropped_not_seeded() {
        // A NaN-scored box fully overlapping a real one must not become
        // the NMS seed that suppresses it.
        let dets = vec![det(0.0, 0.0, 0.0, f32::NAN), det(0.0, 0.0, 0.0, 0.8)];
        let kept = rotated_nms(dets, 0.3, 10);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].score, 0.8);
    }
}
