//! Detection-head post-processing: anchor decode, rotated NMS, detection
//! types. Mirrors the encoding used by `python/compile/targets.py`.
//!
//! Head output layout (per frame, as produced by the tail HLO):
//! - `cls`:   `(H_bev, W_bev, A)` objectness logits (anchor k detects
//!   class `anchors[k].class_id`).
//! - `boxes`: `(H_bev, W_bev, A, 8)` regression targets
//!   `(dx, dy, dz, dl, dw, dh, sin Δyaw, cos Δyaw)` with the SECOND-style
//!   normalization: offsets scaled by the anchor diagonal, sizes by log.

pub mod nms;

pub use nms::rotated_nms;

use crate::config::ModelMeta;
use crate::geom::{Box3, Vec3};

/// One decoded detection in the common frame.
#[derive(Clone, Debug)]
pub struct Detection {
    pub bbox: Box3,
    pub score: f32,
    pub class_id: usize,
}

/// Decode parameters.
#[derive(Clone, Debug)]
pub struct DecodeParams {
    /// Sigmoid-score threshold before NMS.
    pub score_threshold: f32,
    /// Max candidates kept before NMS (sorted by score).
    pub pre_nms_top_k: usize,
    /// BEV IoU threshold for NMS suppression.
    pub nms_iou: f64,
    /// Max detections kept after NMS.
    pub max_detections: usize,
}

impl Default for DecodeParams {
    fn default() -> Self {
        DecodeParams { score_threshold: 0.25, pre_nms_top_k: 512, nms_iou: 0.25, max_detections: 64 }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode raw head outputs into detections (before NMS).
pub fn decode_raw(
    cls_logits: &[f32],
    box_deltas: &[f32],
    meta: &ModelMeta,
    params: &DecodeParams,
) -> Vec<Detection> {
    let [hb, wb] = meta.bev_dims;
    let a = meta.anchors.len();
    assert_eq!(cls_logits.len(), hb * wb * a, "cls shape mismatch");
    assert_eq!(box_deltas.len(), hb * wb * a * 8, "box shape mismatch");

    let mut out = Vec::new();
    for row in 0..hb {
        for col in 0..wb {
            for k in 0..a {
                let idx = (row * wb + col) * a + k;
                let score = sigmoid(cls_logits[idx]);
                // Negated >= so NaN scores fail the filter here (a NaN
                // comparison is always false) instead of slipping through
                // and sorting unpredictably downstream.
                if !(score >= params.score_threshold) {
                    continue;
                }
                let anchor = &meta.anchors[k];
                let (ax, ay) = meta.bev_cell_center(row, col);
                let az = anchor.z_center;
                let (al, aw, ah) = (anchor.size[0], anchor.size[1], anchor.size[2]);
                let diag = (al * al + aw * aw).sqrt();
                let b = &box_deltas[idx * 8..idx * 8 + 8];
                let x = ax + b[0] as f64 * diag;
                let y = ay + b[1] as f64 * diag;
                let z = az + b[2] as f64 * ah;
                let l = al * (b[3] as f64).clamp(-4.0, 4.0).exp();
                let w = aw * (b[4] as f64).clamp(-4.0, 4.0).exp();
                let h = ah * (b[5] as f64).clamp(-4.0, 4.0).exp();
                let dyaw = (b[6] as f64).atan2(b[7] as f64);
                let yaw = crate::geom::box3::normalize_angle(anchor.yaw + dyaw);
                out.push(Detection {
                    bbox: Box3::new(Vec3::new(x, y, z), Vec3::new(l, w, h), yaw),
                    score,
                    class_id: anchor.class_id,
                });
            }
        }
    }
    // Top-k selection instead of a full sort: candidates are O(H·W·A),
    // the kept set is `pre_nms_top_k` — select_nth partitions in O(n),
    // then only the kept prefix is sorted. `total_cmp` keeps the sort
    // panic-proof even if NaN scores ever reached it (the threshold
    // filter above already drops them).
    let k = params.pre_nms_top_k;
    if k == 0 {
        out.clear();
        return out;
    }
    if out.len() > k {
        out.select_nth_unstable_by(k - 1, |p, q| q.score.total_cmp(&p.score));
        out.truncate(k);
    }
    out.sort_unstable_by(|p, q| q.score.total_cmp(&p.score));
    out
}

/// Full post-processing: decode + per-class rotated NMS.
pub fn postprocess(
    cls_logits: &[f32],
    box_deltas: &[f32],
    meta: &ModelMeta,
    params: &DecodeParams,
) -> Vec<Detection> {
    let mut candidates = decode_raw(cls_logits, box_deltas, meta, params);
    // Partition in place per class: a stable sort by class keeps the
    // descending score order inside each class run, then each run is
    // split off and moved into NMS — no per-class clones.
    candidates.sort_by_key(|d| d.class_id);
    let mut kept = Vec::new();
    let mut rest = candidates;
    while !rest.is_empty() {
        let class_id = rest[0].class_id;
        let split = rest.partition_point(|d| d.class_id == class_id);
        let tail = rest.split_off(split);
        kept.extend(rotated_nms(rest, params.nms_iou, params.max_detections));
        rest = tail;
    }
    kept.sort_unstable_by(|p, q| q.score.total_cmp(&p.score));
    kept.truncate(params.max_detections);
    kept
}

/// Encode a ground-truth box against an anchor (inverse of decode; used
/// by round-trip tests to pin the convention shared with python).
pub fn encode_box(
    gt: &Box3,
    anchor_center: (f64, f64),
    anchor: &crate::config::meta::Anchor,
) -> [f32; 8] {
    let (ax, ay) = anchor_center;
    let az = anchor.z_center;
    let (al, aw, ah) = (anchor.size[0], anchor.size[1], anchor.size[2]);
    let diag = (al * al + aw * aw).sqrt();
    let dyaw = gt.yaw - anchor.yaw;
    [
        ((gt.center.x - ax) / diag) as f32,
        ((gt.center.y - ay) / diag) as f32,
        ((gt.center.z - az) / ah) as f32,
        (gt.size.x / al).ln() as f32,
        (gt.size.y / aw).ln() as f32,
        (gt.size.z / ah).ln() as f32,
        dyaw.sin() as f32,
        dyaw.cos() as f32,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta::test_default()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let m = meta();
        let params = DecodeParams { score_threshold: 0.0, ..Default::default() };
        // put a gt box near the center of bev cell (10, 12), anchor 0
        let (ax, ay) = m.bev_cell_center(10, 12);
        let gt = Box3::new(
            Vec3::new(ax + 0.4, ay - 0.3, -3.5),
            Vec3::new(4.2, 1.8, 1.5),
            0.3,
        );
        let enc = encode_box(&gt, (ax, ay), &m.anchors[0]);

        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let mut cls = vec![-10.0f32; hb * wb * a];
        let mut boxes = vec![0.0f32; hb * wb * a * 8];
        let idx = (10 * wb + 12) * a;
        cls[idx] = 5.0; // high score for anchor 0 at that cell
        boxes[idx * 8..idx * 8 + 8].copy_from_slice(&enc);

        let dets = decode_raw(&cls, &boxes, &m, &params);
        // exactly one confident detection (others below threshold at 0.0
        // threshold: sigmoid(-10) ≈ 4.5e-5 > 0 so they appear; use top one)
        let d = &dets[0];
        assert!((d.bbox.center.x - gt.center.x).abs() < 1e-4);
        assert!((d.bbox.center.y - gt.center.y).abs() < 1e-4);
        assert!((d.bbox.center.z - gt.center.z).abs() < 1e-4);
        assert!((d.bbox.size.x - gt.size.x).abs() < 1e-4);
        assert!((d.bbox.yaw - gt.yaw).abs() < 1e-6);
        assert_eq!(d.class_id, 0);
        assert!(d.score > 0.99);
    }

    #[test]
    fn score_threshold_filters() {
        let m = meta();
        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let cls = vec![-10.0f32; hb * wb * a];
        let boxes = vec![0.0f32; hb * wb * a * 8];
        let dets = decode_raw(&cls, &boxes, &m, &DecodeParams::default());
        assert!(dets.is_empty());
    }

    #[test]
    fn postprocess_suppresses_duplicates() {
        let m = meta();
        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let mut cls = vec![-10.0f32; hb * wb * a];
        let boxes = vec![0.0f32; hb * wb * a * 8];
        // two adjacent cells firing for the same physical spot -> their
        // decoded boxes (anchor-sized, zero deltas at cell centers 1.6 m
        // apart) overlap heavily for the 4.5x1.9 car anchor at yaw 0
        let i1 = (10 * wb + 12) * a;
        let i2 = (10 * wb + 13) * a;
        cls[i1] = 4.0;
        cls[i2] = 3.0;
        let dets = postprocess(&cls, &boxes, &m, &DecodeParams::default());
        assert_eq!(dets.len(), 1, "NMS should keep one of the overlapping pair");
        assert!(dets[0].score > 0.9);
    }

    #[test]
    fn nan_logits_are_handled_without_panicking() {
        // Regression: partial_cmp().unwrap() used to panic on NaN scores
        // mid-serve, and NaN used to slip past the `<` threshold test.
        // Now the threshold filter drops NaN (NaN comparisons are false
        // either way, so `!(score >= t)` rejects it) and total_cmp keeps
        // every sort panic-free.
        let m = meta();
        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let mut cls = vec![-10.0f32; hb * wb * a];
        let boxes = vec![0.0f32; hb * wb * a * 8];
        cls[0] = f32::NAN;
        cls[(10 * wb + 12) * a] = 5.0;
        let dets = postprocess(&cls, &boxes, &m, &DecodeParams::default());
        assert_eq!(dets.len(), 1, "NaN-scored candidate must be filtered out");
        assert!(dets[0].score > 0.9, "the valid detection survives");
        assert!(dets.iter().all(|d| d.score.is_finite()));
    }

    #[test]
    fn top_k_selection_keeps_global_best() {
        let m = meta();
        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let n = hb * wb * a;
        // Strictly increasing logits, all above threshold.
        let cls: Vec<f32> = (0..n).map(|i| i as f32 / n as f32).collect();
        let boxes = vec![0.0f32; n * 8];
        let params = DecodeParams { score_threshold: 0.1, pre_nms_top_k: 7, ..Default::default() };
        let dets = decode_raw(&cls, &boxes, &m, &params);
        assert_eq!(dets.len(), 7);
        for w in dets.windows(2) {
            assert!(w[0].score >= w[1].score, "output must stay score-sorted");
        }
        let top_logit = (n - 1) as f32 / n as f32;
        let expect = 1.0 / (1.0 + (-top_logit).exp());
        assert!((dets[0].score - expect).abs() < 1e-6, "must keep the global best");
    }

    #[test]
    fn yaw_anchor_offset_decodes() {
        let m = meta();
        // anchor 1 is the 90° car anchor; zero deltas decode to yaw π/2
        let [hb, wb] = m.bev_dims;
        let a = m.anchors.len();
        let mut cls = vec![-10.0f32; hb * wb * a];
        let mut boxes = vec![0.0f32; hb * wb * a * 8];
        let idx = (5 * wb + 5) * a + 1;
        cls[idx] = 6.0;
        boxes[idx * 8 + 7] = 1.0; // cos = 1, sin = 0
        let dets = decode_raw(&cls, &boxes, &m, &DecodeParams::default());
        assert!((dets[0].bbox.yaw - std::f64::consts::FRAC_PI_2).abs() < 1e-6);
    }
}
