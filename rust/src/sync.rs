//! Concurrency primitives with a build-time `loom` switch, plus
//! poison-tolerant locking helpers.
//!
//! Every concurrency-bearing module in this crate imports its
//! synchronization primitives from here instead of `std::sync` /
//! `std::thread`. A normal build re-exports the `std` types unchanged
//! (zero overhead); building with `RUSTFLAGS="--cfg loom"` swaps in the
//! [loom](https://docs.rs/loom) model-checking equivalents so the
//! protocol models in `tests/loom.rs` can exhaustively explore thread
//! interleavings (see `docs/ARCHITECTURE.md`, "Concurrency model &
//! verification").
//!
//! Deliberate exceptions, kept on `std` under both cfgs:
//!
//! * [`Arc`] — reference counting never blocks, and swapping in loom's
//!   `Arc` would change public API types crate-wide for no modeling
//!   value: none of the modeled protocols synchronize through `Arc`
//!   itself.
//! * [`atomic`] — the loom-verified protocols synchronize exclusively
//!   through [`Mutex`]/[`Condvar`]/[`mpsc`]; the atomics in this crate
//!   are stat counters and stop flags whose exact orderings are not
//!   protocol-critical.
//! * `std::thread::scope` (device pipeline) — loom has no scoped
//!   threads; the loom model for that protocol exercises the [`mpsc`]
//!   one-slot channel the scope communicates over, not the scope itself.
//!
//! The poison policy lives here too: serving-path code must never
//! `.lock().unwrap()` (enforced by `cargo run -p xtask -- lint`).
//! A poisoned mutex means some holder panicked, and the panic has
//! already been reported and contained where it happened (sink
//! delivery, backend execution, pool workers all run under
//! `catch_unwind`); propagating the poison as a *second* panic on an
//! innocent thread is how one bad frame used to wedge a whole session.
//! [`lock_or_recover`] logs and continues with the data as the
//! panicking holder left it — every protected structure in the serving
//! path is valid (if possibly stale) at every await point.

#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

/// Atomically reference-counted pointer. Always `std` (see module docs).
pub use std::sync::Arc;

/// Atomic integer/bool types. Always `std` (see module docs).
pub mod atomic {
    pub use std::sync::atomic::*;
}

/// Lock `m`, recovering (with a warning) instead of panicking if a
/// previous holder panicked and poisoned it.
///
/// This is the only sanctioned way to take a serving-path lock; the
/// repo lint rejects `.lock().unwrap()` in serving modules. The guard
/// hands back the data exactly as the panicking holder left it, which
/// is safe for every structure in this crate: they are kept
/// shrink-to-valid at all times (queues of whole items, maps of whole
/// entries), so the worst case after recovery is a lost in-flight item,
/// never a torn one.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => {
            log::warn!("recovering a mutex poisoned by an earlier panic");
            poisoned.into_inner()
        }
    }
}

/// [`Condvar::wait`] with the same poison-recovery policy as
/// [`lock_or_recover`]. Callers must re-check their condition in a loop
/// (spurious wakeups are allowed, and loom exercises them).
pub fn wait_or_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(guard) => guard,
        Err(poisoned) => {
            log::warn!("recovering a mutex poisoned by an earlier panic (condvar wait)");
            poisoned.into_inner()
        }
    }
}

/// [`Condvar::wait_timeout`] with the same poison-recovery policy as
/// [`lock_or_recover`]. Returns only the guard: callers re-check their
/// condition and their deadline in a loop, so whether the wakeup was a
/// timeout, a notification, or spurious is immaterial.
pub fn wait_timeout_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((guard, _timed_out)) => guard,
        Err(poisoned) => {
            log::warn!("recovering a mutex poisoned by an earlier panic (condvar wait_timeout)");
            poisoned.into_inner().0
        }
    }
}

/// Thread spawning and sleeping, switched between `std` and loom.
pub mod thread {
    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, JoinHandle};
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, JoinHandle};

    /// Spawn a named thread (`std::thread::Builder::name`). Under loom
    /// the name is dropped — loom has no thread builder — but spawning
    /// still works, so pools keep their topology in models.
    #[cfg(not(loom))]
    pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new().name(name.to_string()).spawn(f)
    }

    /// Spawn a named thread (`std::thread::Builder::name`). Under loom
    /// the name is dropped — loom has no thread builder — but spawning
    /// still works, so pools keep their topology in models.
    #[cfg(loom)]
    pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let _ = name;
        Ok(spawn(f))
    }

    /// Sleep for `d`. Under loom real time does not exist; sleeping
    /// becomes a yield so the scheduler explores other threads.
    #[cfg(not(loom))]
    pub fn sleep(d: std::time::Duration) {
        std::thread::sleep(d);
    }

    /// Sleep for `d`. Under loom real time does not exist; sleeping
    /// becomes a yield so the scheduler explores other threads.
    #[cfg(loom)]
    pub fn sleep(d: std::time::Duration) {
        let _ = d;
        yield_now();
    }
}

/// Monotonic time, switched between `std` and a deterministic fake
/// under loom.
pub mod time {
    #[cfg(not(loom))]
    pub use std::time::Instant;

    #[cfg(loom)]
    pub use fake::Instant;

    /// A deterministic stand-in for `std::time::Instant` under loom.
    ///
    /// Loom models have no real clock, but the batch planner's
    /// collection loop and the metrics wall-clock both ask for one.
    /// Every `now()` call advances a global tick by 100 µs, so
    /// deadline loops (`while now < deadline { wait_timeout(...) }`)
    /// terminate after a bounded number of iterations in every
    /// explored interleaving instead of hanging the model.
    #[cfg(loom)]
    pub mod fake {
        use std::ops::{Add, Sub};
        use std::time::Duration;

        /// Nanoseconds advanced per `Instant::now()` call.
        const TICK_NANOS: u64 = 100_000;

        // Deliberately a *std* atomic: this is model bookkeeping, not a
        // synchronization primitive under test, and loom's own atomics
        // cannot be used in statics.
        static TICK: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1 << 30);

        /// Deterministic monotonic timestamp (nanoseconds on a global
        /// tick that advances 100 µs per `now()` call).
        #[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
        pub struct Instant(u64);

        impl Instant {
            /// Current tick; advances the global clock.
            pub fn now() -> Instant {
                Instant(TICK.fetch_add(TICK_NANOS, std::sync::atomic::Ordering::Relaxed))
            }

            /// Time elapsed since `self` (saturating, like std ≥ 1.60).
            pub fn elapsed(&self) -> Duration {
                Instant::now().duration_since(*self)
            }

            /// Saturating difference, mirroring `std::time::Instant`.
            pub fn duration_since(&self, earlier: Instant) -> Duration {
                Duration::from_nanos(self.0.saturating_sub(earlier.0))
            }

            /// Saturating difference, mirroring `std::time::Instant`.
            pub fn saturating_duration_since(&self, earlier: Instant) -> Duration {
                self.duration_since(earlier)
            }

            /// `self - d`, `None` on underflow.
            pub fn checked_sub(&self, d: Duration) -> Option<Instant> {
                self.0.checked_sub(d.as_nanos() as u64).map(Instant)
            }

            /// `self + d`, `None` on overflow.
            pub fn checked_add(&self, d: Duration) -> Option<Instant> {
                self.0.checked_add(d.as_nanos() as u64).map(Instant)
            }
        }

        impl Add<Duration> for Instant {
            type Output = Instant;
            fn add(self, d: Duration) -> Instant {
                Instant(self.0.saturating_add(d.as_nanos() as u64))
            }
        }

        impl Sub<Duration> for Instant {
            type Output = Instant;
            fn sub(self, d: Duration) -> Instant {
                Instant(self.0.saturating_sub(d.as_nanos() as u64))
            }
        }

        impl Sub<Instant> for Instant {
            type Output = Duration;
            fn sub(self, earlier: Instant) -> Duration {
                self.duration_since(earlier)
            }
        }
    }
}

/// Multi-producer channels built on the shim [`Mutex`]/[`Condvar`], so
/// the identical channel code runs under `std` and under loom.
///
/// `std::sync::mpsc` cannot be used directly: loom does not model it,
/// and mixing an unmodeled blocking primitive into a loom-explored path
/// deadlocks the model. The API mirrors the `std::sync::mpsc` subset
/// this crate uses; the one-slot `sync_channel(1)` configuration is
/// itself one of the loom-verified protocols (device pipeline
/// double-buffering).
pub mod mpsc {
    use super::{lock_or_recover, wait_or_recover, Arc, Condvar, Mutex};
    use std::collections::VecDeque;

    struct ChanState<T> {
        queue: VecDeque<T>,
        /// Bound on queued items; `usize::MAX` for unbounded channels.
        cap: usize,
        senders: usize,
        rx_alive: bool,
    }

    struct Chan<T> {
        state: Mutex<ChanState<T>>,
        cv: Condvar,
    }

    /// Sending half of a channel. Clonable (multi-producer); the channel
    /// closes for the receiver when the last sender drops.
    pub struct Sender<T> {
        chan: Arc<Chan<T>>,
    }

    /// Receiving half of a channel (single consumer by convention;
    /// sharing requires an external mutex, as the thread pool does).
    pub struct Receiver<T> {
        chan: Arc<Chan<T>>,
    }

    /// The receiver disconnected before the value could be delivered;
    /// carries the undelivered value back to the caller.
    pub struct SendError<T>(pub T);

    /// The channel is empty and every sender has disconnected.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct RecvError;

    impl<T> std::fmt::Debug for SendError<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("SendError(..)")
        }
    }

    impl<T> std::fmt::Display for SendError<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("sending on a channel whose receiver disconnected")
        }
    }

    impl<T> std::error::Error for SendError<T> {}

    impl std::fmt::Display for RecvError {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("receiving on an empty channel whose senders all disconnected")
        }
    }

    impl std::error::Error for RecvError {}

    fn make<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
        let chan = Arc::new(Chan {
            state: Mutex::new(ChanState {
                queue: VecDeque::new(),
                cap,
                senders: 1,
                rx_alive: true,
            }),
            cv: Condvar::new(),
        });
        (Sender { chan: Arc::clone(&chan) }, Receiver { chan })
    }

    /// Unbounded channel (`std::sync::mpsc::channel` equivalent).
    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        make(usize::MAX)
    }

    /// Bounded channel: `send` blocks while `cap` items are queued
    /// (`std::sync::mpsc::sync_channel` equivalent). A capacity of 0 is
    /// clamped to 1 — rendezvous semantics are not needed here.
    pub fn sync_channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
        make(cap.max(1))
    }

    impl<T> Sender<T> {
        /// Queue `t`, blocking while the channel is full. Errors (and
        /// returns `t`) if the receiver has disconnected.
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            let mut st = lock_or_recover(&self.chan.state);
            loop {
                if !st.rx_alive {
                    return Err(SendError(t));
                }
                if st.queue.len() < st.cap {
                    st.queue.push_back(t);
                    self.chan.cv.notify_all();
                    return Ok(());
                }
                st = wait_or_recover(&self.chan.cv, st);
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            lock_or_recover(&self.chan.state).senders += 1;
            Sender { chan: Arc::clone(&self.chan) }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut st = lock_or_recover(&self.chan.state);
            st.senders -= 1;
            if st.senders == 0 {
                // Wake a receiver blocked in recv() so it observes
                // disconnection instead of waiting forever.
                self.chan.cv.notify_all();
            }
        }
    }

    impl<T> Receiver<T> {
        /// Dequeue the next value, blocking while the channel is empty.
        /// Errors once the channel is empty *and* every sender dropped.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut st = lock_or_recover(&self.chan.state);
            loop {
                if let Some(t) = st.queue.pop_front() {
                    // Wake senders blocked on a full bounded queue.
                    self.chan.cv.notify_all();
                    return Ok(t);
                }
                if st.senders == 0 {
                    return Err(RecvError);
                }
                st = wait_or_recover(&self.chan.cv, st);
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            let mut st = lock_or_recover(&self.chan.state);
            st.rx_alive = false;
            // Wake senders blocked on a full queue so they observe the
            // disconnect (clean shutdown from the consumer side).
            self.chan.cv.notify_all();
        }
    }

    /// Owning iterator over received values; ends when the channel
    /// closes (every sender dropped and the queue drained).
    pub struct IntoIter<T> {
        rx: Receiver<T>,
    }

    impl<T> Iterator for IntoIter<T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    impl<T> IntoIterator for Receiver<T> {
        type Item = T;
        type IntoIter = IntoIter<T>;
        fn into_iter(self) -> IntoIter<T> {
            IntoIter { rx: self }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn lock_or_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let poisoner = Arc::clone(&m);
        let _ = thread::spawn(move || {
            let _g = poisoner.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must be poisoned for the test to bite");
        assert_eq!(*lock_or_recover(&m), 7);
        // And the recovery is durable: taking the lock again still works.
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn wait_timeout_or_recover_returns_on_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_or_recover(&m);
        // Nobody notifies: must come back via the timeout, not hang.
        let _g = wait_timeout_or_recover(&cv, g, Duration::from_millis(10));
    }

    #[test]
    fn mpsc_unbounded_delivers_in_order() {
        let (tx, rx) = mpsc::channel::<u32>();
        let t = thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<u32> = rx.into_iter().collect();
        t.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mpsc_bounded_blocks_then_drains() {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        let t = thread::spawn(move || {
            // Second send blocks until the consumer pops the first.
            tx.send(1).unwrap();
            tx.send(2).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        t.join().unwrap();
        assert_eq!(rx.recv(), Err(mpsc::RecvError));
    }

    #[test]
    fn mpsc_send_errors_after_receiver_drop() {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        drop(rx);
        let err = tx.send(9).unwrap_err();
        assert_eq!(err.0, 9, "undelivered value must come back to the caller");
    }

    #[test]
    fn mpsc_receiver_drop_unblocks_full_sender() {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        tx.send(1).unwrap(); // fill the slot
        let t = thread::spawn(move || tx.send(2)); // blocks on the full slot
        thread::sleep(Duration::from_millis(20));
        drop(rx); // shutdown from the consumer side
        let out = t.join().unwrap();
        assert!(out.is_err(), "blocked sender must observe the disconnect");
    }

    #[test]
    fn mpsc_clone_counts_senders() {
        let (tx, rx) = mpsc::channel::<u32>();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(5).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Ok(5));
        assert_eq!(rx.recv(), Err(mpsc::RecvError));
    }
}
