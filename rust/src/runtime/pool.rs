//! Fixed-size executor pool behind the [`ExecBackend`] trait.
//!
//! Each worker thread owns one thread-local [`PoolExecutor`] (PJRT
//! handles are not `Send`, so executors are *created inside* their
//! worker thread by the spawn factory and never cross it). Exec requests
//! land in one shared queue; whichever worker goes idle first steals the
//! next job, so independent sessions/frames run concurrently up to the
//! pool size. `load` is a broadcast — every worker compiles/builds its
//! own copy of the model, since executables cannot be shared across
//! threads.
//!
//! The pool is backend-agnostic: `XlaBackend` (feature `xla`) wraps it
//! around PJRT engines, and tests wrap it around slow stub executors to
//! prove two sessions' tails overlap in time on a 2-thread pool.
//!
//! Micro-batches ([`BackendPool::exec_batch`]) travel as **one** job on
//! a single-worker pool (one queue round-trip instead of N) and are
//! scattered as individual jobs on a multi-worker pool, so batching
//! never forfeits the pool's parallelism.

use super::{ExecBackend, HostTensor};
use crate::sync::{lock_or_recover, mpsc, thread, wait_or_recover, Arc, Condvar, Mutex};
use anyhow::{Context, Result};
use std::collections::VecDeque;

/// A thread-local model executor living inside one pool worker.
pub trait PoolExecutor {
    /// Execute a loaded model on one input set.
    fn exec(&mut self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>>;

    /// Make `name` executable on this worker. Idempotent.
    fn load(&mut self, name: &str) -> Result<()>;

    /// Names resident on this worker.
    fn loaded_names(&self) -> Vec<String>;

    /// Execute a micro-batch on this executor, one result per entry.
    /// Default: a sequential loop over [`exec`](PoolExecutor::exec);
    /// executors with genuinely batched kernels override it.
    fn exec_batch(
        &mut self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        batch.into_iter().map(|inputs| self.exec(name, inputs)).collect()
    }
}

enum Job {
    Exec { name: String, inputs: Vec<HostTensor>, reply: mpsc::Sender<Result<Vec<HostTensor>>> },
    ExecBatch {
        name: String,
        batch: Vec<Vec<HostTensor>>,
        reply: mpsc::Sender<Vec<Result<Vec<HostTensor>>>>,
    },
    Load { name: String, reply: mpsc::Sender<Result<()>> },
    Loaded { reply: mpsc::Sender<Vec<String>> },
}

struct State {
    /// Shared exec jobs — any idle worker takes the next one.
    queue: VecDeque<Job>,
    /// Per-worker jobs (load broadcasts, introspection).
    control: Vec<VecDeque<Job>>,
    shutdown: bool,
}

/// N worker threads + one shared work queue. Dropping shuts the pool
/// down (workers finish their current job, then exit).
pub struct BackendPool {
    label: String,
    shared: Arc<(Mutex<State>, Condvar)>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl BackendPool {
    /// Spawn `threads` workers (clamped to ≥ 1). `factory(i)` runs *on*
    /// worker `i`'s thread to build its executor; any factory error
    /// aborts the spawn and tears the pool down.
    pub fn spawn<E, F>(label: &str, threads: usize, factory: F) -> Result<BackendPool>
    where
        E: PoolExecutor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let threads = threads.max(1);
        let shared = Arc::new((
            Mutex::new(State {
                queue: VecDeque::new(),
                control: (0..threads).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            Condvar::new(),
        ));
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let worker_shared = Arc::clone(&shared);
            let worker_factory = Arc::clone(&factory);
            let worker_ready = ready_tx.clone();
            let spawned = thread::spawn_named(&format!("{label}-worker-{i}"), move || {
                let mut executor = match worker_factory(i) {
                    Ok(e) => {
                        let _ = worker_ready.send(Ok(()));
                        drop(worker_ready);
                        e
                    }
                    Err(e) => {
                        let _ = worker_ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(i, &worker_shared, &mut executor);
            });
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // Tear down the workers already started before
                    // bailing — constructing the pool makes Drop set
                    // shutdown and join them instead of leaking parked
                    // threads (and their executors).
                    drop(BackendPool { label: label.to_string(), shared, workers });
                    return Err(anyhow::Error::new(e)
                        .context(format!("spawn {label} pool worker {i}")));
                }
            }
        }
        drop(ready_tx);

        let mut startup_err: Option<anyhow::Error> = None;
        let mut got = 0;
        while got < threads {
            match ready_rx.recv() {
                Ok(Ok(())) => got += 1,
                Ok(Err(e)) => {
                    got += 1;
                    if startup_err.is_none() {
                        startup_err = Some(e);
                    }
                }
                Err(_) => {
                    if startup_err.is_none() {
                        startup_err =
                            Some(anyhow::anyhow!("{label} pool worker died during startup"));
                    }
                    break;
                }
            }
        }
        let err_context = format!("start {label} backend pool ({threads} threads)");
        let pool = BackendPool { label: label.to_string(), shared, workers };
        match startup_err {
            // Dropping `pool` joins the workers that did start.
            Some(e) => Err(e.context(err_context)),
            None => Ok(pool),
        }
    }

    /// Number of worker threads (= max concurrent execs).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    fn push(&self, job: Job, worker: Option<usize>) {
        let (lock, cv) = &*self.shared;
        let mut st = lock_or_recover(lock);
        match worker {
            Some(i) => st.control[i].push_back(job),
            None => st.queue.push_back(job),
        }
        // notify_all: a targeted control job must reach its specific
        // worker, which notify_one could miss.
        cv.notify_all();
    }

    /// Execute on whichever worker frees up first.
    pub fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.push(Job::Exec { name: name.to_string(), inputs, reply }, None);
        rx.recv()
            .with_context(|| format!("{} pool worker dropped reply", self.label))?
    }

    /// Execute a micro-batch, one result per entry (order preserved).
    ///
    /// On a **single-worker** pool the batch travels as one queue job —
    /// one round-trip instead of N, which is the whole saving when the
    /// executor cannot overlap anything anyway. On a **multi-worker**
    /// pool the entries are scattered as individual jobs instead: one
    /// worker grinding through B frames serially would forfeit the
    /// pool's parallelism, which is worth far more than the dispatch
    /// overhead the single-job route saves.
    pub fn exec_batch(
        &self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        if self.size() <= 1 {
            let n = batch.len();
            let (reply, rx) = mpsc::channel();
            self.push(Job::ExecBatch { name: name.to_string(), batch, reply }, None);
            return rx.recv().unwrap_or_else(|_| {
                (0..n)
                    .map(|_| {
                        Err(anyhow::anyhow!(
                            "{} pool worker dropped batch reply for {name:?}",
                            self.label
                        ))
                    })
                    .collect()
            });
        }
        // Scatter: every entry is its own job, so idle workers pick them
        // up concurrently; replies are gathered back in entry order.
        let rxs: Vec<_> = batch
            .into_iter()
            .map(|inputs| {
                let (reply, rx) = mpsc::channel();
                self.push(Job::Exec { name: name.to_string(), inputs, reply }, None);
                rx
            })
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv().unwrap_or_else(|_| {
                    Err(anyhow::anyhow!(
                        "{} pool worker dropped batch-entry reply for {name:?}",
                        self.label
                    ))
                })
            })
            .collect()
    }

    /// Load `name` on **every** worker; first error wins (all workers
    /// are still waited on, so no stale load is left in flight).
    pub fn load(&self, name: &str) -> Result<()> {
        let mut replies = Vec::with_capacity(self.size());
        for i in 0..self.size() {
            let (reply, rx) = mpsc::channel();
            self.push(Job::Load { name: name.to_string(), reply }, Some(i));
            replies.push(rx);
        }
        let mut first_err: Option<anyhow::Error> = None;
        for (i, rx) in replies.into_iter().enumerate() {
            match rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("load {name:?} on worker {i}")));
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "{} pool worker {i} gone during load of {name:?}",
                            self.label
                        ));
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Resident model names (queried from worker 0 — `load` broadcasts,
    /// so all workers agree).
    pub fn loaded_names(&self) -> Vec<String> {
        let (reply, rx) = mpsc::channel();
        self.push(Job::Loaded { reply }, Some(0));
        rx.recv().unwrap_or_default()
    }
}

impl ExecBackend for BackendPool {
    fn backend_name(&self) -> &str {
        &self.label
    }

    fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        BackendPool::exec(self, name, inputs)
    }

    fn load(&self, name: &str) -> Result<()> {
        BackendPool::load(self, name)
    }

    fn loaded_names(&self) -> Vec<String> {
        BackendPool::loaded_names(self)
    }

    fn exec_batch(
        &self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        BackendPool::exec_batch(self, name, batch)
    }
}

impl Drop for BackendPool {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            lock_or_recover(lock).shutdown = true;
            cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop<E: PoolExecutor>(idx: usize, shared: &(Mutex<State>, Condvar), executor: &mut E) {
    let (lock, cv) = shared;
    loop {
        let job = {
            let mut st = lock_or_recover(lock);
            loop {
                if let Some(j) = st.control[idx].pop_front() {
                    break j;
                }
                if let Some(j) = st.queue.pop_front() {
                    break j;
                }
                // Drain queued work before honoring shutdown so replies
                // already promised are still delivered.
                if st.shutdown {
                    return;
                }
                st = wait_or_recover(cv, st);
            }
        };
        // A panicking executor must not kill the worker: a dead worker's
        // control queue would absorb later load broadcasts and hang
        // their callers forever. Catch the unwind, reply with an error,
        // and keep serving.
        match job {
            Job::Exec { name, inputs, reply } => {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    executor.exec(&name, inputs)
                }))
                .unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("pool worker {idx} panicked executing {name:?}"))
                });
                let _ = reply.send(result);
            }
            Job::ExecBatch { name, batch, reply } => {
                let n = batch.len();
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    executor.exec_batch(&name, batch)
                }))
                .unwrap_or_else(|_| {
                    (0..n)
                        .map(|_| {
                            Err(anyhow::anyhow!(
                                "pool worker {idx} panicked executing a batch of {name:?}"
                            ))
                        })
                        .collect()
                });
                let _ = reply.send(result);
            }
            Job::Load { name, reply } => {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    executor.load(&name)
                }))
                .unwrap_or_else(|_| {
                    Err(anyhow::anyhow!("pool worker {idx} panicked loading {name:?}"))
                });
                let _ = reply.send(result);
            }
            Job::Loaded { reply } => {
                let names = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    executor.loaded_names()
                }))
                .unwrap_or_default();
                let _ = reply.send(names);
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::collections::BTreeSet;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// Stub executor: echoes one tensor, tracks which worker loaded what.
    struct Echo {
        worker: usize,
        loaded: BTreeSet<String>,
        load_log: Arc<Mutex<Vec<usize>>>,
        delay: Duration,
    }

    impl PoolExecutor for Echo {
        fn exec(&mut self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            if !self.loaded.contains(name) {
                anyhow::bail!("model {name:?} not loaded");
            }
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            Ok(inputs)
        }

        fn load(&mut self, name: &str) -> Result<()> {
            if name == "poison" {
                anyhow::bail!("cannot load poison");
            }
            self.loaded.insert(name.to_string());
            self.load_log.lock().unwrap().push(self.worker);
            Ok(())
        }

        fn loaded_names(&self) -> Vec<String> {
            self.loaded.iter().cloned().collect()
        }
    }

    fn echo_pool(threads: usize, delay: Duration) -> (BackendPool, Arc<Mutex<Vec<usize>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = Arc::clone(&log);
        let pool = BackendPool::spawn("stub", threads, move |worker| {
            Ok(Echo {
                worker,
                loaded: BTreeSet::new(),
                load_log: Arc::clone(&log2),
                delay,
            })
        })
        .unwrap();
        (pool, log)
    }

    #[test]
    fn exec_round_trips_through_a_worker() {
        let (pool, _) = echo_pool(2, Duration::ZERO);
        pool.load("m").unwrap();
        let t = HostTensor::zeros(&[2, 2]);
        let out = pool.exec("m", vec![t.clone()]).unwrap();
        assert_eq!(out, vec![t]);
        assert!(pool.exec("ghost", vec![]).is_err());
    }

    /// Logs which worker ran each batch-level executor call.
    struct BatchLog {
        worker: usize,
        log: Arc<Mutex<Vec<(usize, usize)>>>,
    }
    impl PoolExecutor for BatchLog {
        fn exec(&mut self, _n: &str, i: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            Ok(i)
        }
        fn load(&mut self, _n: &str) -> Result<()> {
            Ok(())
        }
        fn loaded_names(&self) -> Vec<String> {
            Vec::new()
        }
        fn exec_batch(
            &mut self,
            name: &str,
            batch: Vec<Vec<HostTensor>>,
        ) -> Vec<Result<Vec<HostTensor>>> {
            self.log.lock().unwrap().push((self.worker, batch.len()));
            batch.into_iter().map(|i| self.exec(name, i)).collect()
        }
    }

    fn batch_log_pool(threads: usize) -> (BackendPool, Arc<Mutex<Vec<(usize, usize)>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = Arc::clone(&log);
        let pool = BackendPool::spawn("batchy", threads, move |worker| {
            Ok(BatchLog { worker, log: Arc::clone(&log2) })
        })
        .unwrap();
        (pool, log)
    }

    #[test]
    fn exec_batch_is_one_job_on_a_single_worker_pool() {
        let (pool, log) = batch_log_pool(1);
        let t = HostTensor::zeros(&[1]);
        let batch: Vec<Vec<HostTensor>> = (0..5).map(|_| vec![t.clone()]).collect();
        let results = pool.exec_batch("m", batch);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.as_ref().unwrap(), &vec![t.clone()]);
        }
        let log = log.lock().unwrap();
        assert_eq!(log.len(), 1, "the whole batch must be one executor call");
        assert_eq!(log[0].1, 5, "all 5 entries must travel together");
    }

    #[test]
    fn exec_batch_scatters_across_a_multi_worker_pool() {
        // With 2 workers, the batch must NOT be funneled through one
        // worker's exec_batch — entries go out as individual jobs so the
        // pool's parallelism is preserved.
        let (pool, log) = batch_log_pool(2);
        let t = HostTensor::zeros(&[2]);
        let batch: Vec<Vec<HostTensor>> = (0..6).map(|_| vec![t.clone()]).collect();
        let results = pool.exec_batch("m", batch);
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.as_ref().unwrap(), &vec![t.clone()]);
        }
        assert!(
            log.lock().unwrap().is_empty(),
            "multi-worker pools must scatter entries, not call executor exec_batch"
        );
    }

    #[test]
    fn scattered_batch_overlaps_across_workers() {
        // Wall-clock proof: 2 entries of 200 ms on a 2-worker pool must
        // beat the 400 ms a serial single-worker batch would take.
        let (pool, _) = echo_pool(2, Duration::from_millis(200));
        pool.load("m").unwrap();
        let t0 = std::time::Instant::now();
        let batch = vec![vec![HostTensor::zeros(&[1])], vec![HostTensor::zeros(&[1])]];
        let results = pool.exec_batch("m", batch);
        assert!(results.iter().all(|r| r.is_ok()));
        let wall = t0.elapsed();
        assert!(
            wall < Duration::from_millis(360),
            "batch entries serialized on a multi-worker pool: {wall:?}"
        );
    }

    #[test]
    fn panicking_batch_replies_per_entry_errors() {
        struct PanicBatch;
        impl PoolExecutor for PanicBatch {
            fn exec(&mut self, _n: &str, i: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                Ok(i)
            }
            fn load(&mut self, _n: &str) -> Result<()> {
                Ok(())
            }
            fn loaded_names(&self) -> Vec<String> {
                Vec::new()
            }
            fn exec_batch(
                &mut self,
                _name: &str,
                _batch: Vec<Vec<HostTensor>>,
            ) -> Vec<Result<Vec<HostTensor>>> {
                panic!("batch kernel blew up")
            }
        }
        let pool = BackendPool::spawn("panicky-batch", 1, |_| Ok(PanicBatch)).unwrap();
        let results = pool.exec_batch("m", vec![vec![], vec![]]);
        assert_eq!(results.len(), 2, "every entry must get a reply");
        assert!(results.iter().all(|r| r.is_err()));
        // The worker survives for later (non-batch) jobs.
        let t = HostTensor::zeros(&[1]);
        assert_eq!(pool.exec("m", vec![t.clone()]).unwrap(), vec![t]);
    }

    #[test]
    fn load_broadcasts_to_every_worker() {
        let (pool, log) = echo_pool(3, Duration::ZERO);
        pool.load("m").unwrap();
        let workers: BTreeSet<usize> = log.lock().unwrap().iter().copied().collect();
        assert_eq!(workers, (0..3).collect::<BTreeSet<_>>());
        assert_eq!(pool.loaded_names(), vec!["m".to_string()]);
        assert!(pool.load("poison").is_err());
    }

    #[test]
    fn spawn_factory_error_fails_cleanly() {
        let r = BackendPool::spawn("bad", 2, |worker| {
            if worker == 1 {
                anyhow::bail!("worker 1 refuses to start")
            }
            Ok(Echo {
                worker,
                loaded: BTreeSet::new(),
                load_log: Arc::new(Mutex::new(Vec::new())),
                delay: Duration::ZERO,
            })
        });
        assert!(r.is_err());
    }

    #[test]
    fn two_workers_execute_concurrently() {
        let (pool, _) = echo_pool(2, Duration::from_millis(200));
        pool.load("m").unwrap();
        let pool = Arc::new(pool);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || pool.exec("m", vec![HostTensor::zeros(&[1])]))
            })
            .collect();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        let wall = t0.elapsed();
        // Two 200 ms jobs on two workers: serial would be ≥ 400 ms; the
        // wide margin absorbs CI scheduler hiccups.
        assert!(wall < Duration::from_millis(360), "jobs serialized: {wall:?}");
    }

    #[test]
    fn single_worker_serializes() {
        let (pool, _) = echo_pool(1, Duration::from_millis(40));
        pool.load("m").unwrap();
        let pool = Arc::new(pool);
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || pool.exec("m", vec![HostTensor::zeros(&[1])]))
            })
            .collect();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        assert!(t0.elapsed() >= Duration::from_millis(80), "one worker must serialize");
    }

    #[test]
    fn panicking_executor_replies_error_and_worker_survives() {
        struct Panicky;
        impl PoolExecutor for Panicky {
            fn exec(&mut self, name: &str, i: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                if name == "boom" {
                    panic!("executor blew up");
                }
                Ok(i)
            }
            fn load(&mut self, _n: &str) -> Result<()> {
                Ok(())
            }
            fn loaded_names(&self) -> Vec<String> {
                Vec::new()
            }
        }
        let pool = BackendPool::spawn("panicky", 1, |_| Ok(Panicky)).unwrap();
        let err = pool.exec("boom", vec![]).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err:#}");
        // The worker must still be alive: subsequent jobs are served, not
        // queued forever (the old actor's dead-thread hang).
        let t = HostTensor::zeros(&[1]);
        assert_eq!(pool.exec("fine", vec![t.clone()]).unwrap(), vec![t]);
        pool.load("m").unwrap();
    }

    #[test]
    fn drop_joins_workers() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Counting;
        impl Drop for Counting {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        impl PoolExecutor for Counting {
            fn exec(&mut self, _n: &str, i: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                Ok(i)
            }
            fn load(&mut self, _n: &str) -> Result<()> {
                Ok(())
            }
            fn loaded_names(&self) -> Vec<String> {
                Vec::new()
            }
        }
        let pool = BackendPool::spawn("counting", 2, |_| Ok(Counting)).unwrap();
        assert_eq!(pool.size(), 2);
        drop(pool);
        assert_eq!(DROPS.load(Ordering::SeqCst), 2, "workers must be joined on drop");
    }
}
