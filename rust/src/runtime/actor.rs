//! Actor wrapper: owns an [`Engine`] on a dedicated thread so that
//! non-`Send` PJRT handles can serve requests from many threads.

use super::{Engine, HostTensor};
use crate::config::Paths;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::thread;

enum Request {
    Exec { name: String, inputs: Vec<HostTensor>, reply: mpsc::Sender<Result<Vec<HostTensor>>> },
    Load { name: String, reply: mpsc::Sender<Result<()>> },
    Loaded { reply: mpsc::Sender<Vec<String>> },
    Shutdown,
}

/// Owns the engine thread; dropping shuts it down.
pub struct EngineActor {
    tx: mpsc::Sender<Request>,
    join: Option<thread::JoinHandle<()>>,
}

/// Cloneable, `Send` handle for submitting work to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

impl EngineActor {
    /// Spawn the engine thread and pre-load `names`.
    pub fn spawn(paths: Paths, names: &[String]) -> Result<EngineActor> {
        let (tx, rx) = mpsc::channel::<Request>();
        let names = names.to_vec();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new().name("pjrt-engine".into()).spawn(move || {
            let mut engine = match Engine::cpu() {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for n in &names {
                if let Err(e) = engine.load(&paths, n) {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            }
            let _ = ready_tx.send(Ok(()));
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Exec { name, inputs, reply } => {
                        let _ = reply.send(engine.exec(&name, &inputs));
                    }
                    Request::Load { name, reply } => {
                        let _ = reply.send(engine.load(&paths, &name));
                    }
                    Request::Loaded { reply } => {
                        let _ = reply.send(engine.loaded_names());
                    }
                    Request::Shutdown => break,
                }
            }
        })?;
        ready_rx.recv().context("engine thread died during startup")??;
        Ok(EngineActor { tx, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle { tx: self.tx.clone() }
    }
}

impl Drop for EngineActor {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    pub fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec { name: name.to_string(), inputs, reply })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")?
    }

    pub fn load(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Load { name: name.to_string(), reply })
            .context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")?
    }

    /// Names of the artifacts resident on the engine thread (server
    /// startup logging / diagnostics).
    pub fn loaded(&self) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Request::Loaded { reply }).context("engine thread gone")?;
        rx.recv().context("engine thread dropped reply")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_spawns_and_errors_on_missing_artifact() {
        let paths = Paths::new("/nonexistent", "/nonexistent");
        let actor = EngineActor::spawn(paths, &[]).unwrap();
        let h = actor.handle();
        assert!(h.exec("ghost", vec![]).is_err());
        assert!(h.load("ghost").is_err());
        assert!(h.loaded().unwrap().is_empty());
    }

    #[test]
    fn spawn_fails_cleanly_when_preload_missing() {
        let paths = Paths::new("/nonexistent", "/nonexistent");
        let r = EngineActor::spawn(paths, &["ghost".to_string()]);
        assert!(r.is_err());
    }

    #[test]
    fn handle_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<EngineHandle>();
    }
}
