//! Pure-Rust execution backend (feature `native`): the SC-MII graph with
//! no HLO artifacts, no PJRT, no native libraries.
//!
//! Model structure mirrors the lowered jax graphs at reduced capacity:
//!
//! - **head** — voxelize the `(max_points, 4)` cloud into `(D, H, W, c_in)`
//!   statistics, then a per-voxel linear projection to `c_head` + ReLU
//!   (the split-point intermediate output).
//! - **tail** — spatial alignment of each device map via the static
//!   [`AlignMap`] gather built from the calibration [`Pose`]s, then the
//!   variant's integration ([`max_integrate`] /
//!   [`conv_integrate`](crate::integrate::conv_integrate)), then the
//!   [`BevStage`]: depth collapsed into channels, one strided 3×3 BEV
//!   conv + ReLU, and 1×1 cls/box heads.
//! - **full** (baselines) — head + [`BevStage`] on a single cloud.
//!
//! Weights load from `.npy` files under `artifacts/native/` as
//! `<model>.<layer>.npy` (layers: `head_w`, `head_b`, `integrate_w`,
//! `integrate_b`, `bev_w`, `bev_b`, `cls_w`, `cls_b`, `box_w`, `box_b`);
//! any missing file falls back to a deterministic synthetic tensor seeded
//! from the model/layer names, so the backend always runs — tests and
//! benches exercise real code on synthetic weights.
//!
//! Execution happens on the caller's thread (`&self`), so the backend is
//! inherently concurrent — no pool needed.

use super::{ExecBackend, HostTensor};
use crate::align::AlignMap;
use crate::config::{IntegrationKind, ModelMeta, Paths};
use crate::geom::Pose;
use crate::integrate::{conv_integrate, max_integrate};
use crate::utils::npy;
use crate::utils::rng::Pcg64;
use crate::voxel::{tensor_to_points, voxelize, FeatureMap};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Hidden channels of the BEV conv (the native backbone's capacity knob).
pub const NATIVE_C_MID: usize = 16;

/// `(D, H, W, C)` → `(H, W, D·C)` — depth becomes channels so the 3D map
/// can feed a 2D BEV conv (mirror of the lowered reshape).
pub fn bev_collapse(m: &FeatureMap) -> Vec<f32> {
    let [d, h, w, c] = m.shape();
    let mut out = vec![0.0f32; h * w * d * c];
    for iz in 0..d {
        for iy in 0..h {
            for ix in 0..w {
                let src = m.idx(iz, iy, ix, 0);
                let dst = (iy * w + ix) * (d * c) + iz * c;
                out[dst..dst + c].copy_from_slice(&m.data[src..src + c]);
            }
        }
    }
    out
}

/// 2D convolution over an `(H, W, C_in)` HWC input with HWIO weights
/// `(k, k, C_in, C_out)`, zero ("same") padding, stride `s`, optional
/// ReLU. Output `(H/s, W/s, C_out)`. Skips zero activations — BEV maps
/// from infrastructure LiDAR are overwhelmingly sparse.
pub fn conv2d(
    input: &[f32],
    h: usize,
    w: usize,
    c_in: usize,
    weights: &[f32],
    bias: &[f32],
    k: usize,
    stride: usize,
    relu: bool,
) -> Vec<f32> {
    let c_out = bias.len();
    assert_eq!(input.len(), h * w * c_in, "conv2d input shape mismatch");
    assert_eq!(weights.len(), k * k * c_in * c_out, "conv2d weight shape mismatch");
    assert!(k % 2 == 1, "odd kernels only");
    let (ho, wo) = (h / stride, w / stride);
    let half = (k / 2) as i64;
    let mut out = vec![0.0f32; ho * wo * c_out];
    for oy in 0..ho {
        for ox in 0..wo {
            let obase = (oy * wo + ox) * c_out;
            out[obase..obase + c_out].copy_from_slice(bias);
            for ky in 0..k {
                let iy = (oy * stride) as i64 + ky as i64 - half;
                if iy < 0 || iy >= h as i64 {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride) as i64 + kx as i64 - half;
                    if ix < 0 || ix >= w as i64 {
                        continue;
                    }
                    let ibase = (iy as usize * w + ix as usize) * c_in;
                    let wbase = (ky * k + kx) * c_in * c_out;
                    for ci in 0..c_in {
                        let v = input[ibase + ci];
                        if v == 0.0 {
                            continue;
                        }
                        let wrow = wbase + ci * c_out;
                        for oc in 0..c_out {
                            out[obase + oc] += v * weights[wrow + oc];
                        }
                    }
                }
            }
            if relu {
                for oc in 0..c_out {
                    if out[obase + oc] < 0.0 {
                        out[obase + oc] = 0.0;
                    }
                }
            }
        }
    }
    out
}

/// Per-cell dense layer: `(cells, c_in) × (c_in, c_out) + bias` —
/// equivalent to a 1×1 conv. Skips zero activations.
pub fn dense_per_cell(
    input: &[f32],
    cells: usize,
    c_in: usize,
    w: &[f32],
    b: &[f32],
) -> Vec<f32> {
    let c_out = b.len();
    assert_eq!(input.len(), cells * c_in, "dense input shape mismatch");
    assert_eq!(w.len(), c_in * c_out, "dense weight shape mismatch");
    let mut out = vec![0.0f32; cells * c_out];
    for cell in 0..cells {
        let ibase = cell * c_in;
        let obase = cell * c_out;
        out[obase..obase + c_out].copy_from_slice(b);
        for ci in 0..c_in {
            let v = input[ibase + ci];
            if v == 0.0 {
                continue;
            }
            let wrow = ci * c_out;
            for oc in 0..c_out {
                out[obase + oc] += v * w[wrow + oc];
            }
        }
    }
    out
}

/// Deterministic synthetic weights, seeded from the model/layer names —
/// stable across runs and platforms, so parity tests can rebuild the
/// exact reference graph.
pub fn synthetic_weights(model: &str, layer: &str, len: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.bytes().chain([b'/']).chain(layer.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x1_0000_0000_01b3);
    }
    let mut rng = Pcg64::new(h);
    (0..len).map(|_| (rng.uniform_f32() - 0.5) * 0.2).collect()
}

/// Shared BEV trunk: `(D, H, W, C)` map → depth-collapsed BEV → strided
/// 3×3 conv + ReLU → 1×1 cls/box heads at the head resolution.
#[derive(Clone, Debug)]
pub struct BevStage {
    pub c_in: usize,
    pub c_mid: usize,
    pub stride: usize,
    pub n_anchors: usize,
    /// 3×3 conv, HWIO `(3, 3, c_in, c_mid)`.
    pub conv_w: Vec<f32>,
    pub conv_b: Vec<f32>,
    /// 1×1 heads, `(c_mid, A)` / `(c_mid, A·8)`.
    pub cls_w: Vec<f32>,
    pub cls_b: Vec<f32>,
    pub box_w: Vec<f32>,
    pub box_b: Vec<f32>,
}

impl BevStage {
    /// Returns `(cls (hb, wb, A), boxes (hb, wb, A, 8))`.
    pub fn run(&self, integrated: &FeatureMap) -> Result<(HostTensor, HostTensor)> {
        let [d, h, w, c] = integrated.shape();
        anyhow::ensure!(
            d * c == self.c_in,
            "BEV stage expects {} collapsed channels, map has {}",
            self.c_in,
            d * c
        );
        anyhow::ensure!(
            h % self.stride == 0 && w % self.stride == 0,
            "grid ({h}, {w}) not divisible by BEV stride {}",
            self.stride
        );
        let bev = bev_collapse(integrated);
        let mid = conv2d(&bev, h, w, self.c_in, &self.conv_w, &self.conv_b, 3, self.stride, true);
        let (hb, wb) = (h / self.stride, w / self.stride);
        let cls = dense_per_cell(&mid, hb * wb, self.c_mid, &self.cls_w, &self.cls_b);
        let boxes = dense_per_cell(&mid, hb * wb, self.c_mid, &self.box_w, &self.box_b);
        Ok((
            HostTensor::new(vec![hb, wb, self.n_anchors], cls)?,
            HostTensor::new(vec![hb, wb, self.n_anchors, 8], boxes)?,
        ))
    }
}

/// Split-point head: voxel statistics → per-voxel linear → ReLU.
#[derive(Clone, Debug)]
pub struct NativeHead {
    /// `(c_in, c_head)`.
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl NativeHead {
    pub fn run(&self, meta: &ModelMeta, input: &HostTensor) -> Result<FeatureMap> {
        let g = &meta.grid;
        anyhow::ensure!(
            input.shape == vec![g.max_points, 4],
            "head expects ({}, 4) points, got {:?}",
            g.max_points,
            input.shape
        );
        let points = tensor_to_points(&input.data);
        let vox = voxelize(&points, g);
        let [d, h, w, c_in] = vox.shape();
        let mut out = dense_per_cell(&vox.data, d * h * w, c_in, &self.w, &self.b);
        for v in &mut out {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        FeatureMap::from_vec(d, h, w, self.b.len(), out)
    }
}

/// Edge-server tail: align → integrate → BEV trunk + heads.
#[derive(Clone, Debug)]
pub struct NativeTail {
    pub kind: IntegrationKind,
    /// One gather map per device (device 0 is the identity reference).
    pub aligns: Vec<AlignMap>,
    /// Conv-integration weights `(k, k, k, devices·c_head, c_head)`
    /// (DHWIO, matching [`conv_integrate`]); empty for `Max`.
    pub integrate_w: Vec<f32>,
    pub integrate_b: Vec<f32>,
    pub k: usize,
    pub bev: BevStage,
}

impl NativeTail {
    /// The integration step alone (parity tests cross-check this against
    /// the reference kernels directly).
    pub fn integrate(&self, aligned: &[FeatureMap]) -> FeatureMap {
        match self.kind {
            IntegrationKind::Max => max_integrate(aligned),
            IntegrationKind::ConvK1 | IntegrationKind::ConvK3 => {
                conv_integrate(aligned, &self.integrate_w, &self.integrate_b, self.k)
            }
        }
    }

    pub fn run(&self, meta: &ModelMeta, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(
            inputs.len() == meta.num_devices,
            "tail expects {} device maps, got {}",
            meta.num_devices,
            inputs.len()
        );
        let g = &meta.grid;
        let expect = vec![g.dims[2], g.dims[1], g.dims[0], g.c_head];
        let mut aligned = Vec::with_capacity(inputs.len());
        for (dev, t) in inputs.into_iter().enumerate() {
            anyhow::ensure!(
                t.shape == expect,
                "tail input {dev} shape {:?}, expected {:?}",
                t.shape,
                expect
            );
            let map = FeatureMap::from_vec(expect[0], expect[1], expect[2], expect[3], t.data)?;
            aligned.push(self.aligns[dev].apply(&map));
        }
        let integrated = self.integrate(&aligned);
        let (cls, boxes) = self.bev.run(&integrated)?;
        Ok(vec![cls, boxes])
    }
}

/// Baseline full model: head + BEV trunk over a single cloud.
#[derive(Clone, Debug)]
pub struct NativeFull {
    pub head: NativeHead,
    pub bev: BevStage,
}

impl NativeFull {
    pub fn run(&self, meta: &ModelMeta, input: &HostTensor) -> Result<Vec<HostTensor>> {
        let feat = self.head.run(meta, input)?;
        let (cls, boxes) = self.bev.run(&feat)?;
        Ok(vec![cls, boxes])
    }
}

/// One resident native model.
#[derive(Clone, Debug)]
pub enum NativeModel {
    Head(NativeHead),
    Tail(NativeTail),
    Full(NativeFull),
}

/// The pure-Rust [`ExecBackend`]. Model names resolve against
/// `model_meta.json` exactly like HLO artifact names do, so the serving
/// layers are oblivious to the substrate swap.
pub struct NativeBackend {
    meta: ModelMeta,
    /// Device → common-frame calibration poses (index = device id).
    poses: Vec<Pose>,
    weights_dir: Option<PathBuf>,
    models: Mutex<HashMap<String, Arc<NativeModel>>>,
}

impl NativeBackend {
    pub fn new(
        meta: ModelMeta,
        poses: Vec<Pose>,
        weights_dir: Option<PathBuf>,
    ) -> Result<NativeBackend> {
        anyhow::ensure!(
            poses.len() >= meta.num_devices,
            "need one calibration pose per device ({} < {})",
            poses.len(),
            meta.num_devices
        );
        Ok(NativeBackend { meta, poses, weights_dir, models: Mutex::new(HashMap::new()) })
    }

    /// Build from the artifact directory: calibration from `calib.json`
    /// when present, weights from `artifacts/native/`. A *missing*
    /// calib.json falls back to identity poses (single-rig demos, tests
    /// with zero artifacts); a present-but-corrupt one is an error —
    /// silently serving unaligned integration would look like a model
    /// problem, not a config problem.
    pub fn from_paths(paths: &Paths, meta: &ModelMeta) -> Result<NativeBackend> {
        let calib_path = paths.calib();
        let poses = if calib_path.exists() {
            crate::config::load_calib(paths)
                .with_context(|| format!("parse {}", calib_path.display()))?
        } else {
            log::warn!(
                "native backend: {} missing; aligning with identity poses",
                calib_path.display()
            );
            vec![Pose::IDENTITY; meta.num_devices]
        };
        NativeBackend::new(meta.clone(), poses, Some(paths.artifacts.join("native")))
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Shared handle to a resident model (parity tests rebuild the
    /// reference graph from the exact weights the backend runs).
    pub fn model(&self, name: &str) -> Option<Arc<NativeModel>> {
        self.models.lock().unwrap().get(name).cloned()
    }

    /// One weight tensor: `.npy` override when present, deterministic
    /// synthetic fallback otherwise.
    fn layer(&self, model: &str, layer: &str, len: usize) -> Result<Vec<f32>> {
        if let Some(dir) = &self.weights_dir {
            let path = dir.join(format!("{model}.{layer}.npy"));
            if path.exists() {
                let arr = npy::read(&path)?;
                let data = arr
                    .as_f32()
                    .with_context(|| format!("native weight {}", path.display()))?;
                anyhow::ensure!(
                    data.len() == len,
                    "{} has {} values, expected {len}",
                    path.display(),
                    data.len()
                );
                return Ok(data);
            }
        }
        Ok(synthetic_weights(model, layer, len))
    }

    fn head_weights(&self, name: &str) -> Result<NativeHead> {
        let g = &self.meta.grid;
        Ok(NativeHead {
            w: self.layer(name, "head_w", g.c_in * g.c_head)?,
            b: self.layer(name, "head_b", g.c_head)?,
        })
    }

    fn bev_weights(&self, name: &str) -> Result<BevStage> {
        let g = &self.meta.grid;
        let [hb, wb] = self.meta.bev_dims;
        anyhow::ensure!(
            hb > 0 && wb > 0 && g.dims[1] % hb == 0 && g.dims[0] % wb == 0,
            "bev_dims {:?} must evenly divide grid {:?}",
            self.meta.bev_dims,
            g.dims
        );
        anyhow::ensure!(
            g.dims[1] / hb == g.dims[0] / wb,
            "anisotropic BEV strides unsupported (grid {:?}, bev {:?})",
            g.dims,
            self.meta.bev_dims
        );
        let stride = g.dims[1] / hb;
        let c_in = g.dims[2] * g.c_head;
        let c_mid = NATIVE_C_MID;
        let a = self.meta.anchors.len();
        Ok(BevStage {
            c_in,
            c_mid,
            stride,
            n_anchors: a,
            conv_w: self.layer(name, "bev_w", 3 * 3 * c_in * c_mid)?,
            conv_b: self.layer(name, "bev_b", c_mid)?,
            cls_w: self.layer(name, "cls_w", c_mid * a)?,
            cls_b: self.layer(name, "cls_b", a)?,
            box_w: self.layer(name, "box_w", c_mid * a * 8)?,
            box_b: self.layer(name, "box_b", a * 8)?,
        })
    }

    fn build_model(&self, name: &str) -> Result<NativeModel> {
        let meta = &self.meta;
        for v in &meta.variants {
            if v.heads.iter().any(|h| h == name) {
                return Ok(NativeModel::Head(self.head_weights(name)?));
            }
            if v.tail == name {
                let aligns: Vec<AlignMap> = (0..meta.num_devices)
                    .map(|d| AlignMap::build(&meta.grid, &self.poses[d], 1))
                    .collect();
                let (k, integrate_w, integrate_b) = match v.integration {
                    IntegrationKind::Max => (1, Vec::new(), Vec::new()),
                    IntegrationKind::ConvK1 => self.integrate_weights(name, 1)?,
                    IntegrationKind::ConvK3 => self.integrate_weights(name, 3)?,
                };
                return Ok(NativeModel::Tail(NativeTail {
                    kind: v.integration,
                    aligns,
                    integrate_w,
                    integrate_b,
                    k,
                    bev: self.bev_weights(name)?,
                }));
            }
        }
        if meta.single_full.iter().any(|n| n == name) || meta.input_integration_full == name {
            return Ok(NativeModel::Full(NativeFull {
                head: self.head_weights(name)?,
                bev: self.bev_weights(name)?,
            }));
        }
        bail!("model {name:?} is not described by model_meta (native backend)")
    }

    fn integrate_weights(&self, name: &str, k: usize) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        let g = &self.meta.grid;
        let c_in = self.meta.num_devices * g.c_head;
        let c_out = g.c_head;
        Ok((
            k,
            self.layer(name, "integrate_w", k * k * k * c_in * c_out)?,
            self.layer(name, "integrate_b", c_out)?,
        ))
    }
}

impl ExecBackend for NativeBackend {
    fn backend_name(&self) -> &str {
        "native"
    }

    fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let model = self.models.lock().unwrap().get(name).cloned();
        let Some(model) = model else {
            bail!("model {name:?} not loaded in native backend (call load first)");
        };
        match &*model {
            NativeModel::Head(head) => {
                anyhow::ensure!(inputs.len() == 1, "head takes one input");
                let feat = head.run(&self.meta, &inputs[0])?;
                let [d, h, w, c] = feat.shape();
                Ok(vec![HostTensor::new(vec![d, h, w, c], feat.data)?])
            }
            NativeModel::Tail(tail) => tail.run(&self.meta, inputs),
            NativeModel::Full(full) => {
                anyhow::ensure!(inputs.len() == 1, "full model takes one input");
                full.run(&self.meta, &inputs[0])
            }
        }
    }

    fn load(&self, name: &str) -> Result<()> {
        if self.models.lock().unwrap().contains_key(name) {
            return Ok(());
        }
        // Built outside the lock: alignment-map construction is the
        // expensive part and must not serialize concurrent execs.
        let model = self.build_model(name)?;
        self.models
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(model));
        Ok(())
    }

    fn loaded_names(&self) -> Vec<String> {
        self.models.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quarter-resolution meta so conv-k3 integration stays fast in
    /// debug test runs; structure matches production.
    fn small_meta() -> ModelMeta {
        let mut meta = ModelMeta::test_default();
        meta.grid.dims = [16, 16, 4];
        meta.grid.max_points = 512;
        meta.bev_dims = [8, 8];
        meta
    }

    fn backend() -> NativeBackend {
        let poses = vec![
            Pose::IDENTITY,
            Pose::from_xyz_rpy(0.8, 0.0, 0.0, 0.0, 0.0, 0.0),
        ];
        NativeBackend::new(small_meta(), poses, None).unwrap()
    }

    fn feat_shape(meta: &ModelMeta) -> Vec<usize> {
        let g = &meta.grid;
        vec![g.dims[2], g.dims[1], g.dims[0], g.c_head]
    }

    #[test]
    fn tail_runs_all_variants_with_correct_shapes() {
        let b = backend();
        let meta = b.meta().clone();
        let shape = feat_shape(&meta);
        for kind in IntegrationKind::all() {
            let tail = meta.variant(kind).unwrap().tail.clone();
            b.load(&tail).unwrap();
            let inputs = vec![HostTensor::zeros(&shape), HostTensor::zeros(&shape)];
            let out = b.exec(&tail, inputs).unwrap();
            assert_eq!(out.len(), 2, "{kind:?}");
            let [hb, wb] = meta.bev_dims;
            let a = meta.anchors.len();
            assert_eq!(out[0].shape, vec![hb, wb, a]);
            assert_eq!(out[1].shape, vec![hb, wb, a, 8]);
            assert!(out[0].data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn head_produces_meta_shaped_features() {
        let b = backend();
        let meta = b.meta().clone();
        let head = meta.variant(IntegrationKind::Max).unwrap().heads[0].clone();
        b.load(&head).unwrap();
        let g = &meta.grid;
        let input = HostTensor::zeros(&[g.max_points, 4]);
        let out = b.exec(&head, vec![input]).unwrap();
        assert_eq!(out[0].shape, feat_shape(&meta));
        // ReLU output, and a zero cloud voxelizes to zeros → uniform map.
        assert!(out[0].data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn full_baseline_runs() {
        let b = backend();
        let meta = b.meta().clone();
        b.load("single_dev0").unwrap();
        b.load("input_integration").unwrap();
        let g = &meta.grid;
        let out = b
            .exec("single_dev0", vec![HostTensor::zeros(&[g.max_points, 4])])
            .unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn unknown_model_rejected() {
        let b = backend();
        assert!(b.load("no_such_model").is_err());
        assert!(b.exec("tail_max", vec![]).is_err(), "exec before load must error");
        assert!(b.loaded_names().is_empty());
    }

    #[test]
    fn exec_is_deterministic() {
        let b = backend();
        let meta = b.meta().clone();
        let tail = meta.variant(IntegrationKind::ConvK1).unwrap().tail.clone();
        b.load(&tail).unwrap();
        let shape = feat_shape(&meta);
        let mut t = HostTensor::zeros(&shape);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = ((i * 13) % 31) as f32 * 0.05;
        }
        let a = b.exec(&tail, vec![t.clone(), t.clone()]).unwrap();
        let c = b.exec(&tail, vec![t.clone(), t]).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn npy_weight_override_is_used() {
        let dir = std::env::temp_dir().join("scmii_native_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let meta = ModelMeta::test_default();
        let g = &meta.grid;
        // Zero head weights → head output must be relu(bias) = 0 everywhere.
        let zeros = vec![0.0f32; g.c_in * g.c_head];
        npy::write(
            &dir.join("head_max_dev0.head_w.npy"),
            &npy::NpyArray::from_f32(&[g.c_in, g.c_head], &zeros),
        )
        .unwrap();
        let zero_b = vec![0.0f32; g.c_head];
        npy::write(
            &dir.join("head_max_dev0.head_b.npy"),
            &npy::NpyArray::from_f32(&[g.c_head], &zero_b),
        )
        .unwrap();
        let b = NativeBackend::new(
            meta.clone(),
            vec![Pose::IDENTITY; 2],
            Some(dir),
        )
        .unwrap();
        b.load("head_max_dev0").unwrap();
        // A cloud with one in-range point: synthetic weights would give a
        // non-zero voxel; the zero .npy weights must win.
        let mut cloud = vec![0.0f32; g.max_points * 4];
        cloud[0] = 1.0;
        cloud[1] = 1.0;
        cloud[2] = -3.0;
        cloud[3] = 0.5;
        let input = HostTensor::new(vec![g.max_points, 4], cloud).unwrap();
        let out = b.exec("head_max_dev0", vec![input]).unwrap();
        assert!(out[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn synthetic_weights_are_stable_and_name_dependent() {
        let a = synthetic_weights("tail_max", "bev_w", 16);
        let b = synthetic_weights("tail_max", "bev_w", 16);
        let c = synthetic_weights("tail_conv_k1", "bev_w", 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|v| v.abs() <= 0.1));
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel, identity weight matrix: output == input.
        let input: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let mut w = vec![0.0f32; 2 * 2];
        w[0] = 1.0;
        w[3] = 1.0;
        let out = conv2d(&input, 4, 4, 2, &w, &[0.0, 0.0], 1, 1, false);
        assert_eq!(out, input);
    }

    #[test]
    fn conv2d_stride_downsamples() {
        let input = vec![1.0f32; 4 * 4];
        let w = vec![1.0f32; 9]; // 3x3, c_in=1, c_out=1
        let out = conv2d(&input, 4, 4, 1, &w, &[0.0], 3, 2, false);
        assert_eq!(out.len(), 2 * 2);
        // Top-left output sees a 2x2 valid patch (corner), value 4.
        assert_eq!(out[0], 4.0);
    }
}
