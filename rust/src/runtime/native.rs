//! Pure-Rust execution backend (feature `native`): the SC-MII graph with
//! no HLO artifacts, no PJRT, no native libraries.
//!
//! Model structure mirrors the lowered jax graphs at reduced capacity:
//!
//! - **head** — voxelize the `(max_points, 4)` cloud into `(D, H, W, c_in)`
//!   statistics, then a per-voxel linear projection to `c_head` + ReLU
//!   (the split-point intermediate output).
//! - **tail** — spatial alignment of each device map via the static
//!   [`AlignMap`] gather built from the calibration [`Pose`]s, then the
//!   variant's integration ([`max_integrate_into`] /
//!   [`conv_integrate_into`]), then the
//!   [`BevStage`]: depth collapsed into channels, one strided 3×3 BEV
//!   conv + ReLU, and 1×1 cls/box heads.
//! - **full** (baselines) — head + [`BevStage`] on a single cloud.
//!
//! ## Split depths
//!
//! Every head/tail pair is served at three named cut depths
//! (`crate::config::SPLIT_DEPTHS`). The default `split-mid` resolves the
//! bare artifact names above, byte-identical to pre-split builds.
//! `split-shallow` ships raw voxel statistics (`c_in` channels) and the
//! tail runs each device's deferred projection (same weights, relocated
//! compute — outputs match `split-mid` exactly); `split-deep` adds a
//! device-side bottleneck to [`deep_channels`] channels (`deep_w`/
//! `deep_b`) that the tail expands back (`expand_w`/`expand_b`) before
//! alignment — a smaller uplink at reduced capacity. Non-default depths
//! are distinct executables named `<base>@<split>`, so batch planners
//! never coalesce across depths and synthetic weights stay deterministic
//! per depth.
//!
//! Weights load from `.npy` files under `artifacts/native/` as
//! `<model>.<layer>.npy` (layers: `head_w`, `head_b`, `integrate_w`,
//! `integrate_b`, `bev_w`, `bev_b`, `cls_w`, `cls_b`, `box_w`, `box_b`);
//! any missing file falls back to a deterministic synthetic tensor seeded
//! from the model/layer names, so the backend always runs — tests and
//! benches exercise real code on synthetic weights.
//!
//! Single-frame execution happens on the caller's thread (`&self`), so
//! the backend is inherently concurrent. Batched tails additionally fan
//! the per-frame align/integrate stage across a small shared
//! [`ThreadPool`] (the BEV trunk then runs stacked on the caller's
//! thread).
//!
//! ## Batched tails
//!
//! [`ExecBackend::exec_batch`] is overridden for tail models with a
//! genuinely batched path: per-frame alignment + integration feed a
//! **stacked** BEV trunk ([`BevStage::run_batch`]) — the 3×3 conv
//! ([`conv2d_batch`]) reuses every weight row across all frames of the
//! batch, and the 1×1 cls/box heads run as a single [`dense_per_cell`]
//! pass over the frames concatenated along a leading batch axis. The
//! accumulation order per frame is identical to the unbatched kernels,
//! so batched and unbatched outputs are bit-identical.
//!
//! ## Hot-path kernels, lanes and the arena
//!
//! The inner loops below marked `// xtask: hot` are the per-frame hot
//! path. They follow three rules, enforced by `cargo run -p xtask --
//! lint`:
//!
//! - **No allocation** (`vec![]`) and **no `.clone()`** inside a hot
//!   function — scratch comes from the tail's shared
//!   [`Arena`](super::arena::Arena), and public wrapper functions own
//!   whatever allocation remains.
//! - **Exact-size lane chunks**: output-channel loops run over 8-wide
//!   `chunks_exact` array views (`axpy_lanes`-style), so the
//!   autovectorizer sees fixed-size, bounds-check-free bodies.
//! - **Fixed summation order**: lane chunking never reorders the per
//!   output-element addition sequence, so lane-chunked kernels are
//!   byte-identical to the scalar references in
//!   [`crate::integrate`] (proven by `tests/kernels.rs`).

use super::arena::Arena;
use super::{ExecBackend, HostTensor};
use crate::align::AlignMap;
use crate::config::{
    deep_channels, executable_split, normalize_split, split_executable, IntegrationKind,
    ModelMeta, Paths, VariantMeta, DEFAULT_SPLIT, SPLIT_DEEP, SPLIT_SHALLOW,
};
use crate::geom::Pose;
use crate::utils::npy;
use crate::utils::rng::Pcg64;
use crate::utils::threadpool::ThreadPool;
use crate::voxel::{tensor_to_points, voxelize, FeatureMap};
use crate::sync::{lock_or_recover, Arc, Mutex};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Hidden channels of the BEV conv (the native backbone's capacity knob).
pub const NATIVE_C_MID: usize = 16;

/// `(D, H, W, C)` → `(H, W, D·C)` — depth becomes channels so the 3D map
/// can feed a 2D BEV conv (mirror of the lowered reshape).
pub fn bev_collapse(m: &FeatureMap) -> Vec<f32> {
    let [d, h, w, c] = m.shape();
    let mut out = vec![0.0f32; h * w * d * c];
    for iz in 0..d {
        for iy in 0..h {
            for ix in 0..w {
                let src = m.idx(iz, iy, ix, 0);
                let dst = (iy * w + ix) * (d * c) + iz * c;
                out[dst..dst + c].copy_from_slice(&m.data[src..src + c]);
            }
        }
    }
    out
}

/// `out[i] += v * w[i]` over two equal-length rows, split into exact
/// 8-wide lane chunks plus a scalar tail. The `&[f32; 8]` array views
/// erase bounds checks and give the autovectorizer a fixed-trip-count
/// body it can map straight onto SIMD lanes. Each output element still
/// receives exactly one addition per call, in slice order, so results
/// are byte-identical to the plain scalar loop.
// xtask: hot
#[inline]
fn axpy_lanes(out: &mut [f32], w: &[f32], v: f32) {
    const LANES: usize = 8;
    debug_assert_eq!(out.len(), w.len());
    let split = out.len() - out.len() % LANES;
    let (out_body, out_tail) = out.split_at_mut(split);
    let (w_body, w_tail) = w.split_at(split);
    for (o8, w8) in out_body.chunks_exact_mut(LANES).zip(w_body.chunks_exact(LANES)) {
        let o8: &mut [f32; LANES] = o8.try_into().expect("exact lane chunk");
        let w8: &[f32; LANES] = w8.try_into().expect("exact lane chunk");
        for l in 0..LANES {
            o8[l] += v * w8[l];
        }
    }
    for (o, &wv) in out_tail.iter_mut().zip(w_tail) {
        *o += v * wv;
    }
}

/// 2D convolution over an `(H, W, C_in)` HWC input with HWIO weights
/// `(k, k, C_in, C_out)`, zero ("same") padding, stride `s`, optional
/// ReLU. Output `(H/s, W/s, C_out)`. Skips zero activations — BEV maps
/// from infrastructure LiDAR are overwhelmingly sparse.
///
/// Thin wrapper over [`conv2d_batch`] with B=1, so the lane-chunked
/// inner loop exists exactly once; outputs are bit-identical to the
/// historical single-frame kernel (same per-element summation order).
pub fn conv2d(
    input: &[f32],
    h: usize,
    w: usize,
    c_in: usize,
    weights: &[f32],
    bias: &[f32],
    k: usize,
    stride: usize,
    relu: bool,
) -> Vec<f32> {
    assert_eq!(input.len(), h * w * c_in, "conv2d input shape mismatch");
    let mut outs = conv2d_batch(&[input], h, w, c_in, weights, bias, k, stride, relu);
    outs.pop().expect("B=1 batch yields one output")
}

/// [`conv2d`] over a micro-batch of same-shaped `(H, W, C_in)` inputs
/// sharing one set of weights. The batch loop sits *inside* the kernel
/// position loop, so each weight row is loaded once and applied to every
/// frame of the batch — the amortization a per-frame loop cannot get.
/// Per frame, the accumulation order is identical to [`conv2d`], so
/// outputs are bit-identical to B separate calls.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_batch(
    inputs: &[&[f32]],
    h: usize,
    w: usize,
    c_in: usize,
    weights: &[f32],
    bias: &[f32],
    k: usize,
    stride: usize,
    relu: bool,
) -> Vec<Vec<f32>> {
    let c_out = bias.len();
    for input in inputs {
        assert_eq!(input.len(), h * w * c_in, "conv2d_batch input shape mismatch");
    }
    assert_eq!(weights.len(), k * k * c_in * c_out, "conv2d_batch weight shape mismatch");
    assert!(k % 2 == 1, "odd kernels only");
    let (ho, wo) = (h / stride, w / stride);
    let mut outs = vec![vec![0.0f32; ho * wo * c_out]; inputs.len()];
    {
        let mut out_slices: Vec<&mut [f32]> = outs.iter_mut().map(|o| o.as_mut_slice()).collect();
        conv2d_batch_into(inputs, h, w, c_in, weights, bias, k, stride, relu, &mut out_slices);
    }
    outs
}

/// Allocation-free inner loop of [`conv2d_batch`]: the batch loop sits
/// inside the kernel-position loop so each weight row is loaded once per
/// tap, and the per-channel accumulation runs as 8-wide lane chunks
/// ([`axpy_lanes`]). Per frame and per output element the addition order
/// matches the scalar kernel exactly — outputs are byte-identical.
// xtask: hot
#[allow(clippy::too_many_arguments)]
fn conv2d_batch_into(
    inputs: &[&[f32]],
    h: usize,
    w: usize,
    c_in: usize,
    weights: &[f32],
    bias: &[f32],
    k: usize,
    stride: usize,
    relu: bool,
    outs: &mut [&mut [f32]],
) {
    let c_out = bias.len();
    let (ho, wo) = (h / stride, w / stride);
    let half = (k / 2) as i64;
    for oy in 0..ho {
        for ox in 0..wo {
            let obase = (oy * wo + ox) * c_out;
            for out in outs.iter_mut() {
                out[obase..obase + c_out].copy_from_slice(bias);
            }
            for ky in 0..k {
                let iy = (oy * stride) as i64 + ky as i64 - half;
                if iy < 0 || iy >= h as i64 {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride) as i64 + kx as i64 - half;
                    if ix < 0 || ix >= w as i64 {
                        continue;
                    }
                    let ibase = (iy as usize * w + ix as usize) * c_in;
                    let wbase = (ky * k + kx) * c_in * c_out;
                    for ci in 0..c_in {
                        let wrow = &weights[wbase + ci * c_out..wbase + (ci + 1) * c_out];
                        for (bi, input) in inputs.iter().enumerate() {
                            let v = input[ibase + ci];
                            if v == 0.0 {
                                continue;
                            }
                            axpy_lanes(&mut outs[bi][obase..obase + c_out], wrow, v);
                        }
                    }
                }
            }
            if relu {
                for out in outs.iter_mut() {
                    for o in &mut out[obase..obase + c_out] {
                        if *o < 0.0 {
                            *o = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// Per-cell dense layer: `(cells, c_in) × (c_in, c_out) + bias` —
/// equivalent to a 1×1 conv. Skips zero activations.
pub fn dense_per_cell(
    input: &[f32],
    cells: usize,
    c_in: usize,
    w: &[f32],
    b: &[f32],
) -> Vec<f32> {
    let c_out = b.len();
    assert_eq!(input.len(), cells * c_in, "dense input shape mismatch");
    assert_eq!(w.len(), c_in * c_out, "dense weight shape mismatch");
    let mut out = vec![0.0f32; cells * c_out];
    dense_per_cell_into(input, cells, c_in, w, b, &mut out);
    out
}

/// Allocation-free inner loop of [`dense_per_cell`], lane-chunked via
/// [`axpy_lanes`]; byte-identical to the scalar loop.
// xtask: hot
fn dense_per_cell_into(
    input: &[f32],
    cells: usize,
    c_in: usize,
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
) {
    let c_out = b.len();
    for cell in 0..cells {
        let ibase = cell * c_in;
        let obase = cell * c_out;
        out[obase..obase + c_out].copy_from_slice(b);
        for ci in 0..c_in {
            let v = input[ibase + ci];
            if v == 0.0 {
                continue;
            }
            axpy_lanes(&mut out[obase..obase + c_out], &w[ci * c_out..(ci + 1) * c_out], v);
        }
    }
}

/// Element-wise max integration into a caller-provided buffer — the
/// lane-chunked, allocation-free mirror of
/// [`max_integrate`](crate::integrate::max_integrate). `out` is fully
/// overwritten (no pre-zeroing contract). Per element, the comparison
/// sequence matches the reference exactly, so outputs are byte-identical
/// (including NaN handling: a NaN in a later map never replaces a
/// finite value).
// xtask: hot
pub fn max_integrate_into(maps: &[FeatureMap], out: &mut [f32]) {
    let (first, rest) = maps.split_first().expect("max integration needs at least one map");
    assert_eq!(out.len(), first.data.len(), "integration output length mismatch");
    out.copy_from_slice(&first.data);
    for m in rest {
        assert_eq!(m.shape(), first.shape(), "feature map shape mismatch");
        max_fold_lanes(&m.data, out);
    }
}

/// `out[i] = max(out[i], src[i])` in exact 8-wide lane chunks.
// xtask: hot
#[inline]
fn max_fold_lanes(src: &[f32], out: &mut [f32]) {
    const LANES: usize = 8;
    let split = out.len() - out.len() % LANES;
    let (o_body, o_tail) = out.split_at_mut(split);
    let (s_body, s_tail) = src.split_at(split);
    for (o8, s8) in o_body.chunks_exact_mut(LANES).zip(s_body.chunks_exact(LANES)) {
        let o8: &mut [f32; LANES] = o8.try_into().expect("exact lane chunk");
        let s8: &[f32; LANES] = s8.try_into().expect("exact lane chunk");
        for l in 0..LANES {
            if s8[l] > o8[l] {
                o8[l] = s8[l];
            }
        }
    }
    for (o, &s) in o_tail.iter_mut().zip(s_tail) {
        if s > *o {
            *o = s;
        }
    }
}

/// Concat + conv3d integration into a caller-provided buffer — the
/// lane-chunked, allocation-free mirror of
/// [`conv_integrate`](crate::integrate::conv_integrate). All `c_out`
/// accumulators advance together through the identical tap/map/channel
/// sequence the scalar reference walks per output channel, so outputs
/// are byte-identical. `out` is fully overwritten (accumulation starts
/// from the bias), length `d·h·w·c_out`.
// xtask: hot
pub fn conv_integrate_into(
    maps: &[FeatureMap],
    weights: &[f32],
    bias: &[f32],
    k: usize,
    out: &mut [f32],
) {
    let first = maps.first().expect("conv integration needs at least one map");
    let [d, h, w, c_each] = first.shape();
    for m in maps {
        assert_eq!(m.shape(), first.shape(), "feature map shape mismatch");
    }
    let c_in = c_each * maps.len();
    let c_out = bias.len();
    assert_eq!(weights.len(), k * k * k * c_in * c_out, "weight shape mismatch");
    assert!(k % 2 == 1, "odd kernels only");
    assert_eq!(out.len(), d * h * w * c_out, "integration output length mismatch");
    let half = (k / 2) as i64;
    for oz in 0..d as i64 {
        for oy in 0..h as i64 {
            for ox in 0..w as i64 {
                let obase = ((oz as usize * h + oy as usize) * w + ox as usize) * c_out;
                let acc = &mut out[obase..obase + c_out];
                acc.copy_from_slice(bias);
                for kz in 0..k as i64 {
                    let iz = oz + kz - half;
                    if iz < 0 || iz >= d as i64 {
                        continue;
                    }
                    for ky in 0..k as i64 {
                        let iy = oy + ky - half;
                        if iy < 0 || iy >= h as i64 {
                            continue;
                        }
                        for kx in 0..k as i64 {
                            let ix = ox + kx - half;
                            if ix < 0 || ix >= w as i64 {
                                continue;
                            }
                            let wbase =
                                (((kz as usize * k + ky as usize) * k + kx as usize) * c_in)
                                    * c_out;
                            for (mi, m) in maps.iter().enumerate() {
                                let src = m.voxel(iz as usize, iy as usize, ix as usize);
                                let cbase = wbase + mi * c_each * c_out;
                                for ci in 0..c_each {
                                    let wrow = &weights[cbase + ci * c_out
                                        ..cbase + (ci + 1) * c_out];
                                    axpy_lanes(acc, wrow, src[ci]);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Deterministic synthetic weights, seeded from the model/layer names —
/// stable across runs and platforms, so parity tests can rebuild the
/// exact reference graph.
pub fn synthetic_weights(model: &str, layer: &str, len: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.bytes().chain([b'/']).chain(layer.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x1_0000_0000_01b3);
    }
    let mut rng = Pcg64::new(h);
    (0..len).map(|_| (rng.uniform_f32() - 0.5) * 0.2).collect()
}

/// Shared BEV trunk: `(D, H, W, C)` map → depth-collapsed BEV → strided
/// 3×3 conv + ReLU → 1×1 cls/box heads at the head resolution.
#[derive(Clone, Debug)]
pub struct BevStage {
    /// Collapsed input channels (`D·C` of the integrated map).
    pub c_in: usize,
    /// Hidden channels of the BEV conv ([`NATIVE_C_MID`]).
    pub c_mid: usize,
    /// Spatial stride of the BEV conv (grid → head resolution).
    pub stride: usize,
    /// Anchors per BEV cell (`A`).
    pub n_anchors: usize,
    /// 3×3 conv, HWIO `(3, 3, c_in, c_mid)`.
    pub conv_w: Vec<f32>,
    /// 3×3 conv bias, `(c_mid,)`.
    pub conv_b: Vec<f32>,
    /// 1×1 cls head, `(c_mid, A)`.
    pub cls_w: Vec<f32>,
    /// cls head bias, `(A,)`.
    pub cls_b: Vec<f32>,
    /// 1×1 box head, `(c_mid, A·8)`.
    pub box_w: Vec<f32>,
    /// box head bias, `(A·8,)`.
    pub box_b: Vec<f32>,
}

impl BevStage {
    /// Returns `(cls (hb, wb, A), boxes (hb, wb, A, 8))`.
    pub fn run(&self, integrated: &FeatureMap) -> Result<(HostTensor, HostTensor)> {
        let [d, h, w, c] = integrated.shape();
        anyhow::ensure!(
            d * c == self.c_in,
            "BEV stage expects {} collapsed channels, map has {}",
            self.c_in,
            d * c
        );
        anyhow::ensure!(
            h % self.stride == 0 && w % self.stride == 0,
            "grid ({h}, {w}) not divisible by BEV stride {}",
            self.stride
        );
        let bev = bev_collapse(integrated);
        let mid = conv2d(&bev, h, w, self.c_in, &self.conv_w, &self.conv_b, 3, self.stride, true);
        let (hb, wb) = (h / self.stride, w / self.stride);
        let cls = dense_per_cell(&mid, hb * wb, self.c_mid, &self.cls_w, &self.cls_b);
        let boxes = dense_per_cell(&mid, hb * wb, self.c_mid, &self.box_w, &self.box_b);
        Ok((
            HostTensor::new(vec![hb, wb, self.n_anchors], cls)?,
            HostTensor::new(vec![hb, wb, self.n_anchors, 8], boxes)?,
        ))
    }

    /// [`run`](Self::run) over a micro-batch of same-shaped integrated
    /// maps, stacked along a leading batch axis: the BEV conv runs as one
    /// [`conv2d_batch`] call sharing weight loads across frames, and the
    /// 1×1 heads run as a single [`dense_per_cell`] pass over all
    /// `B·hb·wb` cells. Outputs are bit-identical to B [`run`](Self::run)
    /// calls.
    pub fn run_batch(&self, batch: &[&FeatureMap]) -> Result<Vec<(HostTensor, HostTensor)>> {
        let Some(first) = batch.first() else {
            return Ok(Vec::new());
        };
        let [d, h, w, c] = first.shape();
        for m in batch {
            anyhow::ensure!(
                m.shape() == first.shape(),
                "batched BEV stage needs same-shaped maps: {:?} vs {:?}",
                m.shape(),
                first.shape()
            );
        }
        anyhow::ensure!(
            d * c == self.c_in,
            "BEV stage expects {} collapsed channels, map has {}",
            self.c_in,
            d * c
        );
        anyhow::ensure!(
            h % self.stride == 0 && w % self.stride == 0,
            "grid ({h}, {w}) not divisible by BEV stride {}",
            self.stride
        );
        let bevs: Vec<Vec<f32>> = batch.iter().map(|m| bev_collapse(m)).collect();
        let bev_refs: Vec<&[f32]> = bevs.iter().map(|b| b.as_slice()).collect();
        let mids = conv2d_batch(
            &bev_refs, h, w, self.c_in, &self.conv_w, &self.conv_b, 3, self.stride, true,
        );
        let (hb, wb) = (h / self.stride, w / self.stride);
        let cells = hb * wb;
        // Stack the batch along a leading axis for the 1×1 heads: one
        // dense pass over B·hb·wb cells.
        let mut stacked = Vec::with_capacity(batch.len() * cells * self.c_mid);
        for mid in &mids {
            stacked.extend_from_slice(mid);
        }
        let cls_all =
            dense_per_cell(&stacked, batch.len() * cells, self.c_mid, &self.cls_w, &self.cls_b);
        let box_all =
            dense_per_cell(&stacked, batch.len() * cells, self.c_mid, &self.box_w, &self.box_b);
        let a = self.n_anchors;
        (0..batch.len())
            .map(|b| {
                let cls = cls_all[b * cells * a..(b + 1) * cells * a].to_vec();
                let boxes = box_all[b * cells * a * 8..(b + 1) * cells * a * 8].to_vec();
                Ok((
                    HostTensor::new(vec![hb, wb, a], cls)?,
                    HostTensor::new(vec![hb, wb, a, 8], boxes)?,
                ))
            })
            .collect()
    }
}

/// One per-voxel dense + ReLU stage of the split-point encoder. The
/// encoder is a chain of these; a split depth is a cut after some prefix
/// of the chain — the device runs the prefix, the tail runs the rest.
#[derive(Clone, Debug)]
pub struct DenseStage {
    /// Input channels of the stage.
    pub c_in: usize,
    /// Output channels of the stage.
    pub c_out: usize,
    /// Per-voxel weights, `(c_in, c_out)`.
    pub w: Vec<f32>,
    /// Bias, `(c_out,)`.
    pub b: Vec<f32>,
}

impl DenseStage {
    /// Apply the stage (+ ReLU) across every cell of `map`, drawing the
    /// output buffer from `scratch` and donating the input map's backing
    /// store back to the arena.
    fn apply(&self, scratch: &Arena, map: FeatureMap) -> Result<FeatureMap> {
        let [d, h, w, c] = map.shape();
        anyhow::ensure!(
            c == self.c_in,
            "dense stage expects {} channels, map has {c}",
            self.c_in
        );
        let cells = d * h * w;
        let mut out = scratch.take(cells * self.c_out);
        dense_per_cell_into(&map.data, cells, self.c_in, &self.w, &self.b, &mut out);
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        scratch.give(map.data);
        FeatureMap::from_vec(d, h, w, self.c_out, out)
    }
}

/// Split-point head: voxel statistics → zero or more per-voxel dense +
/// ReLU stages. The stage count is the split depth — none for
/// `split-shallow` (raw statistics go on the wire), one for the default
/// `split-mid` projection, two for `split-deep`'s extra bottleneck.
#[derive(Clone, Debug)]
pub struct NativeHead {
    /// Per-voxel stages applied after voxelization, device side.
    pub stages: Vec<DenseStage>,
}

impl NativeHead {
    /// Voxelize one `(max_points, 4)` cloud and run the device-side
    /// stages — the intermediate output that goes on the wire.
    pub fn run(&self, meta: &ModelMeta, input: &HostTensor) -> Result<FeatureMap> {
        let g = &meta.grid;
        anyhow::ensure!(
            input.shape == vec![g.max_points, 4],
            "head expects ({}, 4) points, got {:?}",
            g.max_points,
            input.shape
        );
        let points = tensor_to_points(&input.data);
        let mut map = voxelize(&points, g);
        let [d, h, w, _] = map.shape();
        for stage in &self.stages {
            let [_, _, _, c] = map.shape();
            anyhow::ensure!(
                c == stage.c_in,
                "head stage expects {} channels, map has {c}",
                stage.c_in
            );
            let mut out = dense_per_cell(&map.data, d * h * w, stage.c_in, &stage.w, &stage.b);
            for v in &mut out {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            map = FeatureMap::from_vec(d, h, w, stage.c_out, out)?;
        }
        Ok(map)
    }
}

/// Edge-server tail: align → integrate → BEV trunk + heads.
#[derive(Clone, Debug)]
pub struct NativeTail {
    /// Which integration method this tail applies.
    pub kind: IntegrationKind,
    /// One gather map per device (device 0 is the identity reference).
    pub aligns: Vec<AlignMap>,
    /// Conv-integration weights `(k, k, k, devices·c_head, c_head)`
    /// (DHWIO, matching [`conv_integrate`](crate::integrate::conv_integrate));
    /// empty for `Max`.
    pub integrate_w: Vec<f32>,
    /// Conv-integration bias, `(c_head,)`; empty for `Max`.
    pub integrate_b: Vec<f32>,
    /// Integration kernel size (1 for `Max`/`ConvK1`, 3 for `ConvK3`).
    pub k: usize,
    /// Channels each device map carries on the wire at this tail's split
    /// depth (`c_in` for `split-shallow`, `c_head` for the default,
    /// [`deep_channels`](crate::config::deep_channels) for `split-deep`).
    pub c_wire: usize,
    /// Per-device dense + ReLU stages run *before* alignment — the
    /// projection a `split-shallow` device deferred (that device's own
    /// head weights) or the `split-deep` expansion back to `c_head`.
    /// Empty at the default depth.
    pub pre: Vec<DenseStage>,
    /// The shared BEV trunk + detection heads.
    pub bev: BevStage,
    /// Scratch-buffer arena shared with the owning backend: gather
    /// buffers and integrated backing stores are checked out per frame
    /// instead of allocated (see the arena module's ownership rules).
    pub scratch: Arc<Arena>,
}

impl NativeTail {
    /// The integration step alone (parity tests cross-check this against
    /// the reference kernels directly). The returned map's backing store
    /// comes from the arena; callers may [`Arena::give`] it back when the
    /// map is consumed (dropping it is also fine).
    pub fn integrate(&self, aligned: &[FeatureMap]) -> FeatureMap {
        let first = aligned.first().expect("integration needs at least one map");
        let [d, h, w, _] = first.shape();
        let (c_out, run): (usize, fn(&NativeTail, &[FeatureMap], &mut [f32])) = match self.kind {
            IntegrationKind::Max => (first.c, |_t, maps, out| max_integrate_into(maps, out)),
            IntegrationKind::ConvK1 | IntegrationKind::ConvK3 => {
                (self.integrate_b.len(), |t, maps, out| {
                    conv_integrate_into(maps, &t.integrate_w, &t.integrate_b, t.k, out)
                })
            }
        };
        let mut out = self.scratch.take(d * h * w * c_out);
        run(self, aligned, &mut out);
        FeatureMap::from_vec(d, h, w, c_out, out).expect("integration output shape")
    }

    /// Per-frame front half of the tail: validate the device maps, apply
    /// the gather alignment (into arena scratch), integrate. Shared by
    /// [`run`](Self::run) and [`run_batch`](Self::run_batch); the batched
    /// backend path fans this function across a thread pool. The returned
    /// map's backing store is arena-owned (see [`integrate`](Self::integrate)).
    fn prepare(&self, meta: &ModelMeta, inputs: Vec<HostTensor>) -> Result<FeatureMap> {
        anyhow::ensure!(
            inputs.len() == meta.num_devices,
            "tail expects {} device maps, got {}",
            meta.num_devices,
            inputs.len()
        );
        let g = &meta.grid;
        let expect = vec![g.dims[2], g.dims[1], g.dims[0], self.c_wire];
        let mut aligned = Vec::with_capacity(inputs.len());
        for (dev, t) in inputs.into_iter().enumerate() {
            anyhow::ensure!(
                t.shape == expect,
                "tail input {dev} shape {:?}, expected {:?}",
                t.shape,
                expect
            );
            let mut map =
                FeatureMap::from_vec(expect[0], expect[1], expect[2], expect[3], t.data)?;
            // Non-default split depths run the device's deferred (or
            // expansion) stage here, before alignment, restoring the
            // `c_head`-channel map the trunk was built for.
            if let Some(stage) = self.pre.get(dev) {
                map = stage.apply(&self.scratch, map)?;
            }
            let [md, mh, mw, mc] = map.shape();
            // Gather into a zeroed arena buffer (apply_into's contract),
            // then donate the source map's backing store for reuse.
            let mut gathered = self.scratch.take(map.data.len());
            self.aligns[dev].apply_into(&map, &mut gathered);
            self.scratch.give(map.data);
            aligned.push(FeatureMap::from_vec(md, mh, mw, mc, gathered)?);
        }
        let integrated = self.integrate(&aligned);
        for m in aligned {
            self.scratch.give(m.data);
        }
        Ok(integrated)
    }

    /// Run the full tail on one frame's device maps. Returns `[cls, boxes]`.
    pub fn run(&self, meta: &ModelMeta, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let integrated = self.prepare(meta, inputs)?;
        let heads = self.bev.run(&integrated);
        self.scratch.give(integrated.data);
        let (cls, boxes) = heads?;
        Ok(vec![cls, boxes])
    }

    /// Run the tail over a micro-batch of frames, one result per entry.
    ///
    /// Alignment + integration stay per frame (their cost is
    /// gather-bound), but the BEV trunk and detection heads run stacked
    /// along a leading batch axis ([`BevStage::run_batch`]). Errors are
    /// per entry: a frame with bad shapes gets its own `Err` while its
    /// batch-mates still execute, and outputs are bit-identical to
    /// per-frame [`run`](Self::run) calls.
    pub fn run_batch(
        &self,
        meta: &ModelMeta,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        let prepared: Vec<Result<FeatureMap>> =
            batch.into_iter().map(|inputs| self.prepare(meta, inputs)).collect();
        self.finish_batch(prepared)
    }

    /// Back half of [`run_batch`](Self::run_batch): stacked BEV trunk +
    /// heads over already-prepared (aligned + integrated) frames. Split
    /// out so the backend can run the prepare stage on a thread pool and
    /// still share this code. Donates every prepared map's backing store
    /// back to the arena, on success and failure paths alike.
    fn finish_batch(&self, prepared: Vec<Result<FeatureMap>>) -> Vec<Result<Vec<HostTensor>>> {
        let healthy: Vec<&FeatureMap> = prepared.iter().filter_map(|r| r.as_ref().ok()).collect();
        let heads = match self.bev.run_batch(&healthy) {
            Ok(h) => h,
            Err(e) => {
                // A trunk-level failure (shape mismatch vs the stage
                // config) applies to every healthy entry identically.
                let msg = format!("batched BEV stage failed: {e:#}");
                return prepared
                    .into_iter()
                    .map(|r| {
                        r.and_then(|m| {
                            self.scratch.give(m.data);
                            Err(anyhow::anyhow!("{msg}"))
                        })
                    })
                    .collect();
            }
        };
        let mut heads = heads.into_iter();
        prepared
            .into_iter()
            .map(|r| {
                r.map(|m| {
                    self.scratch.give(m.data);
                    let (cls, boxes) =
                        heads.next().expect("one BEV output per healthy batch entry");
                    vec![cls, boxes]
                })
            })
            .collect()
    }
}

/// Baseline full model: head + BEV trunk over a single cloud.
#[derive(Clone, Debug)]
pub struct NativeFull {
    /// The voxelize → per-voxel-linear front half.
    pub head: NativeHead,
    /// The BEV trunk + detection heads.
    pub bev: BevStage,
}

impl NativeFull {
    /// Run the full baseline on one cloud. Returns `[cls, boxes]`.
    pub fn run(&self, meta: &ModelMeta, input: &HostTensor) -> Result<Vec<HostTensor>> {
        let feat = self.head.run(meta, input)?;
        let (cls, boxes) = self.bev.run(&feat)?;
        Ok(vec![cls, boxes])
    }
}

/// One resident native model.
#[derive(Clone, Debug)]
pub enum NativeModel {
    /// Split-point head (device side).
    Head(NativeHead),
    /// Edge-server tail (align → integrate → BEV + heads).
    Tail(NativeTail),
    /// Single-cloud baseline (head + BEV + heads).
    Full(NativeFull),
}

/// The pure-Rust [`ExecBackend`]. Model names resolve against
/// `model_meta.json` exactly like HLO artifact names do, so the serving
/// layers are oblivious to the substrate swap.
pub struct NativeBackend {
    meta: ModelMeta,
    /// Device → common-frame calibration poses (index = device id).
    poses: Vec<Pose>,
    weights_dir: Option<PathBuf>,
    models: Mutex<HashMap<String, Arc<NativeModel>>>,
    /// Scratch arena shared by every tail this backend builds.
    arena: Arc<Arena>,
    /// Lazily-built pool for the batched tails' parallel prepare stage —
    /// lazy so single-frame deployments never spawn threads.
    batch_pool: Mutex<Option<Arc<ThreadPool>>>,
}

impl NativeBackend {
    /// Build a backend from explicit calibration poses and an optional
    /// `.npy` weights directory (`None` = synthetic weights only).
    pub fn new(
        meta: ModelMeta,
        poses: Vec<Pose>,
        weights_dir: Option<PathBuf>,
    ) -> Result<NativeBackend> {
        anyhow::ensure!(
            poses.len() >= meta.num_devices,
            "need one calibration pose per device ({} < {})",
            poses.len(),
            meta.num_devices
        );
        Ok(NativeBackend {
            meta,
            poses,
            weights_dir,
            models: Mutex::new(HashMap::new()),
            arena: Arc::new(Arena::new()),
            batch_pool: Mutex::new(None),
        })
    }

    /// Build from the artifact directory: calibration from `calib.json`
    /// when present, weights from `artifacts/native/`. A *missing*
    /// calib.json falls back to identity poses (single-rig demos, tests
    /// with zero artifacts); a present-but-corrupt one is an error —
    /// silently serving unaligned integration would look like a model
    /// problem, not a config problem.
    pub fn from_paths(paths: &Paths, meta: &ModelMeta) -> Result<NativeBackend> {
        let calib_path = paths.calib();
        let poses = if calib_path.exists() {
            crate::config::load_calib(paths)
                .with_context(|| format!("parse {}", calib_path.display()))?
        } else {
            log::warn!(
                "native backend: {} missing; aligning with identity poses",
                calib_path.display()
            );
            vec![Pose::IDENTITY; meta.num_devices]
        };
        NativeBackend::new(meta.clone(), poses, Some(paths.artifacts.join("native")))
    }

    /// The model geometry this backend was built for.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Snapshot the shared scratch arena's hit/miss counters (feeds the
    /// `arena_*` gauges and `BENCH_replay.json`).
    pub fn arena_stats(&self) -> super::arena::ArenaStats {
        self.arena.stats()
    }

    /// The shared pool for batched prepare, built on first use.
    fn batch_pool(&self) -> Arc<ThreadPool> {
        let mut slot = lock_or_recover(&self.batch_pool);
        if let Some(pool) = slot.as_ref() {
            return Arc::clone(pool);
        }
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8);
        let pool = Arc::new(ThreadPool::new(n));
        *slot = Some(Arc::clone(&pool));
        pool
    }

    /// Shared handle to a resident model (parity tests rebuild the
    /// reference graph from the exact weights the backend runs).
    pub fn model(&self, name: &str) -> Option<Arc<NativeModel>> {
        lock_or_recover(&self.models).get(name).cloned()
    }

    /// One weight tensor: `.npy` override when present, deterministic
    /// synthetic fallback otherwise.
    fn layer(&self, model: &str, layer: &str, len: usize) -> Result<Vec<f32>> {
        if let Some(dir) = &self.weights_dir {
            let path = dir.join(format!("{model}.{layer}.npy"));
            if path.exists() {
                let arr = npy::read(&path)?;
                let data = arr
                    .as_f32()
                    .with_context(|| format!("native weight {}", path.display()))?;
                anyhow::ensure!(
                    data.len() == len,
                    "{} has {} values, expected {len}",
                    path.display(),
                    data.len()
                );
                return Ok(data);
            }
        }
        Ok(synthetic_weights(model, layer, len))
    }

    /// The per-voxel projection stage every split depth shares — the bare
    /// head artifact's `head_w`/`head_b` weights, so the default depth
    /// resolves the exact weights pre-split deployments ran.
    fn proj_stage(&self, base: &str) -> Result<DenseStage> {
        let g = &self.meta.grid;
        Ok(DenseStage {
            c_in: g.c_in,
            c_out: g.c_head,
            w: self.layer(base, "head_w", g.c_in * g.c_head)?,
            b: self.layer(base, "head_b", g.c_head)?,
        })
    }

    /// Device-side head of artifact `base` cut at `split`.
    fn head_for_split(&self, base: &str, split: &str) -> Result<NativeHead> {
        let g = &self.meta.grid;
        let stages = match normalize_split(split)? {
            SPLIT_SHALLOW => Vec::new(),
            SPLIT_DEEP => {
                let c_deep = deep_channels(g);
                let name = split_executable(base, split)?;
                vec![
                    self.proj_stage(base)?,
                    DenseStage {
                        c_in: g.c_head,
                        c_out: c_deep,
                        w: self.layer(&name, "deep_w", g.c_head * c_deep)?,
                        b: self.layer(&name, "deep_b", c_deep)?,
                    },
                ]
            }
            _ => vec![self.proj_stage(base)?],
        };
        Ok(NativeHead { stages })
    }

    /// Wire width and server-side per-device stages of variant `v`'s tail
    /// cut at `split`. The shallow tail runs each device's deferred
    /// projection with that device's own head weights — relocating the
    /// compute without changing the math — while the deep tail expands
    /// the bottleneck back to `c_head` with one shared stage.
    fn tail_pre_for_split(&self, v: &VariantMeta, split: &str) -> Result<(usize, Vec<DenseStage>)> {
        let g = &self.meta.grid;
        Ok(match normalize_split(split)? {
            SPLIT_SHALLOW => {
                let pre =
                    v.heads.iter().map(|h| self.proj_stage(h)).collect::<Result<Vec<_>>>()?;
                (g.c_in, pre)
            }
            SPLIT_DEEP => {
                let c_deep = deep_channels(g);
                let name = split_executable(&v.tail, split)?;
                let stage = DenseStage {
                    c_in: c_deep,
                    c_out: g.c_head,
                    w: self.layer(&name, "expand_w", c_deep * g.c_head)?,
                    b: self.layer(&name, "expand_b", g.c_head)?,
                };
                (c_deep, vec![stage; self.meta.num_devices])
            }
            _ => (g.c_head, Vec::new()),
        })
    }

    fn bev_weights(&self, name: &str) -> Result<BevStage> {
        let g = &self.meta.grid;
        let [hb, wb] = self.meta.bev_dims;
        anyhow::ensure!(
            hb > 0 && wb > 0 && g.dims[1] % hb == 0 && g.dims[0] % wb == 0,
            "bev_dims {:?} must evenly divide grid {:?}",
            self.meta.bev_dims,
            g.dims
        );
        anyhow::ensure!(
            g.dims[1] / hb == g.dims[0] / wb,
            "anisotropic BEV strides unsupported (grid {:?}, bev {:?})",
            g.dims,
            self.meta.bev_dims
        );
        let stride = g.dims[1] / hb;
        let c_in = g.dims[2] * g.c_head;
        let c_mid = NATIVE_C_MID;
        let a = self.meta.anchors.len();
        Ok(BevStage {
            c_in,
            c_mid,
            stride,
            n_anchors: a,
            conv_w: self.layer(name, "bev_w", 3 * 3 * c_in * c_mid)?,
            conv_b: self.layer(name, "bev_b", c_mid)?,
            cls_w: self.layer(name, "cls_w", c_mid * a)?,
            cls_b: self.layer(name, "cls_b", a)?,
            box_w: self.layer(name, "box_w", c_mid * a * 8)?,
            box_b: self.layer(name, "box_b", a * 8)?,
        })
    }

    fn build_model(&self, name: &str) -> Result<NativeModel> {
        let meta = &self.meta;
        let (base, split) = executable_split(name);
        // Reject aliases like `tail_max@split-mid`: the default depth's
        // canonical name is the bare one, and an alias would fragment
        // batch keys for the same executable.
        let canonical = split_executable(base, split)?;
        anyhow::ensure!(
            name == canonical,
            "non-canonical split executable {name:?} (use {canonical:?})"
        );
        for v in &meta.variants {
            if v.heads.iter().any(|h| h == base) {
                return Ok(NativeModel::Head(self.head_for_split(base, split)?));
            }
            if v.tail == base {
                let aligns: Vec<AlignMap> = (0..meta.num_devices)
                    .map(|d| AlignMap::build(&meta.grid, &self.poses[d], 1))
                    .collect();
                // Integration and BEV trunk weights key off the bare tail
                // name: the server trunk is the same network whichever
                // depth the cut lands on.
                let (k, integrate_w, integrate_b) = match v.integration {
                    IntegrationKind::Max => (1, Vec::new(), Vec::new()),
                    IntegrationKind::ConvK1 => self.integrate_weights(base, 1)?,
                    IntegrationKind::ConvK3 => self.integrate_weights(base, 3)?,
                };
                let (c_wire, pre) = self.tail_pre_for_split(v, split)?;
                return Ok(NativeModel::Tail(NativeTail {
                    kind: v.integration,
                    aligns,
                    integrate_w,
                    integrate_b,
                    k,
                    c_wire,
                    pre,
                    bev: self.bev_weights(base)?,
                    scratch: Arc::clone(&self.arena),
                }));
            }
        }
        if meta.single_full.iter().any(|n| n == base) || meta.input_integration_full == base {
            anyhow::ensure!(
                split == DEFAULT_SPLIT,
                "full baseline {base:?} has no split depths ({name:?})"
            );
            return Ok(NativeModel::Full(NativeFull {
                head: self.head_for_split(base, DEFAULT_SPLIT)?,
                bev: self.bev_weights(base)?,
            }));
        }
        bail!("model {name:?} is not described by model_meta (native backend)")
    }

    fn integrate_weights(&self, name: &str, k: usize) -> Result<(usize, Vec<f32>, Vec<f32>)> {
        let g = &self.meta.grid;
        let c_in = self.meta.num_devices * g.c_head;
        let c_out = g.c_head;
        Ok((
            k,
            self.layer(name, "integrate_w", k * k * k * c_in * c_out)?,
            self.layer(name, "integrate_b", c_out)?,
        ))
    }
}

impl ExecBackend for NativeBackend {
    fn backend_name(&self) -> &str {
        "native"
    }

    fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let model = lock_or_recover(&self.models).get(name).cloned();
        let Some(model) = model else {
            bail!("model {name:?} not loaded in native backend (call load first)");
        };
        match &*model {
            NativeModel::Head(head) => {
                anyhow::ensure!(inputs.len() == 1, "head takes one input");
                let feat = head.run(&self.meta, &inputs[0])?;
                let [d, h, w, c] = feat.shape();
                Ok(vec![HostTensor::new(vec![d, h, w, c], feat.data)?])
            }
            NativeModel::Tail(tail) => tail.run(&self.meta, inputs),
            NativeModel::Full(full) => {
                anyhow::ensure!(inputs.len() == 1, "full model takes one input");
                full.run(&self.meta, &inputs[0])
            }
        }
    }

    fn load(&self, name: &str) -> Result<()> {
        if lock_or_recover(&self.models).contains_key(name) {
            return Ok(());
        }
        // Built outside the lock: alignment-map construction is the
        // expensive part and must not serialize concurrent execs.
        let model = self.build_model(name)?;
        lock_or_recover(&self.models)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(model));
        Ok(())
    }

    fn loaded_names(&self) -> Vec<String> {
        lock_or_recover(&self.models).keys().cloned().collect()
    }

    fn exec_batch(
        &self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        let model = lock_or_recover(&self.models).get(name).cloned();
        let Some(model) = model else {
            return batch
                .iter()
                .map(|_| {
                    Err(anyhow::anyhow!(
                        "model {name:?} not loaded in native backend (call load first)"
                    ))
                })
                .collect();
        };
        match &*model {
            // The tail is the server hot path — the one the coordinator's
            // batch planner feeds — and gets the stacked kernels, with the
            // per-frame align/integrate stage fanned across the pool.
            NativeModel::Tail(tail) => {
                if batch.len() < 2 {
                    return tail.run_batch(&self.meta, batch);
                }
                let n = batch.len();
                // Each slot is taken exactly once (by its own pool job),
                // satisfying the pool's Fn-closure bound while still
                // moving every frame's tensors rather than cloning them.
                let slots: Arc<Vec<Mutex<Option<Vec<HostTensor>>>>> =
                    Arc::new(batch.into_iter().map(|inputs| Mutex::new(Some(inputs))).collect());
                let meta = Arc::new(self.meta.clone());
                let model = Arc::clone(&model);
                let prepared = self.batch_pool().map(n, move |i| {
                    let inputs = lock_or_recover(&slots[i])
                        .take()
                        .expect("each batch slot is taken exactly once");
                    match &*model {
                        NativeModel::Tail(t) => t.prepare(&meta, inputs),
                        _ => unreachable!("batched prepare only dispatches tails"),
                    }
                });
                tail.finish_batch(prepared)
            }
            // Heads/baselines run per entry (single-input models; no
            // server-side batching pressure).
            _ => batch.into_iter().map(|inputs| self.exec(name, inputs)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quarter-resolution meta so conv-k3 integration stays fast in
    /// debug test runs; structure matches production.
    fn small_meta() -> ModelMeta {
        let mut meta = ModelMeta::test_default();
        meta.grid.dims = [16, 16, 4];
        meta.grid.max_points = 512;
        meta.bev_dims = [8, 8];
        meta
    }

    fn backend() -> NativeBackend {
        let poses = vec![
            Pose::IDENTITY,
            Pose::from_xyz_rpy(0.8, 0.0, 0.0, 0.0, 0.0, 0.0),
        ];
        NativeBackend::new(small_meta(), poses, None).unwrap()
    }

    fn feat_shape(meta: &ModelMeta) -> Vec<usize> {
        let g = &meta.grid;
        vec![g.dims[2], g.dims[1], g.dims[0], g.c_head]
    }

    #[test]
    fn tail_runs_all_variants_with_correct_shapes() {
        let b = backend();
        let meta = b.meta().clone();
        let shape = feat_shape(&meta);
        for kind in IntegrationKind::all() {
            let tail = meta.variant(kind).unwrap().tail.clone();
            b.load(&tail).unwrap();
            let inputs = vec![HostTensor::zeros(&shape), HostTensor::zeros(&shape)];
            let out = b.exec(&tail, inputs).unwrap();
            assert_eq!(out.len(), 2, "{kind:?}");
            let [hb, wb] = meta.bev_dims;
            let a = meta.anchors.len();
            assert_eq!(out[0].shape, vec![hb, wb, a]);
            assert_eq!(out[1].shape, vec![hb, wb, a, 8]);
            assert!(out[0].data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn head_produces_meta_shaped_features() {
        let b = backend();
        let meta = b.meta().clone();
        let head = meta.variant(IntegrationKind::Max).unwrap().heads[0].clone();
        b.load(&head).unwrap();
        let g = &meta.grid;
        let input = HostTensor::zeros(&[g.max_points, 4]);
        let out = b.exec(&head, vec![input]).unwrap();
        assert_eq!(out[0].shape, feat_shape(&meta));
        // ReLU output, and a zero cloud voxelizes to zeros → uniform map.
        assert!(out[0].data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn full_baseline_runs() {
        let b = backend();
        let meta = b.meta().clone();
        b.load("single_dev0").unwrap();
        b.load("input_integration").unwrap();
        let g = &meta.grid;
        let out = b
            .exec("single_dev0", vec![HostTensor::zeros(&[g.max_points, 4])])
            .unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn unknown_model_rejected() {
        let b = backend();
        assert!(b.load("no_such_model").is_err());
        assert!(b.exec("tail_max", vec![]).is_err(), "exec before load must error");
        assert!(b.loaded_names().is_empty());
    }

    #[test]
    fn exec_is_deterministic() {
        let b = backend();
        let meta = b.meta().clone();
        let tail = meta.variant(IntegrationKind::ConvK1).unwrap().tail.clone();
        b.load(&tail).unwrap();
        let shape = feat_shape(&meta);
        let mut t = HostTensor::zeros(&shape);
        for (i, v) in t.data.iter_mut().enumerate() {
            *v = ((i * 13) % 31) as f32 * 0.05;
        }
        let a = b.exec(&tail, vec![t.clone(), t.clone()]).unwrap();
        let c = b.exec(&tail, vec![t.clone(), t]).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn batched_tail_is_bit_identical_to_per_frame_exec() {
        let b = backend();
        let meta = b.meta().clone();
        let shape = feat_shape(&meta);
        let mut rng = crate::utils::rng::Pcg64::new(17);
        let mut feature = || {
            let mut t = HostTensor::zeros(&shape);
            for v in t.data.iter_mut() {
                if rng.uniform_f32() < 0.2 {
                    *v = rng.uniform_f32() * 2.0 - 0.5;
                }
            }
            t
        };
        for kind in IntegrationKind::all() {
            let tail = meta.variant(kind).unwrap().tail.clone();
            b.load(&tail).unwrap();
            let batch: Vec<Vec<HostTensor>> =
                (0..3).map(|_| vec![feature(), feature()]).collect();
            let batched = b.exec_batch(&tail, batch.clone());
            assert_eq!(batched.len(), 3);
            for (entry, inputs) in batched.into_iter().zip(batch) {
                let single = b.exec(&tail, inputs).unwrap();
                assert_eq!(
                    entry.unwrap(),
                    single,
                    "{kind:?}: batched output must be bit-identical to per-frame exec"
                );
            }
        }
    }

    #[test]
    fn batched_tail_isolates_bad_entries() {
        let b = backend();
        let meta = b.meta().clone();
        let shape = feat_shape(&meta);
        let tail = meta.variant(IntegrationKind::Max).unwrap().tail.clone();
        b.load(&tail).unwrap();
        let good = vec![HostTensor::zeros(&shape), HostTensor::zeros(&shape)];
        let bad = vec![HostTensor::zeros(&[2, 2])]; // wrong arity + shape
        let results = b.exec_batch(&tail, vec![good.clone(), bad, good.clone()]);
        assert_eq!(results.len(), 3);
        assert!(results[0].is_ok());
        assert!(results[1].is_err(), "bad frame must fail alone");
        assert!(results[2].is_ok(), "batch-mates of a bad frame must survive");
        assert_eq!(
            results[0].as_ref().unwrap(),
            &b.exec(&tail, good).unwrap(),
            "surviving entries still match the per-frame path"
        );
        // Unloaded model: every entry errors, none panics.
        let results = b.exec_batch("ghost", vec![vec![], vec![]]);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.is_err()));
    }

    /// A synthetic cloud with points spread across the grid, so split
    /// parity failures can't hide behind all-zero maps.
    fn dense_cloud(meta: &ModelMeta, seed: u64) -> HostTensor {
        let g = &meta.grid;
        let mut rng = crate::utils::rng::Pcg64::new(seed);
        let mut cloud = vec![0.0f32; g.max_points * 4];
        for p in cloud.chunks_exact_mut(4) {
            p[0] = g.range_min[0] as f32
                + rng.uniform_f32() * (g.range_max[0] - g.range_min[0]) as f32;
            p[1] = g.range_min[1] as f32
                + rng.uniform_f32() * (g.range_max[1] - g.range_min[1]) as f32;
            p[2] = g.range_min[2] as f32
                + rng.uniform_f32() * (g.range_max[2] - g.range_min[2]) as f32;
            p[3] = rng.uniform_f32();
        }
        HostTensor::new(vec![g.max_points, 4], cloud).unwrap()
    }

    #[test]
    fn every_split_depth_serves_matching_head_tail_shapes() {
        use crate::config::{wire_channels, SPLIT_DEPTHS};
        let b = backend();
        let meta = b.meta().clone();
        let g = &meta.grid;
        let v = meta.variant(IntegrationKind::Max).unwrap().clone();
        for split in SPLIT_DEPTHS {
            let c_wire = wire_channels(g, split).unwrap();
            let cloud = dense_cloud(&meta, 7);
            let mut maps = Vec::new();
            for dev in 0..meta.num_devices {
                let head = v.head_for(dev, split).unwrap();
                b.load(&head).unwrap();
                let out = b.exec(&head, vec![cloud.clone()]).unwrap();
                assert_eq!(
                    out[0].shape,
                    vec![g.dims[2], g.dims[1], g.dims[0], c_wire],
                    "{split} head wire shape"
                );
                maps.push(out.into_iter().next().unwrap());
            }
            let tail = v.tail_for(split).unwrap();
            b.load(&tail).unwrap();
            let out = b.exec(&tail, maps).unwrap();
            let [hb, wb] = meta.bev_dims;
            assert_eq!(out[0].shape, vec![hb, wb, meta.anchors.len()], "{split} cls shape");
            assert!(out[0].data.iter().all(|v| v.is_finite()), "{split}");
        }
    }

    #[test]
    fn shallow_split_relocates_compute_without_changing_outputs() {
        // The shallow cut ships raw voxel statistics and the tail runs
        // the deferred projection with the same per-device weights the
        // mid head would use — end-to-end outputs must be bit-identical.
        let b = backend();
        let meta = b.meta().clone();
        let v = meta.variant(IntegrationKind::ConvK1).unwrap().clone();
        let cloud0 = dense_cloud(&meta, 11);
        let cloud1 = dense_cloud(&meta, 13);
        let run = |split: &str| {
            let mut maps = Vec::new();
            for (dev, cloud) in [&cloud0, &cloud1].into_iter().enumerate() {
                let head = v.head_for(dev, split).unwrap();
                b.load(&head).unwrap();
                maps.push(b.exec(&head, vec![cloud.clone()]).unwrap().remove(0));
            }
            let tail = v.tail_for(split).unwrap();
            b.load(&tail).unwrap();
            b.exec(&tail, maps).unwrap()
        };
        let mid = run("split-mid");
        let shallow = run("split-shallow");
        assert_eq!(mid, shallow, "shallow and mid cuts are the same network");
        // The deep cut's bottleneck genuinely reduces capacity — it must
        // NOT reproduce the mid outputs.
        let deep = run("split-deep");
        assert_ne!(mid, deep, "deep bottleneck must actually bottleneck");
    }

    #[test]
    fn default_split_resolves_bare_names() {
        // Bare names (what every pre-split deployment sends) keep
        // resolving, and the mid-depth head is the single projection
        // stage with the bare artifact's synthetic weights.
        let b = backend();
        b.load("head_max_dev0").unwrap();
        let g = b.meta().grid.clone();
        match &*b.model("head_max_dev0").unwrap() {
            NativeModel::Head(h) => {
                assert_eq!(h.stages.len(), 1);
                assert_eq!(
                    h.stages[0].w,
                    synthetic_weights("head_max_dev0", "head_w", g.c_in * g.c_head)
                );
            }
            other => panic!("expected a head, got {other:?}"),
        }
        // Aliased default names are rejected — they would fragment the
        // planner's batch keys for the same executable.
        assert!(b.load("tail_max@split-mid").is_err());
        // Full baselines have exactly one depth.
        assert!(b.load("single_dev0@split-deep").is_err());
        assert!(b.load("tail_max@split-bogus").is_err());
    }

    #[test]
    fn split_tails_batch_bit_identically() {
        let b = backend();
        let meta = b.meta().clone();
        let v = meta.variant(IntegrationKind::Max).unwrap().clone();
        for split in ["split-shallow", "split-deep"] {
            let heads: Vec<String> =
                (0..meta.num_devices).map(|d| v.head_for(d, split).unwrap()).collect();
            for h in &heads {
                b.load(h).unwrap();
            }
            let tail = v.tail_for(split).unwrap();
            b.load(&tail).unwrap();
            let frame = |seed: u64| -> Vec<HostTensor> {
                heads
                    .iter()
                    .map(|h| b.exec(h, vec![dense_cloud(&meta, seed)]).unwrap().remove(0))
                    .collect()
            };
            let batch: Vec<Vec<HostTensor>> = (0..3).map(|i| frame(20 + i)).collect();
            let batched = b.exec_batch(&tail, batch.clone());
            for (entry, inputs) in batched.into_iter().zip(batch) {
                assert_eq!(
                    entry.unwrap(),
                    b.exec(&tail, inputs).unwrap(),
                    "{split}: batched tail must match per-frame exec"
                );
            }
        }
    }

    #[test]
    fn conv2d_batch_matches_conv2d() {
        let mut rng = crate::utils::rng::Pcg64::new(23);
        let (h, w, c_in, c_out, k) = (6usize, 6usize, 3usize, 4usize, 3usize);
        let mut inputs = Vec::new();
        for _ in 0..3 {
            let v: Vec<f32> = (0..h * w * c_in)
                .map(|_| if rng.uniform_f32() < 0.3 { rng.uniform_f32() - 0.5 } else { 0.0 })
                .collect();
            inputs.push(v);
        }
        let weights: Vec<f32> =
            (0..k * k * c_in * c_out).map(|_| rng.uniform_f32() - 0.5).collect();
        let bias: Vec<f32> = (0..c_out).map(|_| rng.uniform_f32() * 0.1).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        for stride in [1usize, 2] {
            let batched = conv2d_batch(&refs, h, w, c_in, &weights, &bias, k, stride, true);
            for (bi, input) in inputs.iter().enumerate() {
                let single = conv2d(input, h, w, c_in, &weights, &bias, k, stride, true);
                assert_eq!(batched[bi], single, "stride {stride}, frame {bi}");
            }
        }
    }

    #[test]
    fn npy_weight_override_is_used() {
        let dir = std::env::temp_dir().join("scmii_native_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let meta = ModelMeta::test_default();
        let g = &meta.grid;
        // Zero head weights → head output must be relu(bias) = 0 everywhere.
        let zeros = vec![0.0f32; g.c_in * g.c_head];
        npy::write(
            &dir.join("head_max_dev0.head_w.npy"),
            &npy::NpyArray::from_f32(&[g.c_in, g.c_head], &zeros),
        )
        .unwrap();
        let zero_b = vec![0.0f32; g.c_head];
        npy::write(
            &dir.join("head_max_dev0.head_b.npy"),
            &npy::NpyArray::from_f32(&[g.c_head], &zero_b),
        )
        .unwrap();
        let b = NativeBackend::new(
            meta.clone(),
            vec![Pose::IDENTITY; 2],
            Some(dir),
        )
        .unwrap();
        b.load("head_max_dev0").unwrap();
        // A cloud with one in-range point: synthetic weights would give a
        // non-zero voxel; the zero .npy weights must win.
        let mut cloud = vec![0.0f32; g.max_points * 4];
        cloud[0] = 1.0;
        cloud[1] = 1.0;
        cloud[2] = -3.0;
        cloud[3] = 0.5;
        let input = HostTensor::new(vec![g.max_points, 4], cloud).unwrap();
        let out = b.exec("head_max_dev0", vec![input]).unwrap();
        assert!(out[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn synthetic_weights_are_stable_and_name_dependent() {
        let a = synthetic_weights("tail_max", "bev_w", 16);
        let b = synthetic_weights("tail_max", "bev_w", 16);
        let c = synthetic_weights("tail_conv_k1", "bev_w", 16);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.iter().all(|v| v.abs() <= 0.1));
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel, identity weight matrix: output == input.
        let input: Vec<f32> = (0..4 * 4 * 2).map(|i| i as f32).collect();
        let mut w = vec![0.0f32; 2 * 2];
        w[0] = 1.0;
        w[3] = 1.0;
        let out = conv2d(&input, 4, 4, 2, &w, &[0.0, 0.0], 1, 1, false);
        assert_eq!(out, input);
    }

    #[test]
    fn conv2d_stride_downsamples() {
        let input = vec![1.0f32; 4 * 4];
        let w = vec![1.0f32; 9]; // 3x3, c_in=1, c_out=1
        let out = conv2d(&input, 4, 4, 1, &w, &[0.0], 3, 2, false);
        assert_eq!(out.len(), 2 * 2);
        // Top-left output sees a 2x2 valid patch (corner), value 4.
        assert_eq!(out[0], 4.0);
    }
}
