//! Reusable scratch-buffer arena for the per-frame tail hot path.
//!
//! The native tail used to allocate every intermediate (`vec![0.0; ..]`)
//! per frame: one gather buffer per device map plus the integrated
//! [`FeatureMap`](crate::voxel::FeatureMap) backing store. Under replay
//! load those allocations dominate the align/integrate stages. The
//! [`Arena`] keeps returned buffers in a bounded pool and hands them back
//! zeroed, so a steady-state frame allocates nothing.
//!
//! ## Ownership rules
//!
//! - [`Arena::take`] transfers **exclusive ownership** of a buffer to the
//!   caller. The pool never retains a reference; two concurrent `take`
//!   calls can never observe the same backing memory (each pops a
//!   distinct `Vec` or allocates fresh).
//! - The caller is free to move the buffer into a `FeatureMap` (all
//!   `FeatureMap` fields are public, so the backing `Vec` can travel in
//!   and out without copying).
//! - [`Arena::give`] donates a buffer back. It is always safe to *not*
//!   give a buffer back — the arena then simply allocates again — so
//!   error paths may drop buffers without cleanup obligations.
//! - Buffers are zeroed on `take`, not on `give`, so a dirty donation is
//!   harmless.
//!
//! Hit/miss counters feed the `arena_hits` / `arena_misses` gauges and
//! `BENCH_replay.json`.

use crate::sync::{lock_or_recover, Mutex};
use std::sync::atomic::{AtomicU64, Ordering};

/// Buffers retained per arena; donations beyond this are dropped so a
/// burst (e.g. a deep batch) cannot pin memory forever.
const MAX_POOLED: usize = 64;

/// Point-in-time snapshot of the arena's reuse counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// `take` calls satisfied from the pool (no allocation).
    pub hits: u64,
    /// `take` calls that had to allocate a fresh buffer.
    pub misses: u64,
}

impl ArenaStats {
    /// Fraction of checkouts served without allocating (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A pool of reusable `Vec<f32>` scratch buffers (see module docs for the
/// ownership rules).
pub struct Arena {
    pool: Mutex<Vec<Vec<f32>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for Arena {
    fn default() -> Self {
        Arena::new()
    }
}

impl std::fmt::Debug for Arena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(f, "Arena {{ hits: {}, misses: {} }}", s.hits, s.misses)
    }
}

impl Arena {
    /// An empty arena.
    pub fn new() -> Arena {
        Arena { pool: Mutex::new(Vec::new()), hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    /// Check out an exclusively-owned, zeroed buffer of exactly `len`
    /// elements. Reuses a pooled buffer when one exists (a *hit*),
    /// allocates otherwise (a *miss*).
    pub fn take(&self, len: usize) -> Vec<f32> {
        let pooled = lock_or_recover(&self.pool).pop();
        match pooled {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // Zero the reused prefix, then size: `resize` fills any
                // grown tail with 0.0, so the whole buffer comes out
                // zeroed without a `vec![]` allocation on the hit path.
                buf.truncate(len);
                buf.fill(0.0);
                buf.resize(len, 0.0);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                vec![0.0; len]
            }
        }
    }

    /// Donate a buffer back to the pool. Dropped (deallocated) when the
    /// pool is full or the buffer is empty.
    pub fn give(&self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut pool = lock_or_recover(&self.pool);
        if pool.len() < MAX_POOLED {
            pool.push(buf);
        }
    }

    /// Snapshot the hit/miss counters.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }

    /// Buffers currently parked in the pool (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        lock_or_recover(&self.pool).len()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_reuse_counts_hits() {
        let a = Arena::new();
        let mut b = a.take(8);
        assert_eq!(b, vec![0.0; 8]);
        b.iter_mut().for_each(|v| *v = 7.0);
        a.give(b);
        assert_eq!(a.pooled(), 1);
        let b2 = a.take(8);
        assert_eq!(b2, vec![0.0; 8], "reused buffer must come back zeroed");
        assert_eq!(a.stats(), ArenaStats { hits: 1, misses: 1 });
    }

    #[test]
    fn reuse_across_sizes() {
        let a = Arena::new();
        a.give(vec![1.0; 16]);
        // Shrinking reuse.
        let small = a.take(4);
        assert_eq!(small, vec![0.0; 4]);
        a.give(small);
        // Growing reuse.
        let big = a.take(32);
        assert_eq!(big, vec![0.0; 32]);
        assert_eq!(a.stats().hits, 2);
    }

    #[test]
    fn pool_is_bounded() {
        let a = Arena::new();
        for _ in 0..(MAX_POOLED + 10) {
            a.give(vec![0.0; 4]);
        }
        assert_eq!(a.pooled(), MAX_POOLED);
        a.give(Vec::new()); // empty donations are dropped, not pooled
        assert_eq!(a.pooled(), MAX_POOLED);
    }

    #[test]
    fn hit_rate_reports() {
        let s = ArenaStats { hits: 3, misses: 1 };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(ArenaStats::default().hit_rate(), 0.0);
    }
}
