//! Execution backends: every model exec in the system (heads, tails,
//! baselines) goes through the [`ExecBackend`] trait, so the serving
//! layers never know which substrate runs the math.
//!
//! Two implementations ship:
//!
//! - `XlaBackend` (feature `xla`, default): loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`) emitted by `python/compile/aot.py` and
//!   executes them through PJRT. The `xla` crate's handles are not
//!   `Send` (raw pointers), so the backend owns a **pool of N engine
//!   threads** ([`pool::BackendPool`]), each with its own PJRT client and
//!   compiled executables; requests land in one shared queue and idle
//!   workers steal them, so independent sessions/frames execute
//!   concurrently up to the pool size (`scmii serve --backend-threads N`).
//! - `native::NativeBackend` (feature `native`): a pure-Rust
//!   implementation of the SC-MII graph (voxelize → per-voxel head,
//!   gather alignment → integration → BEV conv → detection heads) that
//!   needs **no HLO artifacts and no native libraries**; weights come
//!   from `.npy` files under `artifacts/native/` or a deterministic
//!   synthetic fallback.
//!
//! Besides per-request [`ExecBackend::exec`], backends expose
//! [`ExecBackend::exec_batch`] — one call over a micro-batch of
//! independent input sets. The coordinator's
//! [`BatchPlanner`](crate::coordinator::scheduler::BatchPlanner)
//! coalesces compatible tail requests across sessions into such batches,
//! dropping the steady-state server cost per frame from one backend
//! round-trip to ~1/B of one.
//!
//! Interchange for the XLA path is HLO **text** — the image's
//! xla_extension 0.5.1 rejects serialized protos from jax ≥ 0.5 (64-bit
//! instruction ids); the text parser reassigns ids (see
//! /opt/xla-example/README.md).

pub mod arena;
#[cfg(feature = "native")]
pub mod native;
pub mod pool;

pub use pool::{BackendPool, PoolExecutor};

use crate::config::Paths;
#[cfg(feature = "xla")]
use anyhow::Context;
use anyhow::Result;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::sync::Arc;

/// A host-side tensor (f32, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Dimensions, outermost first (row-major layout).
    pub shape: Vec<usize>,
    /// Flat element storage; `data.len() == shape.iter().product()`.
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Build a tensor, validating that `shape` matches `data.len()`.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == data.len(),
            "tensor shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Ok(HostTensor { shape, data })
    }

    /// An all-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Approximate serialized size in bytes (payload accounting).
    pub fn byte_len(&self) -> usize {
        self.data.len() * 4 + self.shape.len() * 8 + 16
    }
}

/// One execution substrate hosting named models. Implementations must be
/// callable from any thread (`&self`, `Send + Sync`); serving code holds
/// them as `Arc<dyn ExecBackend>`.
pub trait ExecBackend: Send + Sync {
    /// Short backend identifier for logs/metrics ("xla", "native", ...).
    fn backend_name(&self) -> &str;

    /// Execute a loaded model. Every model returns a tuple of tensors
    /// (the lowered jax functions use `return_tuple=True`; the native
    /// models mirror that convention).
    fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>>;

    /// Make `name` executable (compile the HLO artifact / build the
    /// native model). Idempotent.
    fn load(&self, name: &str) -> Result<()>;

    /// Names currently resident (diagnostics / startup logging).
    fn loaded_names(&self) -> Vec<String>;

    /// Execute `name` once per entry of a **micro-batch** of independent
    /// input sets, returning one result per entry in order.
    ///
    /// The default implementation is a sequential loop over
    /// [`exec`](ExecBackend::exec) — semantically identical to N separate
    /// calls. Backends that can do better override it: the native backend
    /// stacks the batch along a leading axis through its BEV/head
    /// kernels, and the engine pool routes the whole batch as one queue
    /// job on a single-worker pool while scattering entries across idle
    /// workers on a multi-worker pool (batching must not forfeit pool
    /// parallelism). Errors are isolated per entry — one bad input set
    /// must not fail its batch-mates — which the coordinator's
    /// [`BatchPlanner`](crate::coordinator::scheduler::BatchPlanner)
    /// relies on.
    fn exec_batch(
        &self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        batch.into_iter().map(|inputs| self.exec(name, inputs)).collect()
    }
}

/// Which [`ExecBackend`] implementation to construct (CLI `--backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT/HLO engine pool (feature `xla`).
    Xla,
    /// Pure-Rust kernels, no artifacts (feature `native`).
    Native,
}

impl BackendKind {
    /// Parse a `--backend` flag value (`"xla"` | `"native"`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "xla" => Ok(BackendKind::Xla),
            "native" => Ok(BackendKind::Native),
            other => anyhow::bail!("unknown backend {other:?} (expected xla|native)"),
        }
    }

    /// Canonical CLI spelling of this backend kind.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::Native => "native",
        }
    }

    /// The backend this build prefers: XLA when compiled in, else native.
    pub fn default_kind() -> BackendKind {
        #[cfg(feature = "xla")]
        return BackendKind::Xla;
        #[cfg(not(feature = "xla"))]
        BackendKind::Native
    }
}

/// Construct a backend of `kind`, preloading `preload` model names.
/// `threads` sizes the XLA engine pool (the native backend executes on
/// caller threads and is inherently concurrent).
pub fn build_backend(
    paths: &Paths,
    meta: &crate::config::ModelMeta,
    kind: BackendKind,
    threads: usize,
    preload: &[String],
) -> Result<Arc<dyn ExecBackend>> {
    match kind {
        BackendKind::Xla => {
            #[cfg(feature = "xla")]
            {
                let _ = meta;
                Ok(Arc::new(XlaBackend::spawn(paths.clone(), preload, threads)?))
            }
            #[cfg(not(feature = "xla"))]
            {
                let _ = (paths, meta, threads, preload);
                anyhow::bail!("backend \"xla\" unavailable: built without the `xla` feature")
            }
        }
        BackendKind::Native => {
            #[cfg(feature = "native")]
            {
                let _ = threads;
                let backend = native::NativeBackend::from_paths(paths, meta)?;
                for name in preload {
                    backend.load(name)?;
                }
                Ok(Arc::new(backend))
            }
            #[cfg(not(feature = "native"))]
            {
                let _ = (paths, meta, threads, preload);
                anyhow::bail!("backend \"native\" unavailable: built without the `native` feature")
            }
        }
    }
}

/// Compiled-executable registry over one PJRT client. Not `Send` —
/// use thread-locally or behind [`XlaBackend`].
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl Engine {
    /// Create an engine on the CPU PJRT backend.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, executables: HashMap::new() })
    }

    /// PJRT platform name of the underlying client (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name (`artifacts/<name>.hlo.txt`).
    pub fn load(&mut self, paths: &Paths, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = paths.hlo(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("load HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("compile {}", name))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load several artifacts.
    pub fn load_all(&mut self, paths: &Paths, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(paths, n)?;
        }
        Ok(())
    }

    /// Whether `name` has been compiled into this engine.
    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Names of the compiled executables resident in this engine.
    pub fn loaded_names(&self) -> Vec<String> {
        self.executables.keys().cloned().collect()
    }

    /// Execute a loaded artifact. All jax functions are lowered with
    /// `return_tuple=True`, so the single output is a tuple which we
    /// decompose into one [`HostTensor`] per element.
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data =
                    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                HostTensor::new(dims, data)
            })
            .collect()
    }
}

/// Pool worker owning one thread-local [`Engine`].
#[cfg(feature = "xla")]
struct EngineWorker {
    engine: Engine,
    paths: Paths,
}

#[cfg(feature = "xla")]
impl PoolExecutor for EngineWorker {
    fn exec(&mut self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.engine.exec(name, &inputs)
    }

    fn load(&mut self, name: &str) -> Result<()> {
        self.engine.load(&self.paths, name)
    }

    fn loaded_names(&self) -> Vec<String> {
        self.engine.loaded_names()
    }
}

/// PJRT/HLO backend: a pool of engine threads sharing one work queue.
/// `load` broadcasts to every worker (each thread compiles its own copy
/// — PJRT executables are not `Send`); `exec` is served by whichever
/// worker is free first.
#[cfg(feature = "xla")]
pub struct XlaBackend {
    pool: BackendPool,
}

#[cfg(feature = "xla")]
impl XlaBackend {
    /// Spawn `threads` engine threads (clamped to ≥ 1), each pre-loading
    /// the `preload` artifacts.
    pub fn spawn(paths: Paths, preload: &[String], threads: usize) -> Result<XlaBackend> {
        let preload = preload.to_vec();
        let pool = BackendPool::spawn("xla", threads, move |_worker| {
            let mut engine = Engine::cpu()?;
            for name in &preload {
                engine.load(&paths, name)?;
            }
            Ok(EngineWorker { engine, paths: paths.clone() })
        })?;
        Ok(XlaBackend { pool })
    }

    /// Number of engine threads.
    pub fn pool_size(&self) -> usize {
        self.pool.size()
    }
}

#[cfg(feature = "xla")]
impl ExecBackend for XlaBackend {
    fn backend_name(&self) -> &str {
        "xla"
    }

    fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.pool.exec(name, inputs)
    }

    fn load(&self, name: &str) -> Result<()> {
        self.pool.load(name)
    }

    fn loaded_names(&self) -> Vec<String> {
        self.pool.loaded_names()
    }

    fn exec_batch(
        &self,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        // Compiled HLO executables are fixed-shape, so there is no
        // stacked kernel to run; the pool decides the dispatch strategy —
        // one job on a single-worker pool (saves N-1 queue round-trips),
        // scattered entries on a multi-worker pool (keeps parallelism).
        self.pool.exec_batch(name, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_validates_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = HostTensor::zeros(&[4, 4]);
        assert_eq!(z.len(), 16);
    }

    #[test]
    fn default_exec_batch_loops_with_per_entry_errors() {
        /// Echoes non-empty input sets, errors on empty ones.
        struct Echo;
        impl ExecBackend for Echo {
            fn backend_name(&self) -> &str {
                "echo"
            }
            fn exec(&self, _n: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
                anyhow::ensure!(!inputs.is_empty(), "empty input set");
                Ok(inputs)
            }
            fn load(&self, _n: &str) -> Result<()> {
                Ok(())
            }
            fn loaded_names(&self) -> Vec<String> {
                Vec::new()
            }
        }
        let t = HostTensor::zeros(&[2]);
        let results = Echo.exec_batch("m", vec![vec![t.clone()], vec![], vec![t.clone()]]);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].as_ref().unwrap(), &vec![t.clone()]);
        assert!(results[1].is_err(), "bad entry must not fail its batch-mates");
        assert_eq!(results[2].as_ref().unwrap(), &vec![t]);
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Xla.name(), "xla");
        assert_eq!(BackendKind::Native.name(), "native");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn engine_starts_on_cpu() {
        let engine = Engine::cpu().unwrap();
        assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
        assert!(!engine.is_loaded("nope"));
    }

    #[cfg(feature = "xla")]
    #[test]
    fn missing_artifact_errors() {
        let mut engine = Engine::cpu().unwrap();
        let paths = Paths::new("/nonexistent", "/nonexistent");
        assert!(engine.load(&paths, "ghost").is_err());
        assert!(engine.exec("ghost", &[]).is_err());
    }

    #[cfg(feature = "xla")]
    #[test]
    fn xla_backend_pool_spawns_and_errors_on_missing_artifact() {
        let paths = Paths::new("/nonexistent", "/nonexistent");
        let backend = XlaBackend::spawn(paths.clone(), &[], 2).unwrap();
        assert_eq!(backend.pool_size(), 2);
        assert_eq!(backend.backend_name(), "xla");
        assert!(backend.exec("ghost", vec![]).is_err());
        assert!(backend.load("ghost").is_err());
        assert!(backend.loaded_names().is_empty());
        // Preload failure surfaces at spawn.
        assert!(XlaBackend::spawn(paths, &["ghost".to_string()], 1).is_err());
    }
}
