//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) emitted
//! by `python/compile/aot.py` and executes them on the request path.
//!
//! Interchange is HLO **text** — the image's xla_extension 0.5.1 rejects
//! serialized protos from jax ≥ 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate's handles are not `Send` (raw pointers), so the engine
//! is either used thread-locally ([`Engine`]) or behind the actor wrapper
//! ([`EngineActor`]) whose cloneable handle can cross threads; requests
//! are serialized onto the engine thread, which matches PJRT-CPU's
//! effectively-serial execution anyway.

mod actor;

pub use actor::{EngineActor, EngineHandle};

use crate::config::Paths;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// A host-side tensor (f32, row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        anyhow::ensure!(
            shape.iter().product::<usize>() == data.len(),
            "tensor shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len() * 4 + self.shape.len() * 8 + 16
    }
}

/// Compiled-executable registry over one PJRT client.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create an engine on the CPU PJRT backend.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name (`artifacts/<name>.hlo.txt`).
    pub fn load(&mut self, paths: &Paths, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = paths.hlo(name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("load HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
            .with_context(|| format!("compile {}", name))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load several artifacts.
    pub fn load_all(&mut self, paths: &Paths, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(paths, n)?;
        }
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<String> {
        self.executables.keys().cloned().collect()
    }

    /// Execute a loaded artifact. All jax functions are lowered with
    /// `return_tuple=True`, so the single output is a tuple which we
    /// decompose into one [`HostTensor`] per element.
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data =
                    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
                HostTensor::new(dims, data)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_validates_shape() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = HostTensor::zeros(&[4, 4]);
        assert_eq!(z.len(), 16);
    }

    #[test]
    fn engine_starts_on_cpu() {
        let engine = Engine::cpu().unwrap();
        assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
        assert!(!engine.is_loaded("nope"));
    }

    #[test]
    fn missing_artifact_errors() {
        let mut engine = Engine::cpu().unwrap();
        let paths = Paths::new("/nonexistent", "/nonexistent");
        assert!(engine.load(&paths, "ghost").is_err());
        assert!(engine.exec("ghost", &[]).is_err());
    }
}
