//! Edge-device worker: runs the head model on local point clouds and
//! streams intermediate outputs to the edge server (Fig 2, left half).
//!
//! ## Pipelined runtime
//!
//! The worker is a two-stage pipeline: the caller thread runs the head
//! model (capture → voxelize → head exec → encode), a dedicated writer
//! thread owns the (bandwidth-shaped, optionally fault-injected) socket.
//! A one-slot channel between them double-buffers frames, so head
//! execution of frame *t+1* overlaps transmission of frame *t* and the
//! steady-state device cycle is **max(head, tx)** instead of
//! `head + tx` — the latency hiding split computing relies on (PointSplit
//! makes the same move across heterogeneous accelerators). Frame pacing
//! uses absolute deadlines (`start + i·period`), so scheduling drift does
//! not accumulate over long runs and a single slow frame is absorbed by
//! catching up instead of shifting every later frame.

use crate::cli::Args;
use crate::config::{
    normalize_split, wire_channels, GridConfig, IntegrationKind, LatencyConfig, ModelMeta,
    Paths, SPLIT_DEPTHS,
};
use crate::metrics::Metrics;
use crate::net::{
    chunk_frame, encode_frame, DgramImpairer, ImpairConfig, ImpairStats, ImpairedLink, Msg,
    ShapedWriter,
};
use crate::runtime::{build_backend, BackendKind, HostTensor};
use crate::voxel::{points_to_tensor, Point};
use crate::sync::time::Instant;
use crate::sync::{mpsc, thread};
use anyhow::{Context, Result};
use std::net::{TcpStream, UdpSocket};
use std::time::Duration;

/// How feature frames leave the device. Control messages (`Hello`,
/// `Bye`) always go TCP; `Udp` moves only the feature uplink onto
/// chunked datagrams with latest-wins reassembly and optional
/// XOR-parity FEC (`docs/WIRE_PROTOCOL.md`, "Datagram transport").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Tcp,
    Udp,
}

impl Transport {
    pub fn parse(s: &str) -> Result<Transport> {
        match s {
            "tcp" => Ok(Transport::Tcp),
            "udp" => Ok(Transport::Udp),
            other => anyhow::bail!("unknown transport {other:?} (expected tcp or udp)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Transport::Tcp => "tcp",
            Transport::Udp => "udp",
        }
    }
}

/// Device worker configuration.
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    /// This worker's device slot (0..num_devices) within the session.
    pub device_id: usize,
    /// Server address (`host:port`).
    pub server: String,
    /// Named [`DetectorSession`](super::session::DetectorSession) on the
    /// server this worker feeds (multi-intersection hosting).
    pub session: String,
    /// Integration variant (selects which head model this worker runs).
    pub variant: IntegrationKind,
    /// Inter-frame period (paper: 10 Hz sensors). `None` = as fast as
    /// possible (throughput mode).
    pub period: Option<Duration>,
    /// Shape outgoing bytes to this line rate (paper: 1 Gbps LAN).
    pub bandwidth_bps: Option<f64>,
    /// Stop after this many frames.
    pub max_frames: usize,
    /// u8-quantize intermediate outputs before transmission (paper §IV-E
    /// compressed intermediate outputs: 4× smaller payload).
    pub quantize: bool,
    /// Execution backend running the head model on this worker.
    pub backend: BackendKind,
    /// Overlap head execution of frame t+1 with transmission of frame t
    /// (double-buffered writer thread). Off = the historical serialized
    /// loop, kept for A/B latency comparisons.
    pub pipelined: bool,
    /// Uplink fault injection (loss/delay/reorder); `None` = clean link.
    pub impair: Option<ImpairConfig>,
    /// First frame id this worker emits (late-join scenarios: a device
    /// joining mid-run starts at the fleet's current frame index).
    pub start_frame: u64,
    /// Feature-frame transport (`--transport udp`); control messages
    /// stay TCP either way. With `Udp`, `impair` applies per datagram
    /// instead of per frame and bandwidth shaping covers only the TCP
    /// control link.
    pub transport: Transport,
    /// Datagram FEC group size (`--fec k`): one XOR-parity datagram per
    /// `k` chunks, recovering any single loss per group without
    /// retransmit. 0 = FEC off. Only meaningful with `Udp`.
    pub fec_k: u32,
    /// Split depth this worker cuts the model at (`--split`): one of
    /// [`SPLIT_DEPTHS`], or empty for the default depth. Must match the
    /// session's configured depth — the server closes the connection at
    /// `Hello` time otherwise. (`--split auto` is resolved to a concrete
    /// depth by [`cmd_device`] before the config is built.)
    pub split: String,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            device_id: 0,
            server: "127.0.0.1:7321".into(),
            session: crate::net::DEFAULT_SESSION.into(),
            variant: IntegrationKind::ConvK3,
            period: Some(Duration::from_millis(100)),
            bandwidth_bps: Some(1e9),
            max_frames: 32,
            quantize: false,
            backend: BackendKind::default_kind(),
            pipelined: true,
            impair: None,
            start_frame: 0,
            transport: Transport::Tcp,
            fec_k: 0,
            split: String::new(),
        }
    }
}

/// What one worker run produced: per-frame timings plus uplink
/// fault-injection counters (zeros on a clean link).
#[derive(Clone, Debug, Default)]
pub struct DeviceReport {
    /// Per transmitted frame: (head_secs, tx_secs). `tx_secs` is measured
    /// on the writer thread and includes injected delay; frames the
    /// impairment layer dropped still appear (their send returns fast).
    pub frame_times: Vec<(f64, f64)>,
    /// Fault-injection counters.
    pub impair: ImpairStats,
}

/// Drive `n` frames through a produce (head) / consume (transmit) pair,
/// returning per-frame `(produce_secs, consume_secs)`.
///
/// With `pipelined`, `consume` runs on a dedicated writer thread behind a
/// one-slot channel: produce of frame *t+1* overlaps consume of frame
/// *t*, so the steady-state cycle is `max(produce, consume)` rather than
/// their sum. Without it, the two run back to back on the caller thread.
///
/// With a `period`, frame *i* is released no earlier than
/// `start + i·period` — absolute next-deadline scheduling, so per-cycle
/// overhead and one slow frame do not shift every subsequent frame the
/// way `sleep(period - elapsed)` loops do.
///
/// Frame ids passed to the callbacks run `start_frame..start_frame + n`.
pub fn pipeline_frames<M, P, C>(
    n: usize,
    start_frame: u64,
    period: Option<Duration>,
    pipelined: bool,
    mut produce: P,
    mut consume: C,
) -> Result<Vec<(f64, f64)>>
where
    M: Send,
    P: FnMut(u64) -> Result<M>,
    C: FnMut(u64, M) -> Result<()> + Send,
{
    let start = Instant::now();
    let pace = |i: usize| {
        if let Some(p) = period {
            let deadline = start + Duration::from_secs_f64(p.as_secs_f64() * i as f64);
            let now = Instant::now();
            if deadline > now {
                thread::sleep(deadline - now);
            }
        }
    };

    if !pipelined {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            pace(i);
            let frame_id = start_frame + i as u64;
            let t0 = Instant::now();
            let msg = produce(frame_id)?;
            let produce_secs = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            consume(frame_id, msg)?;
            out.push((produce_secs, t1.elapsed().as_secs_f64()));
        }
        return Ok(out);
    }

    let (tx, rx) = mpsc::sync_channel::<(u64, M)>(1);
    let mut produce_times: Vec<(u64, f64)> = Vec::with_capacity(n);
    let mut produce_err: Option<anyhow::Error> = None;
    // The writer thread borrows `consume` from the caller's stack, so it
    // needs a scope; the *channel* between the stages is the modeled
    // primitive (`crate::sync::mpsc`, exercised under loom in
    // `tests/loom.rs`), while the scope itself stays `std` — loom has no
    // scoped threads, and model tests drive the channel directly.
    let consume_times = std::thread::scope(|s| {
        let writer = s.spawn(move || -> Result<Vec<(u64, f64)>> {
            let mut out = Vec::new();
            for (frame_id, msg) in rx {
                let t0 = Instant::now();
                consume(frame_id, msg)?;
                out.push((frame_id, t0.elapsed().as_secs_f64()));
            }
            Ok(out)
        });
        for i in 0..n {
            pace(i);
            let frame_id = start_frame + i as u64;
            let t0 = Instant::now();
            match produce(frame_id) {
                Ok(msg) => {
                    produce_times.push((frame_id, t0.elapsed().as_secs_f64()));
                    if tx.send((frame_id, msg)).is_err() {
                        // The writer died; its error surfaces below.
                        break;
                    }
                }
                Err(e) => {
                    produce_err = Some(e);
                    break;
                }
            }
        }
        drop(tx); // closes the channel: the writer drains and returns
        writer.join().expect("device writer thread panicked")
    });
    if let Some(e) = produce_err {
        return Err(e);
    }
    let consume_times = consume_times?;
    // Pair by frame id (frames produced but never written — producer or
    // writer stopped early — are excluded).
    let consumed: std::collections::HashMap<u64, f64> = consume_times.into_iter().collect();
    Ok(produce_times
        .into_iter()
        .filter_map(|(id, p)| consumed.get(&id).map(|&c| (p, c)))
        .collect())
}

/// Run the worker over pre-loaded frames (each entry = this device's local
/// cloud for one frame).
pub fn run_device(
    paths: &Paths,
    cfg: &DeviceConfig,
    frames: &[Vec<Point>],
) -> Result<DeviceReport> {
    anyhow::ensure!(
        !cfg.session.is_empty() && cfg.session.len() <= crate::net::MAX_SESSION_NAME,
        "session name must be 1..={} bytes, got {:?}",
        crate::net::MAX_SESSION_NAME,
        cfg.session
    );
    let meta = ModelMeta::load(&paths.model_meta())?;
    let vm = meta.variant(cfg.variant)?;
    // Out-of-range --device used to panic on `vm.heads[cfg.device_id]`;
    // validate against the rig size instead.
    anyhow::ensure!(
        cfg.device_id < vm.heads.len(),
        "device id {} out of range: variant {:?} has {} heads (devices 0..{})",
        cfg.device_id,
        cfg.variant,
        vm.heads.len(),
        vm.heads.len()
    );
    let split = normalize_split(&cfg.split)?;
    let head_name = vm.head_for(cfg.device_id, split)?;
    // One worker, one head model, one frame in flight on the backend: a
    // single-threaded backend is all a device needs (the overlap is
    // between head exec and transmission, not between head execs).
    let backend = build_backend(paths, &meta, cfg.backend, 1, &[head_name.clone()])?;

    let stream = TcpStream::connect(&cfg.server)
        .with_context(|| format!("connect to {}", cfg.server))?;
    stream.set_nodelay(true)?;
    let writer = match cfg.bandwidth_bps {
        Some(bw) => ShapedWriter::new(stream, bw),
        None => ShapedWriter::unshaped(stream),
    };
    // With the datagram uplink, fault injection applies per datagram
    // (below); the TCP control link stays clean so `Hello`/`Bye` always
    // arrive and the wire bytes of the TCP mode stay byte-identical.
    let link_impair = if cfg.transport == Transport::Tcp { cfg.impair } else { None };
    let mut link = ImpairedLink::new(writer, link_impair);
    // The wire carries the configured (possibly empty) split string, not
    // the normalized name: default-depth devices emit a Hello
    // byte-identical to pre-split workers, which legacy servers accept.
    link.send(&Msg::Hello {
        device_id: cfg.device_id as u32,
        session: cfg.session.clone(),
        split: cfg.split.clone(),
    })?;

    let n = frames.len().min(cfg.max_frames.max(1));
    let device_id = cfg.device_id as u32;
    let quantize = cfg.quantize;
    let session = cfg.session.clone();
    let start_frame = cfg.start_frame;
    let max_points = meta.grid.max_points;

    let mut produce = |frame_id: u64| -> Result<Msg> {
        let cloud = &frames[(frame_id - start_frame) as usize];
        let capture_micros = crate::utils::unix_micros();
        let input = HostTensor::new(
            vec![max_points, 4],
            points_to_tensor(cloud, max_points),
        )?;
        let mut feat = backend.exec(&head_name, vec![input])?;
        anyhow::ensure!(!feat.is_empty(), "head {head_name:?} returned no output");
        let tensor = feat.remove(0);
        Ok(if quantize {
            Msg::FeaturesQ {
                frame_id,
                device_id,
                tensor: crate::net::quantize(&tensor),
                session: session.clone(),
                capture_micros,
            }
        } else {
            Msg::Features { frame_id, device_id, tensor, session: session.clone(), capture_micros }
        })
    };

    let (frame_times, impair_stats) = match cfg.transport {
        Transport::Tcp => {
            let times = pipeline_frames(
                n,
                start_frame,
                cfg.period,
                cfg.pipelined,
                &mut produce,
                |_frame_id, msg| link.send(&msg),
            )?;
            (times, link.stats())
        }
        Transport::Udp => {
            let socket = UdpSocket::bind("0.0.0.0:0").context("bind datagram uplink")?;
            socket
                .connect(&cfg.server)
                .with_context(|| format!("udp connect to {}", cfg.server))?;
            let mut imp = DgramImpairer::new(cfg.impair);
            let dg_session = cfg.session.clone();
            let fec_k = cfg.fec_k;
            let times = pipeline_frames(
                n,
                start_frame,
                cfg.period,
                cfg.pipelined,
                &mut produce,
                |frame_id, msg: Msg| {
                    // Encode to the exact TCP framed bytes, then chunk:
                    // the server reassembles byte-identical frames and
                    // feeds them to the unchanged decode path.
                    let framed = encode_frame(&msg)?;
                    let mut tx = |d: &[u8]| -> Result<()> {
                        socket.send(d).context("udp send")?;
                        Ok(())
                    };
                    for dgram in
                        chunk_frame(&framed, &dg_session, device_id, frame_id, fec_k)?
                    {
                        imp.send(dgram, &mut tx)?;
                    }
                    Ok(())
                },
            )?;
            // Flush a datagram the reorder injector may still hold, so
            // the final frame can complete server-side.
            imp.finish(&mut |d: &[u8]| {
                socket.send(d).context("udp send")?;
                Ok(())
            })?;
            (times, imp.stats())
        }
    };
    link.send(&Msg::Bye)?;

    let metrics = Metrics::new();
    for &(head_secs, tx_secs) in &frame_times {
        metrics.record("head_exec", head_secs);
        metrics.record("tx", tx_secs);
    }
    log::info!("device {} done:\n{}", cfg.device_id, metrics.report());
    Ok(DeviceReport { frame_times, impair: impair_stats })
}

/// Pick the split depth whose steady-state device cycle is smallest.
///
/// Under the pipelined runtime the cycle is `max(head, tx)` (head exec
/// of frame *t+1* overlaps transmission of frame *t*), so the best cut
/// balances device compute against uplink width: `measured` pairs each
/// candidate depth with its measured head-execution seconds, and tx
/// seconds are modeled from the depth's wire channel count at
/// `bandwidth_bps` (an unshaped link prices tx at zero, so the cheapest
/// head wins). Ties keep the earlier candidate, so list depths in
/// preference order.
pub fn choose_split(
    measured: &[(&str, f64)],
    grid: &GridConfig,
    bandwidth_bps: Option<f64>,
) -> Result<&'static str> {
    anyhow::ensure!(!measured.is_empty(), "no split candidates measured");
    let cells = grid.dims[0] * grid.dims[1] * grid.dims[2];
    let mut best: Option<(&'static str, f64)> = None;
    for &(split, head_secs) in measured {
        let split = normalize_split(split)?;
        let tx_secs = match bandwidth_bps {
            Some(bw) if bw > 0.0 => {
                let bits = (cells * wire_channels(grid, split)? * 4 * 8) as f64;
                bits / bw
            }
            _ => 0.0,
        };
        let cycle = head_secs.max(tx_secs);
        if best.is_none() || cycle < best.expect("checked").1 {
            best = Some((split, cycle));
        }
    }
    Ok(best.expect("measured is non-empty").0)
}

/// Resolve `--split auto`: run every depth's head once to warm caches,
/// once to measure, on a synthetic zero cloud, then pick with
/// [`choose_split`]. The measurement backend is thrown away — the real
/// run builds its own with only the chosen head resident.
fn auto_pick_split(paths: &Paths, meta: &ModelMeta, cfg: &DeviceConfig) -> Result<&'static str> {
    let vm = meta.variant(cfg.variant)?;
    let heads: Vec<String> = SPLIT_DEPTHS
        .iter()
        .map(|s| vm.head_for(cfg.device_id, s))
        .collect::<Result<_>>()?;
    let backend = build_backend(paths, meta, cfg.backend, 1, &heads)?;
    let input = HostTensor::zeros(&[meta.grid.max_points, 4]);
    let mut measured: Vec<(&str, f64)> = Vec::new();
    for (split, head) in SPLIT_DEPTHS.iter().zip(&heads) {
        backend.exec(head, vec![input.clone()])?; // warm-up
        let t0 = Instant::now();
        backend.exec(head, vec![input.clone()])?;
        measured.push((split, t0.elapsed().as_secs_f64()));
    }
    let pick = choose_split(&measured, &meta.grid, cfg.bandwidth_bps)?;
    log::info!(
        "auto split: measured {:?} -> {pick} (bandwidth {:?} bps)",
        measured,
        cfg.bandwidth_bps
    );
    Ok(pick)
}

/// `scmii device` CLI entry: stream frames from the dataset.
pub fn cmd_device(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts",
        "data",
        "device",
        "server",
        "session",
        "variant",
        "hz",
        "bandwidth-gbps",
        "max-frames",
        "split",
        "data-split",
        "unshaped",
        "quantize",
        "backend",
        "no-pipeline",
        "start-frame",
        "loss",
        "drop-every",
        "delay-ms",
        "jitter-ms",
        "reorder",
        "dup",
        "impair-seed",
        "transport",
        "fec",
    ])?;
    let paths = Paths::new(
        &args.str_or("artifacts", "artifacts"),
        &args.str_or("data", "data"),
    );
    let mut cfg = DeviceConfig::default();
    cfg.device_id = args.usize_or("device", 0)?;
    cfg.server = args.str_or("server", &cfg.server);
    cfg.session = args.str_or("session", &cfg.session);
    cfg.variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    let hz = args.f64_or("hz", 10.0)?;
    cfg.period = if hz > 0.0 { Some(Duration::from_secs_f64(1.0 / hz)) } else { None };
    cfg.bandwidth_bps = if args.switch("unshaped") {
        None
    } else {
        Some(args.f64_or("bandwidth-gbps", LatencyConfig::default().bandwidth_bps / 1e9)? * 1e9)
    };
    cfg.max_frames = args.usize_or("max-frames", 32)?;
    cfg.quantize = args.switch("quantize");
    cfg.backend = BackendKind::parse(&args.str_or("backend", cfg.backend.name()))?;
    cfg.pipelined = !args.switch("no-pipeline");
    cfg.start_frame = args.u64_or("start-frame", 0)?;
    cfg.transport = Transport::parse(&args.str_one_of("transport", &["tcp", "udp"], "tcp")?)?;
    cfg.fec_k = args.u64_or("fec", 0)? as u32;
    anyhow::ensure!(
        cfg.transport == Transport::Udp || cfg.fec_k == 0,
        "--fec applies to the datagram uplink; add --transport udp"
    );
    let impair = ImpairConfig {
        loss: args.f64_or("loss", 0.0)?,
        drop_every: args.u64_or("drop-every", 0)?,
        delay: Duration::from_millis(args.u64_or("delay-ms", 0)?),
        jitter: Duration::from_millis(args.u64_or("jitter-ms", 0)?),
        reorder: args.f64_or("reorder", 0.0)?,
        dup: args.f64_or("dup", 0.0)?,
        seed: args.u64_or("impair-seed", 1)?,
    };
    let clean = ImpairConfig { seed: impair.seed, ..Default::default() };
    if impair != clean {
        impair.validate()?;
        cfg.impair = Some(impair);
    }

    // Split depth: a concrete name, or `auto` to measure each depth's
    // head against the modeled uplink and pick the best cycle. (The
    // dataset partition moved to `--data-split` when this flag arrived.)
    cfg.split = args.str_or("split", "");
    if cfg.split == "auto" {
        let meta = ModelMeta::load(&paths.model_meta())?;
        cfg.split = auto_pick_split(&paths, &meta, &cfg)?.to_string();
        println!("auto split -> {}", cfg.split);
    } else {
        normalize_split(&cfg.split)?;
    }

    let data_split = args.str_or("data-split", "val");
    let frames = crate::sim::dataset::load_split(&paths.data.join(&data_split))?;
    anyhow::ensure!(!frames.is_empty(), "no frames in data split {data_split:?}");
    // Out-of-range --device used to panic in `swap_remove`; check the
    // dataset's rig size up front.
    let n_dev = frames[0].clouds.len();
    anyhow::ensure!(
        cfg.device_id < n_dev,
        "--device {} out of range: dataset {:?} has {} devices",
        cfg.device_id,
        data_split,
        n_dev
    );
    let clouds: Vec<Vec<Point>> =
        frames.into_iter().map(|mut f| f.clouds.swap_remove(cfg.device_id)).collect();
    run_device(&paths, &cfg, &clouds)?;
    Ok(())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn transport_parses_and_rejects_unknown() {
        assert_eq!(Transport::parse("tcp").unwrap(), Transport::Tcp);
        assert_eq!(Transport::parse("udp").unwrap(), Transport::Udp);
        assert!(Transport::parse("sctp").is_err());
        assert_eq!(Transport::Udp.name(), "udp");
        assert_eq!(DeviceConfig::default().transport, Transport::Tcp, "udp is opt-in");
        assert_eq!(DeviceConfig::default().fec_k, 0, "FEC is opt-in");
    }

    #[test]
    fn run_device_rejects_out_of_range_device_id() {
        // A temp model_meta.json is all the validation path needs — the
        // error must fire before any backend is built or socket opened.
        let dir = std::env::temp_dir().join("scmii_device_oob_test");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = Paths { artifacts: dir.clone(), data: dir };
        crate::utils::json::write_file(
            &paths.model_meta(),
            &ModelMeta::test_default().to_json(),
        )
        .unwrap();

        let mut cfg = DeviceConfig::default();
        cfg.device_id = 99;
        cfg.variant = IntegrationKind::Max;
        let err = run_device(&paths, &cfg, &[Vec::new()]).unwrap_err();
        assert!(
            err.to_string().contains("out of range"),
            "expected a device-range error, got: {err:#}"
        );
    }

    /// Timestamped spans recorded inside the stub head/writer closures.
    type SpanLog = Arc<Mutex<Vec<(&'static str, u64, Instant, Instant)>>>;

    fn spans_overlap(a: (Instant, Instant), b: (Instant, Instant)) -> bool {
        a.0.max(b.0) < a.1.min(b.1)
    }

    fn run_stub_pipeline(
        n: usize,
        head: Duration,
        tx: Duration,
        pipelined: bool,
    ) -> (Vec<(f64, f64)>, SpanLog, Duration) {
        let log: SpanLog = Arc::new(Mutex::new(Vec::new()));
        let (hlog, tlog) = (Arc::clone(&log), Arc::clone(&log));
        let t0 = Instant::now();
        let times = pipeline_frames(
            n,
            0,
            None,
            pipelined,
            move |id| {
                let s = Instant::now();
                std::thread::sleep(head);
                hlog.lock().unwrap().push(("head", id, s, Instant::now()));
                Ok(id)
            },
            move |id, _msg: u64| {
                let s = Instant::now();
                std::thread::sleep(tx);
                tlog.lock().unwrap().push(("tx", id, s, Instant::now()));
                Ok(())
            },
        )
        .unwrap();
        let total = t0.elapsed();
        (times, log, total)
    }

    /// The tentpole acceptance assertion: with the pipelined runtime the
    /// device cycle is ≈ max(head, tx), not head + tx. Proven two ways:
    /// head-exec spans overlap transmission spans (timestamps recorded
    /// inside the stubs), and the wall clock beats the serial sum by a
    /// margin no scheduling noise can fake.
    #[test]
    fn pipelined_device_cycle_is_max_of_head_and_tx() {
        let (head, tx) = (Duration::from_millis(25), Duration::from_millis(40));
        let n = 6;
        let (times, log, total) = run_stub_pipeline(n, head, tx, true);
        assert_eq!(times.len(), n);

        // Wall clock: serial would be n·(head+tx) = 390 ms; pipelined is
        // ≈ head + n·tx = 265 ms. Demand at least one tx of savings.
        let serial = (head + tx) * n as u32;
        assert!(
            total < serial - tx,
            "pipelined run took {total:?}, serial would be {serial:?}"
        );
        // It can't beat the bottleneck stage either.
        assert!(total >= tx * n as u32, "faster than the bottleneck: {total:?}");

        // Timestamps: head of frame i+1 must overlap tx of frame i.
        let log = log.lock().unwrap();
        let span = |kind: &str, id: u64| {
            log.iter()
                .find(|(k, i, _, _)| *k == kind && *i == id)
                .map(|(_, _, s, e)| (*s, *e))
                .unwrap()
        };
        let mut overlaps = 0;
        for i in 0..(n as u64 - 1) {
            if spans_overlap(span("head", i + 1), span("tx", i)) {
                overlaps += 1;
            }
        }
        assert!(
            overlaps >= 1,
            "head exec of frame t+1 must overlap tx of frame t at least once"
        );
    }

    /// Control: the non-pipelined loop serializes head and tx.
    #[test]
    fn non_pipelined_loop_serializes_head_and_tx() {
        let (head, tx) = (Duration::from_millis(15), Duration::from_millis(20));
        let n = 4;
        let (times, log, total) = run_stub_pipeline(n, head, tx, false);
        assert_eq!(times.len(), n);
        assert!(total >= (head + tx) * n as u32, "serial loop finished too fast: {total:?}");
        let log = log.lock().unwrap();
        for (_, _, s1, e1) in log.iter() {
            for (_, _, s2, e2) in log.iter() {
                if s1 != s2 {
                    assert!(
                        !spans_overlap((*s1, *e1), (*s2, *e2)),
                        "no two stages may overlap without pipelining"
                    );
                }
            }
        }
    }

    /// Satellite regression: pacing uses absolute deadlines, so one slow
    /// frame is absorbed by catching up instead of shifting every later
    /// frame (`sleep(period - elapsed)` drifts by the overshoot forever).
    #[test]
    fn absolute_deadline_pacing_absorbs_a_slow_frame() {
        let period = Duration::from_millis(30);
        let n = 8;
        let t0 = Instant::now();
        let times = pipeline_frames(
            n,
            0,
            Some(period),
            false,
            |id| {
                // Frame 2 blows its budget by ~65 ms; the rest are cheap.
                if id == 2 {
                    std::thread::sleep(Duration::from_millis(95));
                }
                Ok(id)
            },
            |_, _: u64| Ok(()),
        )
        .unwrap();
        let total = t0.elapsed();
        assert_eq!(times.len(), n);
        // Last frame is released at (n-1)·period = 210 ms; drifting
        // relative scheduling would land at ≥ 275 ms (210 + the 65 ms
        // overshoot it never recovers), so 265 ms discriminates.
        let budget = period * (n as u32 - 1) + Duration::from_millis(55);
        assert!(
            total < budget,
            "pacing drifted: took {total:?}, absolute schedule allows {budget:?}"
        );
        assert!(total >= period * (n as u32 - 1), "finished before the schedule: {total:?}");
    }

    /// A writer-side failure must surface as the run's error, not hang.
    #[test]
    fn writer_error_propagates() {
        let err = pipeline_frames(
            8,
            0,
            None,
            true,
            |id| Ok(id),
            |id, _: u64| {
                anyhow::ensure!(id < 2, "link down");
                Ok(())
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("link down"));
    }

    #[test]
    fn choose_split_balances_head_against_uplink() {
        let g = GridConfig::default();
        // Heads get costlier with depth; on a slow 1 Mbps uplink tx
        // dominates every cycle, so the narrowest wire (deep) wins even
        // with the most expensive head.
        let measured = [("split-shallow", 0.01), ("split-mid", 0.02), ("split-deep", 0.04)];
        assert_eq!(choose_split(&measured, &g, Some(1e6)).unwrap(), "split-deep");

        // Unshaped link: tx is free, the cheapest head wins.
        assert_eq!(choose_split(&measured, &g, None).unwrap(), "split-shallow");
        // Same on a link fast enough that head time dominates.
        assert_eq!(choose_split(&measured, &g, Some(1e12)).unwrap(), "split-shallow");

        // Default-depth spelling ("" = split-mid) normalizes.
        assert_eq!(choose_split(&[("", 0.01)], &g, None).unwrap(), "split-mid");

        assert!(choose_split(&[], &g, None).is_err(), "no candidates is an error");
        assert!(
            choose_split(&[("split-bogus", 0.01)], &g, None).is_err(),
            "unknown depth is an error, not a silent skip"
        );
    }

    #[test]
    fn device_split_defaults_keep_the_legacy_wire_form() {
        let cfg = DeviceConfig::default();
        assert_eq!(cfg.split, "", "default depth: Hello omits the split field");
        let frame = |split: &str| {
            crate::net::encode_frame(&Msg::Hello {
                device_id: cfg.device_id as u32,
                session: cfg.session.clone(),
                split: split.to_string(),
            })
            .unwrap()
        };
        let legacy = frame("");
        let deep = frame("split-deep");
        assert_eq!(
            deep.len(),
            legacy.len() + 1 + "split-deep".len(),
            "an explicit split costs exactly len-byte + name; the default costs zero"
        );
        // Header is magic(4) + type(1) + payload-length(4): the frames
        // agree everywhere except the length field and the trailing
        // split bytes.
        assert_eq!(&deep[..5], &legacy[..5]);
        assert_eq!(&deep[9..legacy.len()], &legacy[9..]);
    }

    /// Frame ids offset by `start_frame` (late join).
    #[test]
    fn start_frame_offsets_ids() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let s2 = Arc::clone(&seen);
        let times = pipeline_frames(
            3,
            10,
            None,
            true,
            move |id| {
                s2.lock().unwrap().push(id);
                Ok(id)
            },
            |_, _: u64| Ok(()),
        )
        .unwrap();
        assert_eq!(times.len(), 3);
        assert_eq!(*seen.lock().unwrap(), vec![10, 11, 12]);
    }
}
