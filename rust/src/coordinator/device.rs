//! Edge-device worker: runs the head model on local point clouds and
//! streams intermediate outputs to the edge server (Fig 2, left half).

use crate::cli::Args;
use crate::config::{IntegrationKind, LatencyConfig, ModelMeta, Paths};
use crate::metrics::Metrics;
use crate::net::{write_msg, Msg, ShapedWriter};
use crate::runtime::{build_backend, BackendKind, HostTensor};
use crate::voxel::{points_to_tensor, Point};
use anyhow::{Context, Result};
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Device worker configuration.
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    pub device_id: usize,
    pub server: String,
    /// Named [`DetectorSession`](super::session::DetectorSession) on the
    /// server this worker feeds (multi-intersection hosting).
    pub session: String,
    pub variant: IntegrationKind,
    /// Inter-frame period (paper: 10 Hz sensors). `None` = as fast as
    /// possible (throughput mode).
    pub period: Option<Duration>,
    /// Shape outgoing bytes to this line rate (paper: 1 Gbps LAN).
    pub bandwidth_bps: Option<f64>,
    pub max_frames: usize,
    /// u8-quantize intermediate outputs before transmission (paper §IV-E
    /// compressed intermediate outputs: 4× smaller payload).
    pub quantize: bool,
    /// Execution backend running the head model on this worker.
    pub backend: BackendKind,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            device_id: 0,
            server: "127.0.0.1:7321".into(),
            session: crate::net::DEFAULT_SESSION.into(),
            variant: IntegrationKind::ConvK3,
            period: Some(Duration::from_millis(100)),
            bandwidth_bps: Some(1e9),
            max_frames: 32,
            quantize: false,
            backend: BackendKind::default_kind(),
        }
    }
}

/// Run the worker over pre-loaded frames (each entry = this device's local
/// cloud for one frame). Returns per-frame (head_secs, tx_secs).
pub fn run_device(
    paths: &Paths,
    cfg: &DeviceConfig,
    frames: &[Vec<Point>],
) -> Result<Vec<(f64, f64)>> {
    anyhow::ensure!(
        !cfg.session.is_empty() && cfg.session.len() <= crate::net::MAX_SESSION_NAME,
        "session name must be 1..={} bytes, got {:?}",
        crate::net::MAX_SESSION_NAME,
        cfg.session
    );
    let meta = ModelMeta::load(&paths.model_meta())?;
    let vm = meta.variant(cfg.variant)?;
    let head_name = vm.heads[cfg.device_id].clone();
    // One worker, one head model, one frame in flight: a single-threaded
    // backend is all a device needs.
    let backend = build_backend(paths, &meta, cfg.backend, 1, &[head_name.clone()])?;

    let stream = TcpStream::connect(&cfg.server)
        .with_context(|| format!("connect to {}", cfg.server))?;
    stream.set_nodelay(true)?;
    let mut writer = match cfg.bandwidth_bps {
        Some(bw) => ShapedWriter::new(stream, bw),
        None => ShapedWriter::unshaped(stream),
    };
    write_msg(
        &mut writer,
        &Msg::Hello { device_id: cfg.device_id as u32, session: cfg.session.clone() },
    )?;

    let metrics = Metrics::new();
    let mut out = Vec::new();
    let n = frames.len().min(cfg.max_frames.max(1));
    for (frame_id, cloud) in frames.iter().take(n).enumerate() {
        let cycle_start = Instant::now();
        let input = HostTensor::new(
            vec![meta.grid.max_points, 4],
            points_to_tensor(cloud, meta.grid.max_points),
        )?;
        let t0 = Instant::now();
        let mut feat = backend.exec(&head_name, vec![input])?;
        let head_secs = t0.elapsed().as_secs_f64();
        metrics.record("head_exec", head_secs);

        let t0 = Instant::now();
        let msg = if cfg.quantize {
            Msg::FeaturesQ {
                frame_id: frame_id as u64,
                device_id: cfg.device_id as u32,
                tensor: crate::net::quantize(&feat.remove(0)),
                session: cfg.session.clone(),
            }
        } else {
            Msg::Features {
                frame_id: frame_id as u64,
                device_id: cfg.device_id as u32,
                tensor: feat.remove(0),
                session: cfg.session.clone(),
            }
        };
        write_msg(&mut writer, &msg)?;
        writer.flush()?;
        let tx_secs = t0.elapsed().as_secs_f64();
        metrics.record("tx", tx_secs);
        out.push((head_secs, tx_secs));

        if let Some(period) = cfg.period {
            let elapsed = cycle_start.elapsed();
            if elapsed < period {
                std::thread::sleep(period - elapsed);
            }
        }
    }
    write_msg(&mut writer, &Msg::Bye)?;
    log::info!("device {} done:\n{}", cfg.device_id, metrics.report());
    Ok(out)
}

/// `scmii device` CLI entry: stream frames from the dataset.
pub fn cmd_device(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts",
        "data",
        "device",
        "server",
        "session",
        "variant",
        "hz",
        "bandwidth-gbps",
        "max-frames",
        "split",
        "unshaped",
        "quantize",
        "backend",
    ])?;
    let paths = Paths::new(
        &args.str_or("artifacts", "artifacts"),
        &args.str_or("data", "data"),
    );
    let mut cfg = DeviceConfig::default();
    cfg.device_id = args.usize_or("device", 0)?;
    cfg.server = args.str_or("server", &cfg.server);
    cfg.session = args.str_or("session", &cfg.session);
    cfg.variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    let hz = args.f64_or("hz", 10.0)?;
    cfg.period = if hz > 0.0 { Some(Duration::from_secs_f64(1.0 / hz)) } else { None };
    cfg.bandwidth_bps = if args.switch("unshaped") {
        None
    } else {
        Some(args.f64_or("bandwidth-gbps", LatencyConfig::default().bandwidth_bps / 1e9)? * 1e9)
    };
    cfg.max_frames = args.usize_or("max-frames", 32)?;
    cfg.quantize = args.switch("quantize");
    cfg.backend = BackendKind::parse(&args.str_or("backend", cfg.backend.name()))?;

    let split = args.str_or("split", "val");
    let frames = crate::sim::dataset::load_split(&paths.data.join(&split))?;
    let clouds: Vec<Vec<Point>> =
        frames.into_iter().map(|mut f| f.clouds.swap_remove(cfg.device_id)).collect();
    run_device(&paths, &cfg, &clouds)?;
    Ok(())
}
