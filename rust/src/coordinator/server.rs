//! The edge server: receives intermediate outputs from device workers
//! over TCP, synchronizes them per frame, runs the tail model
//! (alignment + integration + detection heads) and publishes results.

use super::scheduler::{FrameSync, LossPolicy};
use crate::cli::Args;
use crate::config::{IntegrationKind, ModelMeta, Paths};
use crate::metrics::Metrics;
use crate::model::{postprocess, DecodeParams};
use crate::net::{read_msg, write_msg, Msg, WireDetection};
use crate::runtime::{EngineActor, EngineHandle};
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub port: u16,
    pub variant: IntegrationKind,
    pub deadline: Duration,
    pub policy: LossPolicy,
    /// Stop after this many frames (None = run until Ctrl-C).
    pub max_frames: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7321,
            variant: IntegrationKind::ConvK3,
            deadline: Duration::from_millis(200),
            policy: LossPolicy::ZeroFill,
            max_frames: None,
        }
    }
}

struct Shared {
    sync: Mutex<FrameSync>,
    subscribers: Mutex<Vec<TcpStream>>,
    metrics: Metrics,
    done: std::sync::atomic::AtomicBool,
    frames_out: std::sync::atomic::AtomicU64,
}

/// Run the edge server until `max_frames` results have been produced.
/// Returns the metrics collected.
pub fn run_server(paths: &Paths, cfg: &ServerConfig) -> Result<Arc<Metrics>> {
    let meta = ModelMeta::load(&paths.model_meta())?;
    let vm = meta.variant(cfg.variant)?.clone();
    let actor = EngineActor::spawn(paths.clone(), &[vm.tail.clone()])?;
    let engine = actor.handle();

    let grid = &meta.grid;
    let feat_shape = vec![grid.dims[2], grid.dims[1], grid.dims[0], grid.c_head];
    let shared = Arc::new(Shared {
        sync: Mutex::new(FrameSync::new(meta.num_devices, cfg.deadline, cfg.policy, feat_shape)),
        subscribers: Mutex::new(Vec::new()),
        metrics: Metrics::new(),
        done: std::sync::atomic::AtomicBool::new(false),
        frames_out: std::sync::atomic::AtomicU64::new(0),
    });

    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("bind port {}", cfg.port))?;
    listener.set_nonblocking(true)?;
    log::info!(
        "edge server on 127.0.0.1:{} variant={} devices={}",
        cfg.port,
        cfg.variant.name(),
        meta.num_devices
    );

    let mut conn_threads = Vec::new();
    let deadline_poll = Duration::from_millis(20);
    loop {
        if shared.done.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, addr)) => {
                log::debug!("connection from {addr}");
                let shared = Arc::clone(&shared);
                let engine = engine.clone();
                let meta = meta.clone();
                let tail = vm.tail.clone();
                let cfg = cfg.clone();
                conn_threads.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, shared, engine, meta, tail, cfg) {
                        log::debug!("connection ended: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Poll expired frames while idle.
                let expired = shared.sync.lock().unwrap().poll_expired();
                for ready in expired {
                    process_ready(&shared, &engine, &meta, &vm.tail, cfg, ready);
                }
                std::thread::sleep(deadline_poll);
            }
            Err(e) => return Err(e.into()),
        }
    }
    for t in conn_threads {
        let _ = t.join();
    }
    // Metrics live in Shared; clone the report out via Arc.
    let shared2 = Arc::clone(&shared);
    drop(shared);
    // Safe: all threads joined; extract metrics by Arc::try_unwrap fallback.
    Ok(Arc::new(match Arc::try_unwrap(shared2) {
        Ok(s) => s.metrics,
        Err(arc) => {
            // Still referenced (should not happen); clone the report only.
            let m = Metrics::new();
            m.incr("metrics_clone_fallback", 1);
            log::warn!("metrics still shared; report:\n{}", arc.metrics.report());
            m
        }
    }))
}

fn handle_conn(
    stream: TcpStream,
    shared: Arc<Shared>,
    engine: EngineHandle,
    meta: ModelMeta,
    tail: String,
    cfg: ServerConfig,
) -> Result<()> {
    stream.set_nodelay(true)?;
    // Read timeout so the thread re-checks `done` even on idle
    // connections (e.g. a subscriber that only listens).
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    loop {
        if shared.done.load(std::sync::atomic::Ordering::SeqCst) {
            return Ok(());
        }
        let msg = match read_msg(&mut reader) {
            Ok(m) => m,
            Err(e) => {
                // Timeout (no header byte yet): keep polling. Any other
                // error means the peer closed or the stream desynced.
                let timed_out = e.downcast_ref::<std::io::Error>().map_or(false, |io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out {
                    continue;
                }
                return Ok(()); // connection closed
            }
        };
        match msg {
            Msg::Hello { device_id } => {
                log::info!("device {device_id} connected");
            }
            Msg::Subscribe => {
                shared.subscribers.lock().unwrap().push(stream.try_clone()?);
                log::info!("result subscriber attached");
            }
            Msg::Features { frame_id, device_id, tensor } => {
                shared.metrics.incr("features_rx", 1);
                let ready =
                    shared.sync.lock().unwrap().add(frame_id, device_id as usize, tensor);
                if let Some(ready) = ready {
                    process_ready(&shared, &engine, &meta, &tail, &cfg, ready);
                }
                // Opportunistically resolve expirations on traffic too.
                let expired = shared.sync.lock().unwrap().poll_expired();
                for r in expired {
                    process_ready(&shared, &engine, &meta, &tail, &cfg, r);
                }
            }
            Msg::FeaturesQ { frame_id, device_id, tensor } => {
                // Compressed intermediate output (paper §IV-E): dequantize
                // at the server edge, then flow through the same path.
                shared.metrics.incr("features_rx_quantized", 1);
                match crate::net::dequantize(&tensor) {
                    Ok(full) => {
                        let ready = shared
                            .sync
                            .lock()
                            .unwrap()
                            .add(frame_id, device_id as usize, full);
                        if let Some(ready) = ready {
                            process_ready(&shared, &engine, &meta, &tail, &cfg, ready);
                        }
                    }
                    Err(e) => {
                        shared.metrics.incr("decode_errors", 1);
                        log::warn!("bad quantized features: {e:#}");
                    }
                }
            }
            Msg::Bye => return Ok(()),
            Msg::Result { .. } => {
                log::warn!("unexpected Result from client");
            }
        }
    }
}

fn process_ready(
    shared: &Arc<Shared>,
    engine: &EngineHandle,
    meta: &ModelMeta,
    tail: &str,
    cfg: &ServerConfig,
    ready: super::scheduler::ReadyFrame,
) {
    let t0 = Instant::now();
    let result = engine.exec(tail, ready.tensors);
    let tail_secs = t0.elapsed().as_secs_f64();
    shared.metrics.record("tail_exec", tail_secs);
    shared
        .metrics
        .record("sync_wait", t0.duration_since(ready.first_arrival).as_secs_f64());
    let dets = match result {
        Ok(out) if out.len() == 2 => {
            postprocess(&out[0].data, &out[1].data, meta, &DecodeParams::default())
        }
        Ok(_) | Err(_) => {
            shared.metrics.incr("tail_errors", 1);
            Vec::new()
        }
    };
    shared.metrics.incr("frames_done", 1);
    let wire: Vec<WireDetection> = dets
        .iter()
        .map(|d| WireDetection {
            bbox: d.bbox.to_array(),
            score: d.score,
            class_id: d.class_id as u32,
        })
        .collect();
    let msg = Msg::Result {
        frame_id: ready.frame_id,
        detections: wire,
        server_micros: (tail_secs * 1e6) as u64,
    };
    let mut subs = shared.subscribers.lock().unwrap();
    subs.retain_mut(|s| write_msg(s, &msg).is_ok());
    drop(subs);

    let done = shared
        .frames_out
        .fetch_add(1, std::sync::atomic::Ordering::SeqCst)
        + 1;
    if let Some(max) = cfg.max_frames {
        if done >= max {
            shared.done.store(true, std::sync::atomic::Ordering::SeqCst);
        }
    }
}

/// `scmii serve` CLI entry.
pub fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "port", "variant", "deadline-ms", "policy", "max-frames"])?;
    let paths = Paths::new(&args.str_or("artifacts", "artifacts"), "data");
    let mut cfg = ServerConfig::default();
    cfg.port = args.usize_or("port", cfg.port as usize)? as u16;
    cfg.variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    cfg.deadline = Duration::from_millis(args.u64_or("deadline-ms", 200)?);
    cfg.policy = match args.str_or("policy", "zero-fill").as_str() {
        "drop" => LossPolicy::Drop,
        _ => LossPolicy::ZeroFill,
    };
    let max = args.u64_or("max-frames", 0)?;
    cfg.max_frames = if max > 0 { Some(max) } else { None };
    let metrics = run_server(&paths, &cfg)?;
    print!("{}", metrics.report());
    Ok(())
}
