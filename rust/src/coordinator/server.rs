//! The edge server, reduced to pure I/O: sockets in, [`Msg`]s decoded,
//! everything else delegated to the [`DetectorSession`] serving core.
//! One process hosts N named sessions (multiple intersections, A/B
//! integration variants) through a [`SessionRegistry`]; wire messages
//! address a session by name, with pre-session clients landing on
//! [`DEFAULT_SESSION`].
//!
//! ## Event-loop connection handling
//!
//! Connections are multiplexed on **one** readiness-driven event loop
//! (see [`crate::net::poll`]) instead of one OS thread each, so fleet
//! size is bounded by fd limits and backend throughput, not by thread
//! count. Ownership is strict:
//!
//! * the **loop thread** owns the listener, every [`TcpStream`], the
//!   per-connection [`FrameAssembler`]s, and the poller — nothing else
//!   touches a socket;
//! * a fixed **worker pool** (`utils/threadpool.rs`) owns decode +
//!   session dispatch: feature frames are handed over as raw bytes (at
//!   most one in-flight job per connection, so per-device frame order
//!   is preserved) and completions come back over a self-pipe-signalled
//!   [`ReadyQueue`];
//! * **subscriber delivery** is enqueue-only: sinks push encoded result
//!   frames into a bounded per-connection queue and the loop flushes it
//!   on write-readiness, so a slow subscriber drops its own oldest
//!   frames (`sink_dropped`) instead of stalling sibling subscribers or
//!   pinning a thread.
//!
//! Session deadline sweeps ride the poller's timer wheel; external stop
//! ([`ServerStop`]) and worker completions wake the loop via the
//! self-pipe, so stop latency is bounded by a poll wake, not a sleep
//! window. The wire protocol is untouched — byte-identical to the
//! thread-per-connection server this replaced.

use super::scheduler::{BatchConfig, BatchPlanner, LossPolicy};
use super::session::{
    DetectorSession, FeaturePayload, FrameResult, ResultSink, SessionConfig, SessionEvent,
    SessionRegistry,
};
use crate::cli::Args;
use crate::config::{IntegrationKind, ModelMeta, Paths};
use crate::metrics::Metrics;
use crate::model::DecodeParams;
use crate::net::poll::{Event, Interest, Poller, ReadyQueue, TimerWheel, WakeSignal, Waker};
use crate::net::{
    DgramAssembler, FrameAssembler, Msg, RawFrame, WireDetection, DEFAULT_SESSION, MAX_DGRAM,
};
use crate::runtime::{build_backend, BackendKind};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::time::Instant;
use crate::sync::{lock_or_recover, Arc, Mutex};
use crate::trace::TraceSink;
use crate::utils::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::os::unix::io::AsRawFd;
use std::time::Duration;

/// Poller token of the listening socket.
const TOKEN_LISTENER: usize = 0;
/// Timer-wheel token of the recurring session-deadline sweep.
const TIMER_SESSION_POLL: usize = 1;
/// Poller token of the UDP feature socket (`--udp`).
const TOKEN_UDP: usize = 2;
/// First token handed to an accepted connection.
const FIRST_CONN_TOKEN: usize = 3;
/// Max datagrams drained from the UDP socket per readiness round, so a
/// datagram flood cannot starve the TCP control plane (level-triggered
/// readiness re-reports the remainder immediately).
const UDP_RECV_BUDGET: usize = 256;
/// Period of the session-deadline sweep (parity with the 20 ms accept
/// poll the previous server used).
const DEADLINE_POLL: Duration = Duration::from_millis(20);
/// Timer-wheel resolution.
const WHEEL_TICK: Duration = Duration::from_millis(5);
/// Timer-wheel buckets.
const WHEEL_SLOTS: usize = 64;
/// Max bytes read from one connection per readiness round, so one
/// firehose connection cannot starve its siblings (level-triggered
/// readiness re-reports the remainder immediately).
const READ_BUDGET: usize = 1 << 20;
/// On stop, keep flushing subscriber queues for at most this long.
const SHUTDOWN_FLUSH: Duration = Duration::from_millis(500);
/// Default bound on a subscriber's undelivered-result queue (frames).
const DEFAULT_SINK_QUEUE: usize = 256;

/// Server configuration. The top-level fields describe the `"default"`
/// session; `extra_sessions` adds more, each with its own
/// [`SessionConfig`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// TCP port to listen on.
    pub port: u16,
    /// Integration method of the default session.
    pub variant: IntegrationKind,
    /// Frame-sync deadline of the default session.
    pub deadline: Duration,
    /// Incomplete-frame policy of the default session.
    pub policy: LossPolicy,
    /// Decode parameters for the default session (satellite fix: the old
    /// server silently post-processed with `DecodeParams::default()`).
    pub decode: DecodeParams,
    /// Stop after this many frames across all sessions (None = run until
    /// Ctrl-C).
    pub max_frames: Option<u64>,
    /// Additional named sessions hosted alongside the default one.
    pub extra_sessions: Vec<(String, SessionConfig)>,
    /// Execution backend for every hosted session.
    pub backend: BackendKind,
    /// Engine-pool threads (`--backend-threads`): how many tails can
    /// execute concurrently on the XLA backend.
    pub backend_threads: usize,
    /// Cross-session micro-batching of tail executions
    /// (`--max-batch` / `--batch-window-ms`). `max_batch <= 1` (the
    /// default) keeps the per-frame path byte-identical to the unbatched
    /// server.
    pub batch: BatchConfig,
    /// Tee every received intermediate output (with its arrival stamp)
    /// into a replayable capture file (`--trace`); `None` = no capture.
    /// See [`crate::trace`].
    pub trace: Option<std::path::PathBuf>,
    /// Decode/dispatch worker threads behind the event loop
    /// (`--workers`); 0 = one per core, capped like
    /// [`ThreadPool::default_size`].
    pub workers: usize,
    /// Bound on each subscriber's undelivered-result queue, in frames
    /// (`--sink-queue`). When a slow subscriber lets it fill, its oldest
    /// queued frame is dropped and `sink_dropped` incremented.
    pub sink_queue: usize,
    /// Also bind a UDP socket on `port` for the datagram feature uplink
    /// (`--udp`): feature frames arrive as chunked datagrams with
    /// latest-wins reassembly and optional XOR-parity FEC (see
    /// `docs/WIRE_PROTOCOL.md`, "Datagram transport"), while the
    /// control plane (`Hello`/`Subscribe`/`Bye`/`Result`) stays TCP.
    /// Every hosted session runs its `FrameSync` in latest-wins mode so
    /// a stale completion is counted and dropped, never integrated.
    pub udp: bool,
    /// Split depth of the default session (`--split`): one of
    /// [`crate::config::SPLIT_DEPTHS`], or empty for the default depth.
    /// Extra sessions pick their own via `--sessions name=variant@split`.
    pub split: String,
    /// Overload shedding watermark (`--shed-watermark`) inherited by
    /// every hosted session: when the shared batch planner's queue
    /// reaches this many pending tail requests, sessions degrade frames
    /// through their cheaper shed tail instead of rejecting them. 0
    /// (default) disables shedding. Only meaningful with `--max-batch`
    /// > 1 — without a planner there is no queue to watermark.
    pub shed_watermark: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7321,
            variant: IntegrationKind::ConvK3,
            deadline: Duration::from_millis(200),
            policy: LossPolicy::ZeroFill,
            decode: DecodeParams::default(),
            max_frames: None,
            extra_sessions: Vec::new(),
            backend: BackendKind::default_kind(),
            backend_threads: 1,
            batch: BatchConfig::default(),
            trace: None,
            workers: 0,
            sink_queue: DEFAULT_SINK_QUEUE,
            udp: false,
            split: String::new(),
            shed_watermark: 0,
        }
    }
}

impl ServerConfig {
    /// Every session this server hosts: the default one first, then the
    /// extras. Duplicate names are a configuration error — the registry
    /// would silently keep only the last one.
    pub fn session_specs(&self) -> Result<Vec<(String, SessionConfig)>> {
        let mut specs = vec![(
            DEFAULT_SESSION.to_string(),
            SessionConfig::new(self.variant)
                .deadline(self.deadline)
                .policy(self.policy)
                .decode(self.decode.clone())
                .split(&self.split)
                .shed_watermark(self.shed_watermark),
        )];
        specs.extend(self.extra_sessions.iter().cloned());
        if self.udp {
            // Datagram reassembly already enforces latest-wins per
            // device stream; the session-level gate closes the race
            // where a stale completion is dispatched concurrently with
            // a newer one.
            specs = specs.into_iter().map(|(n, sc)| (n, sc.latest_wins(true))).collect();
        }
        let mut seen = std::collections::BTreeSet::new();
        for (name, _) in &specs {
            anyhow::ensure!(
                seen.insert(name.clone()),
                "duplicate session name {name:?} (the default session is named {DEFAULT_SESSION:?})"
            );
        }
        Ok(specs)
    }
}

/// External stop handle for [`run_server_until`]: set-flag-then-wake.
///
/// The event loop installs its [`Waker`] here at startup and re-checks
/// the flag afterwards, so a `stop()` racing startup can miss the waker
/// but never the flag — the no-lost-wakeup discipline the loom model in
/// `tests/loom.rs` verifies for the ready-queue handoff applies here
/// identically.
pub struct ServerStop {
    flag: AtomicBool,
    waker: Mutex<Option<Waker>>,
}

impl ServerStop {
    /// A fresh, unset stop handle.
    pub fn new() -> Arc<ServerStop> {
        Arc::new(ServerStop { flag: AtomicBool::new(false), waker: Mutex::new(None) })
    }

    /// Ask the server to stop. Latency is bounded by one poll wake (the
    /// self-pipe), not by an accept-poll or read-timeout window.
    pub fn stop(&self) {
        self.flag.store(true, Ordering::SeqCst);
        if let Some(w) = lock_or_recover(&self.waker).as_ref() {
            w.wake();
        }
    }

    /// Whether [`stop`](ServerStop::stop) has been called (or the server
    /// tripped its own `max_frames` budget).
    pub fn is_set(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Install the loop's waker. The loop re-checks
    /// [`ServerStop::is_set`] right after arming: a racing `stop()` may
    /// have found the slot empty, but its flag store already happened.
    fn arm(&self, w: Waker) {
        *lock_or_recover(&self.waker) = Some(w);
    }
}

/// What [`run_server_until`] returns once the server exits.
pub struct ServerRun {
    /// The hosted sessions — inspect per-session metrics and sync stats.
    pub registry: Arc<SessionRegistry>,
    /// Server-wide connection accounting (`conn_accepted`, `conn_active`,
    /// `conn_peak`, `conn_closed`).
    pub server_metrics: Arc<Metrics>,
    /// The shared [`BatchPlanner`]'s metrics when `--max-batch` > 1
    /// (batch_backend_calls / batch_frames / batch_occupancy — the
    /// backend-call occupancy numbers `BENCH_scale.json` reports).
    pub planner_metrics: Option<Arc<Metrics>>,
}

/// Bounded queue of encoded result frames awaiting one subscriber
/// connection. Producers are session delivery threads (via [`TcpSink`]),
/// the sole consumer is the event loop flushing on write-readiness.
/// Overflow drops the **oldest** undelivered frame — except a frame
/// already partially on the wire, which can never be dropped (that
/// would tear the byte stream); if that half-sent frame is the only
/// queued one, the incoming frame is dropped instead.
struct SubscriberQueue {
    cap: usize,
    state: Mutex<SinkQueueState>,
}

struct SinkQueueState {
    frames: VecDeque<Vec<u8>>,
    /// Bytes of `frames[0]` already written to the socket.
    head_written: usize,
    closed: bool,
}

/// What a flush attempt left behind.
enum FlushOutcome {
    /// Queue empty; drop write interest.
    Idle,
    /// Socket buffer full mid-queue; poll for write-readiness.
    Blocked,
    /// Peer closed the connection.
    Closed,
    /// Write error; the stream can no longer be trusted.
    Failed(std::io::Error),
}

impl SubscriberQueue {
    fn new(cap: usize) -> SubscriberQueue {
        SubscriberQueue {
            cap: cap.max(1),
            state: Mutex::new(SinkQueueState {
                frames: VecDeque::new(),
                head_written: 0,
                closed: false,
            }),
        }
    }

    /// Enqueue one encoded frame without ever blocking; returns how many
    /// frames overflow dropped to make room (0 normally). An `Err` means
    /// the subscriber is gone (closed or poisoned) and the sink must
    /// detach.
    fn push(&self, frame: Vec<u8>) -> Result<u64> {
        // Never `unwrap()` this lock: it is shared by every session the
        // connection subscribed, and a panic inside one delivery must
        // not cascade into every later one. A poisoned queue means a
        // holder died mid-operation; the conservative move is to detach
        // (the loop closes the connection when its flush next runs).
        let mut st = match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let mut st = poisoned.into_inner();
                st.closed = true;
                log::warn!("subscriber queue poisoned by an earlier panic; detaching sink");
                anyhow::bail!("subscriber queue poisoned; sink detached");
            }
        };
        if st.closed {
            anyhow::bail!("subscriber connection closed; sink detached");
        }
        let mut dropped = 0u64;
        while st.frames.len() >= self.cap {
            // Index 0 unless the head frame is partially written — a
            // torn frame would desync the subscriber's whole stream.
            let evict = usize::from(st.head_written > 0);
            if evict >= st.frames.len() {
                // Only the half-sent head remains (cap 1): drop the
                // incoming frame instead.
                return Ok(dropped + 1);
            }
            st.frames.remove(evict);
            dropped += 1;
        }
        st.frames.push_back(frame);
        Ok(dropped)
    }

    /// Write queued frames to `stream` until empty or `WouldBlock`.
    /// Called only from the event loop (single consumer); the lock is
    /// held across the nonblocking writes, which cannot stall.
    fn flush_to(&self, stream: &TcpStream) -> FlushOutcome {
        let mut st = lock_or_recover(&self.state);
        if st.closed {
            return FlushOutcome::Closed;
        }
        loop {
            let off = st.head_written;
            let wrote = match st.frames.front() {
                None => return FlushOutcome::Idle,
                Some(front) => {
                    let mut w = stream;
                    w.write(&front[off..])
                }
            };
            match wrote {
                Ok(0) => return FlushOutcome::Closed,
                Ok(n) => {
                    st.head_written += n;
                    if st.head_written == st.frames[0].len() {
                        st.frames.pop_front();
                        st.head_written = 0;
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    return FlushOutcome::Blocked
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return FlushOutcome::Failed(e),
            }
        }
    }

    /// Frames still awaiting delivery.
    fn pending(&self) -> usize {
        lock_or_recover(&self.state).frames.len()
    }

    /// Refuse all future pushes (the connection is gone); queued frames
    /// are discarded.
    fn close(&self) {
        let mut st = lock_or_recover(&self.state);
        st.closed = true;
        st.frames.clear();
        st.head_written = 0;
    }
}

/// Forwards completed frames to one subscriber connection — enqueue
/// only, never a socket write: delivery cost on the session thread is
/// one encode + one bounded queue push, so a stalled subscriber cannot
/// delay sibling subscribers or pin the delivering thread. One
/// connection subscribed to several sessions shares one queue, so
/// frames from concurrent sessions interleave whole, never torn.
struct TcpSink {
    queue: Arc<SubscriberQueue>,
    /// Wakes the event loop to flush after each enqueue.
    completions: Arc<ReadyQueue<Completion>>,
    token: usize,
    /// Session metrics for `sink_dropped` accounting.
    metrics: Arc<Metrics>,
}

impl ResultSink for TcpSink {
    fn deliver(&mut self, _session: &str, result: &FrameResult) -> Result<()> {
        let detections: Vec<WireDetection> = result
            .detections
            .iter()
            .map(|d| WireDetection {
                bbox: d.bbox.to_array(),
                score: d.score,
                class_id: d.class_id as u32,
            })
            .collect();
        let frame = crate::net::encode_frame(&Msg::Result {
            frame_id: result.frame_id,
            detections,
            server_micros: (result.tail_secs * 1e6) as u64,
            capture_micros: result.capture_micros,
        })?;
        let dropped = self.queue.push(frame)?; // Err ⇒ session detaches this sink
        if dropped > 0 {
            self.metrics.incr("sink_dropped", dropped);
            log::debug!("slow subscriber: dropped {dropped} oldest queued result frame(s)");
        }
        self.completions.push(Completion::SinkReady { token: self.token });
        Ok(())
    }
}

struct Shared {
    registry: Arc<SessionRegistry>,
    /// Shutdown handle: tripped internally when `max_frames` is reached,
    /// or externally by the holder of the [`run_server_until`] handle.
    stop: Arc<ServerStop>,
    frames_out: AtomicU64,
    max_frames: Option<u64>,
    /// Capture tee (`--trace`): every received intermediate output is
    /// appended here (byte-identical framed form) before being routed to
    /// its session.
    trace: Option<Mutex<TraceSink>>,
}

impl Shared {
    /// Count completed frames toward the shutdown budget.
    fn note_events(&self, events: &[SessionEvent]) {
        let n = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::Result(_)))
            .count() as u64;
        if n == 0 {
            return;
        }
        let done = self.frames_out.fetch_add(n, Ordering::SeqCst) + n;
        if let Some(max) = self.max_frames {
            if done >= max {
                // stop() wakes the event loop, so a budget reached on a
                // worker thread stops the server within one poll wake.
                self.stop.stop();
            }
        }
    }

    fn poll_sessions(&self) {
        for (_, events) in self.registry.poll_all() {
            self.note_events(&events);
        }
    }
}

/// Worker → event-loop notifications, carried by a [`ReadyQueue`] whose
/// signal is the poller's self-pipe.
enum Completion {
    /// A per-connection decode/dispatch job finished.
    Dispatched { token: usize, result: Result<()> },
    /// A sink enqueued result frames for this connection; flush it.
    SinkReady { token: usize },
    /// The recurring session-deadline sweep finished.
    SessionsPolled,
}

/// One connection's loop-owned state machine. Lifecycle:
/// accepted → streaming (assembler yields frames; control frames are
/// handled inline, feature frames batch into `inbox` and dispatch to the
/// worker pool one job at a time) → draining (`read_closed` after EOF or
/// `Bye`; retired once the in-flight job, inbox and sink queue are all
/// empty) → closed.
struct Conn {
    stream: TcpStream,
    assembler: FrameAssembler,
    /// Feature frames awaiting a worker slot.
    inbox: VecDeque<RawFrame>,
    /// A worker job for this connection is in flight (at most one, to
    /// preserve per-connection frame order).
    busy: bool,
    /// Result queue, created on the first `Subscribe`.
    sink: Option<Arc<SubscriberQueue>>,
    /// No more reads: EOF, `Bye`, or a read error.
    read_closed: bool,
    /// The last flush hit `WouldBlock`; poll for write-readiness.
    write_blocked: bool,
    peer: String,
}

/// Loop-owned state of the UDP feature socket (`--udp`). Mirrors the
/// per-connection state machine of [`Conn`] with the socket-specific
/// parts swapped out: datagrams reassemble through a [`DgramAssembler`]
/// (latest-wins, FEC) into byte-identical framed messages, which feed
/// the same [`FrameAssembler`] → inbox → worker-dispatch path as TCP.
struct UdpState {
    socket: UdpSocket,
    assembler: DgramAssembler,
    /// Decodes reassembled frames (each is one complete framed message,
    /// byte-identical to its TCP wire form).
    frames: FrameAssembler,
    /// Feature frames awaiting a worker slot.
    inbox: VecDeque<RawFrame>,
    /// A worker job for the UDP inbox is in flight (at most one, so
    /// frames dispatch in reassembly order).
    busy: bool,
}

struct EventLoop {
    poller: Poller,
    conns: HashMap<usize, Conn>,
    udp: Option<UdpState>,
    shared: Arc<Shared>,
    pool: ThreadPool,
    completions: Arc<ReadyQueue<Completion>>,
    next_token: usize,
    /// Worker jobs whose completion has not been observed yet.
    jobs_in_flight: usize,
    /// A session-deadline sweep is in flight (never stack a second).
    poll_job_in_flight: bool,
    server_metrics: Arc<Metrics>,
    conn_peak: u64,
    sink_queue: usize,
    draining: bool,
}

impl EventLoop {
    fn run(&mut self, listener: &TcpListener, stop: &ServerStop) -> Result<()> {
        let mut wheel = TimerWheel::new(WHEEL_TICK, WHEEL_SLOTS, Instant::now());
        wheel.schedule(DEADLINE_POLL, TIMER_SESSION_POLL);
        let mut events: Vec<Event> = Vec::new();
        let mut fired: Vec<usize> = Vec::new();
        let mut completed: Vec<Completion> = Vec::new();
        let mut drain_started: Option<Instant> = None;
        loop {
            if stop.is_set() && !self.draining {
                self.draining = true;
                drain_started = Some(Instant::now());
                self.poller.deregister(TOKEN_LISTENER);
            }
            if let Some(t0) = drain_started {
                let flushed = self
                    .conns
                    .values()
                    .all(|c| c.sink.as_ref().map_or(true, |q| q.pending() == 0));
                // In-flight jobs may still produce results; give queued
                // deliveries a bounded window to reach their subscribers
                // (the thread-per-conn server wrote them synchronously).
                if (self.jobs_in_flight == 0 && flushed) || t0.elapsed() > SHUTDOWN_FLUSH {
                    return Ok(());
                }
            }
            let timeout = if self.draining {
                Duration::from_millis(10)
            } else {
                wheel.next_timeout(Instant::now()).unwrap_or(DEADLINE_POLL)
            };
            self.poller.poll(Some(timeout), &mut events)?;

            // Timers first: the deadline sweep must not starve behind a
            // busy fd set.
            fired.clear();
            wheel.advance(Instant::now(), &mut fired);
            for &t in &fired {
                if t == TIMER_SESSION_POLL {
                    wheel.schedule(DEADLINE_POLL, TIMER_SESSION_POLL);
                    self.spawn_session_poll();
                }
            }

            // Worker completions (frees `busy` connections to dispatch
            // their next inbox batch, flushes freshly-fed sinks).
            completed.clear();
            self.completions.drain_into(&mut completed);
            for c in completed.drain(..) {
                self.on_completion(c);
            }

            // Socket readiness.
            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == TOKEN_LISTENER {
                    if !self.draining {
                        self.accept_ready(listener)?;
                    }
                } else if ev.token == TOKEN_UDP {
                    if !self.draining {
                        self.udp_ready();
                    }
                } else {
                    self.conn_event(ev);
                }
            }
        }
    }

    fn spawn_session_poll(&mut self) {
        if self.poll_job_in_flight || self.draining {
            return;
        }
        self.poll_job_in_flight = true;
        self.jobs_in_flight += 1;
        let shared = Arc::clone(&self.shared);
        let completions = Arc::clone(&self.completions);
        self.pool.execute(move || {
            let out =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| shared.poll_sessions()));
            if out.is_err() {
                log::warn!("session-deadline sweep panicked; continuing");
            }
            completions.push(Completion::SessionsPolled);
        });
    }

    fn on_completion(&mut self, c: Completion) {
        match c {
            Completion::SessionsPolled => {
                self.poll_job_in_flight = false;
                self.jobs_in_flight -= 1;
            }
            Completion::Dispatched { token, result } => {
                self.jobs_in_flight -= 1;
                if token == TOKEN_UDP {
                    if let Some(u) = self.udp.as_mut() {
                        u.busy = false;
                    }
                    // UDP has no connection to close on a dispatch
                    // error; the worker logs per frame and reports Ok.
                    if let Err(e) = result {
                        log::warn!("udp dispatch failed: {e:#}");
                    }
                    self.maybe_dispatch_udp();
                    return;
                }
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.busy = false;
                } else {
                    return; // connection closed while its job ran
                }
                match result {
                    Ok(()) => {
                        self.maybe_dispatch(token);
                        self.maybe_retire(token);
                    }
                    Err(e) => {
                        // Protocol violations (unknown session, device
                        // out of range, undecodable payload) close the
                        // connection — same contract as the blocking
                        // server's per-thread error path.
                        log::warn!("connection closed with error: {e:#}");
                        self.close_conn(token, "dispatch error");
                    }
                }
            }
            Completion::SinkReady { token } => self.flush_conn(token),
        }
    }

    fn accept_ready(&mut self, listener: &TcpListener) -> Result<()> {
        loop {
            match listener.accept() {
                Ok((stream, addr)) => {
                    if let Err(e) =
                        stream.set_nonblocking(true).and_then(|_| stream.set_nodelay(true))
                    {
                        log::warn!("connection from {addr} rejected at setup: {e}");
                        continue;
                    }
                    let token = self.next_token;
                    self.next_token += 1;
                    if let Err(e) =
                        self.poller.register(stream.as_raw_fd(), token, Interest::READ)
                    {
                        log::warn!("poller registration failed for {addr}: {e:#}");
                        continue;
                    }
                    log::debug!("connection from {addr} (token {token})");
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            assembler: FrameAssembler::new(),
                            inbox: VecDeque::new(),
                            busy: false,
                            sink: None,
                            read_closed: false,
                            write_blocked: false,
                            peer: addr.to_string(),
                        },
                    );
                    self.server_metrics.incr("conn_accepted", 1);
                    let active = self.conns.len() as u64;
                    self.server_metrics.set("conn_active", active);
                    if active > self.conn_peak {
                        self.conn_peak = active;
                        self.server_metrics.set("conn_peak", active);
                    }
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(ref e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::Interrupted | std::io::ErrorKind::ConnectionAborted
                    ) =>
                {
                    continue
                }
                Err(e) => return Err(e).context("accept"),
            }
        }
    }

    fn conn_event(&mut self, ev: Event) {
        if ev.writable {
            self.flush_conn(ev.token);
        }
        if ev.readable && !self.draining {
            self.read_ready(ev.token);
        }
        // Hangup with readable data still pending is handled by the read
        // path (it sees EOF after draining the buffer); a bare hangup
        // (or error) means the peer is gone now.
        if ev.hangup && !ev.readable && self.conns.contains_key(&ev.token) {
            self.close_conn(ev.token, "peer hung up");
        }
    }

    fn read_ready(&mut self, token: usize) {
        enum Outcome {
            Progress,
            Eof,
            Error(std::io::Error),
        }
        let outcome = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.read_closed {
                return;
            }
            let mut budget = READ_BUDGET;
            let mut buf = [0u8; 16 * 1024];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => break Outcome::Eof,
                    Ok(n) => {
                        conn.assembler.feed(&buf[..n]);
                        budget = budget.saturating_sub(n);
                        if budget == 0 {
                            break Outcome::Progress;
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        break Outcome::Progress
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => break Outcome::Error(e),
                }
            }
        };
        match outcome {
            Outcome::Error(e) => {
                log::debug!("connection read ended: {e}");
                self.close_conn(token, "read error");
            }
            Outcome::Eof => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.read_closed = true;
                }
                self.process_frames(token);
                self.update_interest(token);
                self.maybe_retire(token);
            }
            Outcome::Progress => self.process_frames(token),
        }
    }

    /// Pop complete frames off a connection's assembler: control frames
    /// are handled inline (they are a few bytes), feature frames batch
    /// into the inbox for the worker pool.
    fn process_frames(&mut self, token: usize) {
        enum Step {
            Control(RawFrame),
            Queued,
            Done,
            Desync(anyhow::Error),
        }
        loop {
            let step = {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                match conn.assembler.next_frame() {
                    Ok(Some(f)) if f.is_features() => {
                        conn.inbox.push_back(f);
                        Step::Queued
                    }
                    Ok(Some(f)) => Step::Control(f),
                    Ok(None) => Step::Done,
                    Err(e) => Step::Desync(e),
                }
            };
            match step {
                Step::Queued => continue,
                Step::Done => break,
                Step::Desync(e) => {
                    log::debug!("connection read ended: {e:#}");
                    self.close_conn(token, "protocol desync");
                    return;
                }
                Step::Control(f) => {
                    if let Err(e) = self.handle_control(token, &f) {
                        log::warn!("connection closed with error: {e:#}");
                        self.close_conn(token, "control error");
                        return;
                    }
                }
            }
        }
        self.maybe_dispatch(token);
    }

    fn handle_control(&mut self, token: usize, frame: &RawFrame) -> Result<()> {
        match frame.decode()? {
            Msg::Hello { device_id, session, split } => {
                // Unknown session: closing the connection is the only
                // signal the protocol can give the peer — silently
                // dropping its traffic would let a typoed `--session`
                // "succeed" while every frame is discarded.
                let Some(sess) = self.shared.registry.get(&session) else {
                    anyhow::bail!(
                        "device {device_id} greeted unknown session {session:?} (have {:?})",
                        self.shared.registry.names()
                    );
                };
                // Split mismatch closes the connection for the same
                // reason: a head cut at the wrong depth would ship
                // feature maps of the wrong channel count, and every
                // frame would be silently rejected at shape validation.
                // Legacy Hellos omit the field and land on the default
                // depth (`normalize_split("")`).
                let declared = crate::config::normalize_split(&split)
                    .with_context(|| format!("device {device_id} Hello"))?;
                anyhow::ensure!(
                    declared == sess.split(),
                    "device {device_id} declared split {declared:?} but session {session:?} \
                     serves {:?}",
                    sess.split()
                );
                log::info!(
                    "device {device_id} connected to session {session:?} (split {declared:?})"
                );
            }
            Msg::Subscribe { session } => match self.shared.registry.get(&session) {
                Some(s) => {
                    let queue = {
                        let Some(conn) = self.conns.get_mut(&token) else { return Ok(()) };
                        // One queue per connection, shared by every
                        // session it subscribes, so concurrent sessions
                        // cannot interleave frames on the socket.
                        Arc::clone(
                            conn.sink
                                .get_or_insert_with(|| {
                                    Arc::new(SubscriberQueue::new(self.sink_queue))
                                }),
                        )
                    };
                    s.attach_sink(Box::new(TcpSink {
                        queue,
                        completions: Arc::clone(&self.completions),
                        token,
                        metrics: s.metrics(),
                    }));
                    log::info!("result subscriber attached to session {session:?}");
                }
                None => anyhow::bail!(
                    "subscribe to unknown session {session:?} (have {:?})",
                    self.shared.registry.names()
                ),
            },
            Msg::Bye => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.read_closed = true;
                }
                self.update_interest(token);
                self.maybe_retire(token);
            }
            Msg::Result { .. } => log::warn!("unexpected Result from client"),
            // Feature frames are routed to the inbox before decode.
            Msg::Features { .. } | Msg::FeaturesQ { .. } => {
                log::warn!("feature frame (type {}) reached the control path", frame.ty);
            }
        }
        Ok(())
    }

    /// Hand the connection's queued feature frames to the worker pool —
    /// at most one job per connection at a time, so frames dispatch in
    /// arrival order.
    fn maybe_dispatch(&mut self, token: usize) {
        let batch: Vec<RawFrame> = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.busy || conn.inbox.is_empty() {
                return;
            }
            conn.busy = true;
            conn.inbox.drain(..).collect()
        };
        self.jobs_in_flight += 1;
        let shared = Arc::clone(&self.shared);
        let completions = Arc::clone(&self.completions);
        self.pool.execute(move || {
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    dispatch_frames(&shared, &batch)
                }))
                .unwrap_or_else(|_| Err(anyhow::anyhow!("dispatch job panicked")));
            completions.push(Completion::Dispatched { token, result });
        });
    }

    /// Drain the UDP feature socket: parse datagrams through the
    /// latest-wins assembler, hand every completed (or FEC-recovered)
    /// frame to the framed-message decoder, and queue feature frames
    /// for worker dispatch. Malformed or stale datagrams are counted
    /// and dropped — never a panic, never an integration.
    fn udp_ready(&mut self) {
        let Some(u) = self.udp.as_mut() else { return };
        let mut buf = [0u8; MAX_DGRAM + 64];
        for _ in 0..UDP_RECV_BUDGET {
            let n = match u.socket.recv_from(&mut buf) {
                Ok((n, _)) => n,
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient ICMP-induced errors (ECONNREFUSED after
                    // a device exits) must not kill the uplink.
                    log::debug!("udp recv error (ignored): {e}");
                    continue;
                }
            };
            let Some(done) = u.assembler.feed(&buf[..n]) else { continue };
            u.frames.feed(&done.frame);
            loop {
                match u.frames.next_frame() {
                    Ok(Some(f)) if f.is_features() => u.inbox.push_back(f),
                    Ok(Some(f)) => {
                        log::warn!("non-feature frame (type {}) over the datagram uplink", f.ty)
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // A reassembled frame is byte-identical to its
                        // TCP form, so a desync means a corrupt sender;
                        // reset the decoder rather than wedge the path.
                        log::warn!("udp frame decode desync (decoder reset): {e:#}");
                        u.frames = FrameAssembler::new();
                        break;
                    }
                }
            }
        }
        let st = u.assembler.stats();
        self.server_metrics.set("dgram_rx", st.rx);
        self.server_metrics.set("dgram_stale_dropped", st.stale_dropped);
        self.server_metrics.set("fec_recovered", st.fec_recovered);
        self.server_metrics.set("dgram_dup", st.dup);
        self.server_metrics.set("dgram_malformed", st.malformed);
        self.maybe_dispatch_udp();
    }

    /// Hand queued UDP feature frames to the worker pool — at most one
    /// job at a time, so frames dispatch in reassembly order. Unlike
    /// the TCP path, per-frame errors are logged and skipped: one bad
    /// datagram sender must not discard siblings' queued frames.
    fn maybe_dispatch_udp(&mut self) {
        let batch: Vec<RawFrame> = {
            let Some(u) = self.udp.as_mut() else { return };
            if u.busy || u.inbox.is_empty() {
                return;
            }
            u.busy = true;
            u.inbox.drain(..).collect()
        };
        self.jobs_in_flight += 1;
        let shared = Arc::clone(&self.shared);
        let completions = Arc::clone(&self.completions);
        self.pool.execute(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                for frame in &batch {
                    if let Err(e) = dispatch_frames(&shared, std::slice::from_ref(frame)) {
                        log::warn!("udp feature frame dropped: {e:#}");
                    }
                }
            }))
            .map_err(|_| anyhow::anyhow!("udp dispatch job panicked"));
            completions.push(Completion::Dispatched { token: TOKEN_UDP, result });
        });
    }

    fn flush_conn(&mut self, token: usize) {
        let outcome = {
            let Some(conn) = self.conns.get(&token) else { return };
            let Some(queue) = &conn.sink else { return };
            queue.flush_to(&conn.stream)
        };
        match outcome {
            FlushOutcome::Idle => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.write_blocked = false;
                }
                self.update_interest(token);
                self.maybe_retire(token);
            }
            FlushOutcome::Blocked => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.write_blocked = true;
                }
                self.update_interest(token);
            }
            FlushOutcome::Closed => self.close_conn(token, "subscriber closed"),
            FlushOutcome::Failed(e) => {
                // A torn frame may be on the socket; the connection is
                // closed so the subscriber sees EOF instead of blocking
                // forever on a partial frame.
                log::warn!("subscriber write failed, closing its stream: {e}");
                self.close_conn(token, "write error");
            }
        }
    }

    fn update_interest(&mut self, token: usize) {
        if let Some(conn) = self.conns.get(&token) {
            self.poller.set_interest(
                token,
                Interest { readable: !conn.read_closed, writable: conn.write_blocked },
            );
        }
    }

    /// Close a finished connection once nothing references it anymore:
    /// reads are done, no worker job is in flight, the inbox is empty
    /// and every queued result frame has been flushed.
    fn maybe_retire(&mut self, token: usize) {
        let retire = match self.conns.get(&token) {
            Some(c) => {
                c.read_closed
                    && !c.busy
                    && c.inbox.is_empty()
                    && c.sink.as_ref().map_or(true, |q| q.pending() == 0)
            }
            None => false,
        };
        if retire {
            self.close_conn(token, "peer finished");
        }
    }

    fn close_conn(&mut self, token: usize, why: &str) {
        if let Some(conn) = self.conns.remove(&token) {
            if let Some(q) = &conn.sink {
                q.close(); // future deliveries error ⇒ sessions detach the sink
            }
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
            self.poller.deregister(token);
            self.server_metrics.incr("conn_closed", 1);
            self.server_metrics.set("conn_active", self.conns.len() as u64);
            log::debug!("connection {} closed ({why})", conn.peer);
        }
    }
}

/// Worker-side half of the dispatch handoff: tee, decode and route one
/// connection's batch of feature frames. An `Err` closes the connection
/// (addressing/protocol violations must not look like success).
fn dispatch_frames(shared: &Shared, frames: &[RawFrame]) -> Result<()> {
    for frame in frames {
        // Capture tee: the framed bytes go in verbatim (byte-identical
        // to the wire), before decode so even a frame that fails decode
        // is captured. A tee failure degrades the capture, never the
        // serving path.
        if let Some(sink) = &shared.trace {
            let arrival = crate::utils::unix_micros();
            if let Err(e) = lock_or_recover(sink).record(arrival, &frame.framed_bytes()) {
                log::warn!("trace tee write failed: {e:#}");
            }
        }
        match frame.decode()? {
            Msg::Features { frame_id, device_id, tensor, session, capture_micros } => {
                submit(
                    shared,
                    &session,
                    frame_id,
                    device_id,
                    FeaturePayload::Raw(tensor),
                    capture_micros,
                )?;
            }
            Msg::FeaturesQ { frame_id, device_id, tensor, session, capture_micros } => {
                submit(
                    shared,
                    &session,
                    frame_id,
                    device_id,
                    FeaturePayload::Quantized(tensor),
                    capture_micros,
                )?;
            }
            _ => log::warn!("non-feature frame (type {}) on the dispatch path", frame.ty),
        }
    }
    Ok(())
}

/// Run the edge server until `max_frames` results have been produced
/// across all sessions. Returns the registry so callers can inspect
/// per-session metrics.
pub fn run_server(paths: &Paths, cfg: &ServerConfig) -> Result<Arc<SessionRegistry>> {
    Ok(run_server_until(paths, cfg, ServerStop::new())?.registry)
}

/// [`run_server`] with an external stop handle: the server also exits
/// when [`ServerStop::stop`] is called, within one poll wake (the stop
/// handle writes the event loop's self-pipe). The fleet scenario
/// harness uses this to stop a `max_frames: None` server once its
/// device fleet has drained and stragglers flushed.
pub fn run_server_until(
    paths: &Paths,
    cfg: &ServerConfig,
    stop: Arc<ServerStop>,
) -> Result<ServerRun> {
    let meta = ModelMeta::load(&paths.model_meta())?;
    let specs = cfg.session_specs()?;

    // One backend serves every session; preload each distinct tail at
    // its session's split depth — plus, for watermark-armed sessions,
    // the shed tail (Max variant, same depth) so the first shed frame
    // doesn't pay a model load. On the XLA backend this is a pool of
    // `backend_threads` engine threads, so different sessions' tails
    // execute concurrently.
    let mut tails: Vec<String> = Vec::new();
    for (_, sc) in &specs {
        let split = crate::config::normalize_split(&sc.split)?;
        let mut wanted = vec![meta.variant(sc.variant)?.tail_for(split)?];
        if sc.shed_watermark > 0 {
            if let Ok(vm) = meta.variant(IntegrationKind::Max) {
                wanted.push(vm.tail_for(split)?);
            }
        }
        for tail in wanted {
            if !tails.contains(&tail) {
                tails.push(tail);
            }
        }
    }
    let backend = build_backend(paths, &meta, cfg.backend, cfg.backend_threads, &tails)?;

    // Cross-session micro-batching: one planner shared by every session,
    // so compatible tail requests coalesce across sessions and frames
    // into stacked backend calls (`--max-batch`, `--batch-window-ms`).
    let planner = if cfg.batch.max_batch > 1 {
        Some(BatchPlanner::new(Arc::clone(&backend), cfg.batch))
    } else {
        None
    };

    let registry = Arc::new(SessionRegistry::new());
    for (name, sc) in specs {
        let mut session = DetectorSession::new(&name, meta.clone(), Arc::clone(&backend), sc)?;
        if let Some(planner) = &planner {
            session.set_batch_planner(Arc::clone(planner));
        }
        registry.insert(session);
    }
    let trace = match &cfg.trace {
        Some(path) => Some(Mutex::new(TraceSink::create(path)?)),
        None => None,
    };
    let shared = Arc::new(Shared {
        registry: Arc::clone(&registry),
        stop: Arc::clone(&stop),
        frames_out: AtomicU64::new(0),
        max_frames: cfg.max_frames,
        trace,
    });

    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("bind port {}", cfg.port))?;
    listener.set_nonblocking(true)?;
    log::info!(
        "edge server on 127.0.0.1:{} sessions={:?} devices={} backend={} threads={} \
         max-batch={} resident={:?}",
        cfg.port,
        registry.names(),
        meta.num_devices,
        backend.backend_name(),
        cfg.backend_threads,
        cfg.batch.max_batch,
        backend.loaded_names()
    );

    let (mut poller, waker) = Poller::new()?;
    let completions: Arc<ReadyQueue<Completion>> =
        Arc::new(ReadyQueue::new(Arc::new(waker.clone()) as Arc<dyn WakeSignal>));
    // Arm-then-recheck: a stop() racing startup that misses the waker
    // still set the flag, which the loop's first iteration observes.
    stop.arm(waker);
    poller.register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
    let udp = if cfg.udp {
        let socket = UdpSocket::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("bind udp port {}", cfg.port))?;
        socket.set_nonblocking(true)?;
        poller.register(socket.as_raw_fd(), TOKEN_UDP, Interest::READ)?;
        log::info!("datagram feature uplink on udp 127.0.0.1:{}", cfg.port);
        Some(UdpState {
            socket,
            assembler: DgramAssembler::new(),
            frames: FrameAssembler::new(),
            inbox: VecDeque::new(),
            busy: false,
        })
    } else {
        None
    };

    let workers = if cfg.workers > 0 { cfg.workers } else { ThreadPool::default_size() };
    let server_metrics = Arc::new(Metrics::new());
    let mut lp = EventLoop {
        poller,
        conns: HashMap::new(),
        udp,
        shared: Arc::clone(&shared),
        pool: ThreadPool::new(workers),
        completions,
        next_token: FIRST_CONN_TOKEN,
        jobs_in_flight: 0,
        poll_job_in_flight: false,
        server_metrics: Arc::clone(&server_metrics),
        conn_peak: 0,
        sink_queue: cfg.sink_queue.max(1),
        draining: false,
    };
    let run_result = lp.run(&listener, &stop);
    let open: Vec<usize> = lp.conns.keys().copied().collect();
    for token in open {
        lp.close_conn(token, "server stopping");
    }
    // Dropping the loop joins the worker pool, so every in-flight
    // dispatch (and its trace tee) finishes before the capture flushes.
    drop(lp);
    run_result?;

    if let Some(sink) = &shared.trace {
        let mut sink = lock_or_recover(sink);
        sink.flush()?;
        log::info!("trace capture: {} records", sink.records());
    }
    if let Some(planner) = &planner {
        let m = planner.metrics();
        log::info!(
            "batch planner: {} backend calls for {} frames ({} rejected)",
            m.counter("batch_backend_calls"),
            m.counter("batch_frames"),
            m.counter("batch_rejected"),
        );
    }
    Ok(ServerRun {
        registry,
        server_metrics,
        planner_metrics: planner.as_ref().map(|p| p.metrics()),
    })
}

/// Route one intermediate output into its session; dequantization and
/// post-processing happen inside the session core. An unknown session is
/// an error (closes the connection); a bad payload is logged and
/// tolerated so one corrupt frame doesn't kill a healthy device link.
fn submit(
    shared: &Shared,
    session: &str,
    frame_id: u64,
    device_id: u32,
    payload: FeaturePayload,
    capture_micros: u64,
) -> Result<()> {
    let Some(sess) = shared.registry.get(session) else {
        anyhow::bail!(
            "features for unknown session {session:?} (have {:?})",
            shared.registry.names()
        );
    };
    // Addressing errors close the connection (a misconfigured worker
    // must not look like it is succeeding); a corrupt payload is logged
    // and tolerated so one bad frame doesn't kill a healthy link.
    anyhow::ensure!(
        (device_id as usize) < sess.meta().num_devices,
        "device {device_id} out of range for session {session:?} ({} devices)",
        sess.meta().num_devices
    );
    if shared.trace.is_some() {
        sess.metrics().incr("trace_recorded", 1);
    }
    // submit() already resolves this session's expirations; other
    // sessions are swept by the timer wheel every 20 ms. Polling them
    // here too would make this worker run (and block on) other sessions'
    // work — breaking per-session isolation.
    match sess.submit_at(frame_id, device_id as usize, payload, capture_micros) {
        Ok(events) => shared.note_events(&events),
        Err(e) => log::warn!("submit to session {session:?} failed: {e:#}"),
    }
    Ok(())
}

/// Parse `--sessions name=variant[@split][:deadline_ms],...` into extra
/// session configs; unset knobs (policy, decode, shed watermark,
/// deadline, split) inherit the default session's.
pub fn parse_session_specs(
    spec: &str,
    base: &ServerConfig,
) -> Result<Vec<(String, SessionConfig)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let (name, rest) = part.split_once('=').with_context(|| {
            format!("session spec {part:?} must be name=variant[@split][:deadline_ms]")
        })?;
        anyhow::ensure!(!name.is_empty(), "empty session name in {part:?}");
        let (variant_split, deadline) = match rest.split_once(':') {
            Some((v, ms)) => {
                let ms: u64 = ms
                    .parse()
                    .with_context(|| format!("bad deadline {ms:?} in session spec {part:?}"))?;
                (v, Duration::from_millis(ms))
            }
            None => (rest, base.deadline),
        };
        let (variant, split) = match variant_split.split_once('@') {
            Some((v, s)) => {
                // Validate eagerly so a typoed depth fails at flag-parse
                // time, not at session build.
                let split = crate::config::normalize_split(s)
                    .with_context(|| format!("bad split in session spec {part:?}"))?;
                (IntegrationKind::parse(v)?, split.to_string())
            }
            None => (IntegrationKind::parse(variant_split)?, base.split.clone()),
        };
        out.push((
            name.to_string(),
            SessionConfig::new(variant)
                .deadline(deadline)
                .policy(base.policy)
                .decode(base.decode.clone())
                .split(&split)
                .shed_watermark(base.shed_watermark),
        ));
    }
    Ok(out)
}

/// Build the server configuration from `scmii serve` flags (separated
/// from `cmd_serve` so flag wiring is unit-testable).
pub fn server_config_from_args(args: &Args) -> Result<ServerConfig> {
    args.check_known(&[
        "artifacts",
        "port",
        "variant",
        "deadline-ms",
        "policy",
        "max-frames",
        "score-thresh",
        "nms-iou",
        "sessions",
        "backend",
        "backend-threads",
        "max-batch",
        "batch-window-ms",
        "trace",
        "workers",
        "sink-queue",
        "udp",
        "split",
        "shed-watermark",
    ])?;
    let mut cfg = ServerConfig::default();
    cfg.port = args.usize_or("port", cfg.port as usize)? as u16;
    cfg.variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    cfg.deadline = Duration::from_millis(args.u64_or("deadline-ms", 200)?);
    // One spelling authority: str_one_of rejects typos with the flag
    // name, LossPolicy::parse owns the string → variant mapping.
    cfg.policy =
        LossPolicy::parse(&args.str_one_of("policy", &["zero-fill", "drop"], "zero-fill")?)?;
    // Same flags, same defaults as the in-process pipeline — one parser.
    let be = super::pipeline::PipelineBackend::from_args(args)?;
    cfg.backend = be.kind;
    cfg.backend_threads = be.threads;
    cfg.decode.score_threshold = args.f32_or("score-thresh", cfg.decode.score_threshold)?;
    cfg.decode.nms_iou = args.f64_or("nms-iou", cfg.decode.nms_iou)?;
    cfg.batch.max_batch = args.usize_or("max-batch", cfg.batch.max_batch)?;
    cfg.batch.window = args.ms_or("batch-window-ms", cfg.batch.window.as_millis() as u64)?;
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.sink_queue = args.usize_or("sink-queue", cfg.sink_queue)?;
    cfg.udp = args.switch("udp");
    cfg.split = args.str_or("split", "");
    // Validate the depth at flag-parse time (empty = default depth).
    crate::config::normalize_split(&cfg.split)?;
    cfg.shed_watermark = args.usize_or("shed-watermark", 0)?;
    let max = args.u64_or("max-frames", 0)?;
    cfg.max_frames = if max > 0 { Some(max) } else { None };
    cfg.trace = args.str_opt("trace").map(std::path::PathBuf::from);
    if let Some(spec) = args.str_opt("sessions") {
        cfg.extra_sessions = parse_session_specs(spec, &cfg)?;
    }
    Ok(cfg)
}

/// `scmii serve` CLI entry.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let paths = Paths::new(&args.str_or("artifacts", "artifacts"), "data");
    let cfg = server_config_from_args(args)?;
    let registry = run_server(&paths, &cfg)?;
    for name in registry.names() {
        if let Some(s) = registry.get(&name) {
            println!("--- session {name} ---");
            print!("{}", s.metrics().report());
        }
    }
    Ok(())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn serve_flags_thread_decode_params() {
        let cfg = server_config_from_args(&args(&[
            "--score-thresh",
            "0.4",
            "--nms-iou",
            "0.6",
            "--deadline-ms",
            "150",
            "--policy",
            "drop",
        ]))
        .unwrap();
        assert!((cfg.decode.score_threshold - 0.4).abs() < 1e-6);
        assert!((cfg.decode.nms_iou - 0.6).abs() < 1e-9);
        assert_eq!(cfg.deadline, Duration::from_millis(150));
        assert_eq!(cfg.policy, LossPolicy::Drop);
        // ... and the session spec inherits them.
        let specs = cfg.session_specs().unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].0, DEFAULT_SESSION);
        assert!((specs[0].1.decode.score_threshold - 0.4).abs() < 1e-6);
        assert_eq!(specs[0].1.policy, LossPolicy::Drop);
    }

    #[test]
    fn serve_flags_default_decode_unchanged() {
        let cfg = server_config_from_args(&args(&[])).unwrap();
        let d = DecodeParams::default();
        assert!((cfg.decode.score_threshold - d.score_threshold).abs() < 1e-9);
        assert!((cfg.decode.nms_iou - d.nms_iou).abs() < 1e-12);
    }

    #[test]
    fn unknown_serve_flag_rejected() {
        assert!(server_config_from_args(&args(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn serve_backend_flags_parse() {
        let cfg = server_config_from_args(&args(&[
            "--backend",
            "native",
            "--backend-threads",
            "4",
        ]))
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::Native);
        assert_eq!(cfg.backend_threads, 4);
        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.backend, BackendKind::default_kind());
        assert_eq!(d.backend_threads, 1);
        assert!(server_config_from_args(&args(&["--backend", "gpu"])).is_err());
        // Satellite regression: a typoed policy used to silently mean
        // zero-fill; it must now be rejected.
        assert!(server_config_from_args(&args(&["--policy", "bogus"])).is_err());
    }

    #[test]
    fn serve_batch_flags_parse() {
        let cfg = server_config_from_args(&args(&[
            "--max-batch",
            "8",
            "--batch-window-ms",
            "5",
        ]))
        .unwrap();
        assert_eq!(cfg.batch.max_batch, 8);
        assert_eq!(cfg.batch.window, Duration::from_millis(5));
        // Defaults keep batching off — the per-frame path is untouched.
        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.batch.max_batch, 1);
        assert!(server_config_from_args(&args(&["--max-batch", "lots"])).is_err());
    }

    #[test]
    fn serve_udp_flag_threads_latest_wins_into_sessions() {
        let cfg = server_config_from_args(&args(&["--udp"])).unwrap();
        assert!(cfg.udp);
        let specs = cfg.session_specs().unwrap();
        assert!(specs.iter().all(|(_, sc)| sc.latest_wins), "udp mode gates FrameSync");

        let d = server_config_from_args(&args(&[])).unwrap();
        assert!(!d.udp, "datagram uplink is opt-in");
        let specs = d.session_specs().unwrap();
        assert!(
            specs.iter().all(|(_, sc)| !sc.latest_wins),
            "TCP-only servers keep the seed FrameSync behavior"
        );
    }

    #[test]
    fn serve_trace_flag_parses() {
        let cfg = server_config_from_args(&args(&["--trace", "/tmp/cap.scmt"])).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("/tmp/cap.scmt")));
        let d = server_config_from_args(&args(&[])).unwrap();
        assert!(d.trace.is_none(), "capture is opt-in");
    }

    #[test]
    fn serve_event_loop_flags_parse() {
        let cfg =
            server_config_from_args(&args(&["--workers", "3", "--sink-queue", "16"])).unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.sink_queue, 16);
        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.workers, 0, "0 = auto-size the pool");
        assert_eq!(d.sink_queue, DEFAULT_SINK_QUEUE);
        assert!(server_config_from_args(&args(&["--workers", "many"])).is_err());
    }

    /// A no-op signal for sink tests that never touch a poller.
    struct NullSignal;
    impl WakeSignal for NullSignal {
        fn wake(&self) {}
    }

    fn test_sink(queue: Arc<SubscriberQueue>, metrics: Arc<Metrics>) -> TcpSink {
        TcpSink {
            queue,
            completions: Arc::new(ReadyQueue::new(Arc::new(NullSignal))),
            token: 99,
            metrics,
        }
    }

    fn frame_result(frame_id: u64) -> FrameResult {
        FrameResult {
            frame_id,
            detections: Vec::new(),
            present: vec![true, true],
            tail_secs: 0.0,
            post_secs: 0.0,
            sync_wait_secs: 0.0,
            capture_micros: 0,
            tail_error: false,
        }
    }

    #[test]
    fn poisoned_subscriber_queue_detaches_instead_of_panicking() {
        // Regression carried over from the blocking server's shared-
        // stream mutex: poison the queue the way a panicking holder
        // would, then deliver — the sink must return an error (detach),
        // not unwind.
        let queue = Arc::new(SubscriberQueue::new(4));
        let poisoner = Arc::clone(&queue);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.state.lock().unwrap();
            panic!("holder dies mid-operation");
        })
        .join();
        assert!(queue.state.lock().is_err(), "mutex must be poisoned for the test to bite");

        let mut sink = test_sink(queue, Arc::new(Metrics::new()));
        let out = sink.deliver("default", &frame_result(1));
        assert!(out.is_err(), "poisoned sink must detach via an error, not a panic");
    }

    #[test]
    fn subscriber_queue_drops_oldest_when_full() {
        let q = SubscriberQueue::new(3);
        for i in 0..5u8 {
            let dropped = q.push(vec![i]).unwrap();
            assert_eq!(dropped, u64::from(i >= 3), "cap 3: pushes 4 and 5 each evict one");
        }
        let st = q.state.lock().unwrap();
        let kept: Vec<u8> = st.frames.iter().map(|f| f[0]).collect();
        assert_eq!(kept, vec![2, 3, 4], "the *oldest* frames are the ones dropped");
    }

    #[test]
    fn subscriber_queue_never_drops_a_partially_written_frame() {
        let q = SubscriberQueue::new(2);
        q.push(vec![10, 11]).unwrap();
        q.push(vec![20]).unwrap();
        // Simulate the loop having flushed one byte of the head frame.
        q.state.lock().unwrap().head_written = 1;
        q.push(vec![30]).unwrap();
        let st = q.state.lock().unwrap();
        let heads: Vec<u8> = st.frames.iter().map(|f| f[0]).collect();
        assert_eq!(heads, vec![10, 30], "evict index 1, never the half-sent head");
        drop(st);

        // Cap 1 with a half-sent head: the incoming frame is the drop.
        let q = SubscriberQueue::new(1);
        q.push(vec![1, 2, 3]).unwrap();
        q.state.lock().unwrap().head_written = 2;
        assert_eq!(q.push(vec![9]).unwrap(), 1, "drop-newest fallback still counts");
        assert_eq!(q.pending(), 1);
        assert_eq!(q.state.lock().unwrap().frames[0], vec![1, 2, 3]);
    }

    #[test]
    fn slow_subscriber_delivery_is_nonblocking_and_counted() {
        // Satellite regression: the blocking sink's write_all could
        // stall delivery ~5 s per frame on a wedged subscriber. The
        // queue-backed sink must absorb any number of deliveries with
        // nobody flushing, within the bound, without blocking.
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(SubscriberQueue::new(8));
        let mut sink = test_sink(Arc::clone(&queue), Arc::clone(&metrics));
        let t0 = std::time::Instant::now();
        for i in 0..100 {
            sink.deliver("default", &frame_result(i)).unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "100 deliveries into a wedged subscriber must not block"
        );
        assert_eq!(queue.pending(), 8, "bounded at the configured cap");
        assert_eq!(metrics.counter("sink_dropped"), 92, "every overflow is accounted");
    }

    #[test]
    fn closed_subscriber_queue_detaches_sink() {
        let queue = Arc::new(SubscriberQueue::new(4));
        let mut sink = test_sink(Arc::clone(&queue), Arc::new(Metrics::new()));
        sink.deliver("default", &frame_result(1)).unwrap();
        queue.close();
        assert!(
            sink.deliver("default", &frame_result(2)).is_err(),
            "delivery to a closed connection must error so the session detaches"
        );
        assert_eq!(queue.pending(), 0, "close discards undeliverable frames");
    }

    #[test]
    fn server_stop_is_idempotent_and_observable() {
        let stop = ServerStop::new();
        assert!(!stop.is_set());
        stop.stop();
        stop.stop(); // arming no waker, stopping twice: both fine
        assert!(stop.is_set());
    }

    #[cfg(feature = "native")]
    #[test]
    fn stop_wakes_the_event_loop_promptly() {
        // Satellite regression: stop used to be observed only within one
        // 20 ms accept-poll / 250 ms read-timeout window. With the
        // self-pipe the latency is one poll wake; assert well under the
        // old read-timeout bound, with margin for CI scheduling noise.
        let paths = Paths::new("/nonexistent-artifacts", "/nonexistent-data");
        let paths = crate::scenario::materialize_paths(&paths, "stop-latency-test").unwrap();
        let port = {
            let l = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let cfg = ServerConfig {
            port,
            backend: BackendKind::Native,
            ..ServerConfig::default()
        };
        let stop = ServerStop::new();
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || run_server_until(&paths, &cfg, stop2));
        // Wait for the listener, and hold an idle connection open so the
        // old per-connection read-timeout path would have been the bound.
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        let _idle_conn = loop {
            match std::net::TcpStream::connect(("127.0.0.1", port)) {
                Ok(s) => break s,
                Err(e) if std::time::Instant::now() < deadline => {
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => panic!("server never came up: {e}"),
            }
        };
        std::thread::sleep(Duration::from_millis(50)); // let the loop accept it
        let t0 = std::time::Instant::now();
        stop.stop();
        let run = server.join().expect("server thread panicked").unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "stop took {:?}; the self-pipe wake must beat the old 250 ms read timeout",
            t0.elapsed()
        );
        assert_eq!(run.server_metrics.counter("conn_accepted"), 1);
        assert_eq!(run.server_metrics.counter("conn_closed"), 1);
        assert_eq!(run.server_metrics.counter("conn_active"), 0);
    }

    #[test]
    fn session_spec_parsing() {
        let base = ServerConfig::default();
        let specs = parse_session_specs("north=max,south=conv_k1:150", &base).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].0, "north");
        assert_eq!(specs[0].1.variant, IntegrationKind::Max);
        assert_eq!(specs[0].1.deadline, base.deadline);
        assert_eq!(specs[1].0, "south");
        assert_eq!(specs[1].1.variant, IntegrationKind::ConvK1);
        assert_eq!(specs[1].1.deadline, Duration::from_millis(150));

        assert!(parse_session_specs("noequals", &base).is_err());
        assert!(parse_session_specs("x=notavariant", &base).is_err());
        assert!(parse_session_specs("x=max:notanumber", &base).is_err());
        assert!(parse_session_specs("=max", &base).is_err());
    }

    #[test]
    fn session_spec_split_parsing() {
        let base = ServerConfig::default();
        let specs =
            parse_session_specs("deep=max@split-deep:150,plain=conv_k1", &base).unwrap();
        assert_eq!(specs[0].1.split, "split-deep");
        assert_eq!(specs[0].1.variant, IntegrationKind::Max);
        assert_eq!(specs[0].1.deadline, Duration::from_millis(150));
        assert_eq!(specs[1].1.split, "", "unset split inherits the base (default depth)");

        // Extras inherit the base shed watermark and split.
        let mut base = ServerConfig::default();
        base.shed_watermark = 8;
        base.split = "split-shallow".to_string();
        let specs = parse_session_specs("a=max,b=conv_k3@split-mid", &base).unwrap();
        assert_eq!(specs[0].1.shed_watermark, 8);
        assert_eq!(specs[0].1.split, "split-shallow");
        assert_eq!(specs[1].1.split, "split-mid", "explicit split overrides the base");

        assert!(
            parse_session_specs("x=max@split-bogus", &ServerConfig::default()).is_err(),
            "typoed split must fail at flag-parse time"
        );
    }

    #[test]
    fn serve_split_and_shed_flags_parse() {
        let cfg = server_config_from_args(&args(&[
            "--split",
            "split-deep",
            "--shed-watermark",
            "16",
        ]))
        .unwrap();
        assert_eq!(cfg.split, "split-deep");
        assert_eq!(cfg.shed_watermark, 16);
        let specs = cfg.session_specs().unwrap();
        assert_eq!(specs[0].1.split, "split-deep", "default session carries the depth");
        assert_eq!(specs[0].1.shed_watermark, 16);

        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.split, "", "default depth, byte-identical to pre-split servers");
        assert_eq!(d.shed_watermark, 0, "shedding is opt-in");

        assert!(
            server_config_from_args(&args(&["--split", "split-bogus"])).is_err(),
            "unknown depth rejected at flag-parse time"
        );
    }

    #[test]
    fn server_config_lists_all_sessions() {
        let mut cfg = ServerConfig::default();
        cfg.extra_sessions =
            vec![("aux".to_string(), SessionConfig::new(IntegrationKind::Max))];
        let specs = cfg.session_specs().unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].0, DEFAULT_SESSION);
        assert_eq!(specs[1].0, "aux");
    }

    #[test]
    fn duplicate_session_names_rejected() {
        let mut cfg = ServerConfig::default();
        cfg.extra_sessions = vec![
            ("north".to_string(), SessionConfig::new(IntegrationKind::Max)),
            ("north".to_string(), SessionConfig::new(IntegrationKind::ConvK1)),
        ];
        assert!(cfg.session_specs().is_err(), "repeated extra name must fail");

        let mut cfg = ServerConfig::default();
        cfg.extra_sessions =
            vec![(DEFAULT_SESSION.to_string(), SessionConfig::new(IntegrationKind::Max))];
        assert!(cfg.session_specs().is_err(), "shadowing the default must fail");
    }
}
