//! The edge server, reduced to pure I/O: sockets in, [`Msg`]s decoded,
//! everything else delegated to the [`DetectorSession`] serving core.
//! One process hosts N named sessions (multiple intersections, A/B
//! integration variants) through a [`SessionRegistry`]; wire messages
//! address a session by name, with pre-session clients landing on
//! [`DEFAULT_SESSION`].

use super::scheduler::{BatchConfig, BatchPlanner, LossPolicy};
use super::session::{
    DetectorSession, FeaturePayload, FrameResult, ResultSink, SessionConfig, SessionEvent,
    SessionRegistry,
};
use crate::cli::Args;
use crate::config::{IntegrationKind, ModelMeta, Paths};
use crate::model::DecodeParams;
use crate::net::{write_msg, Msg, WireDetection, DEFAULT_SESSION};
use crate::runtime::{build_backend, BackendKind};
use anyhow::{Context, Result};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{lock_or_recover, thread, Arc, Mutex};
use crate::trace::TraceSink;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Server configuration. The top-level fields describe the `"default"`
/// session; `extra_sessions` adds more, each with its own
/// [`SessionConfig`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// TCP port to listen on.
    pub port: u16,
    /// Integration method of the default session.
    pub variant: IntegrationKind,
    /// Frame-sync deadline of the default session.
    pub deadline: Duration,
    /// Incomplete-frame policy of the default session.
    pub policy: LossPolicy,
    /// Decode parameters for the default session (satellite fix: the old
    /// server silently post-processed with `DecodeParams::default()`).
    pub decode: DecodeParams,
    /// Stop after this many frames across all sessions (None = run until
    /// Ctrl-C).
    pub max_frames: Option<u64>,
    /// Additional named sessions hosted alongside the default one.
    pub extra_sessions: Vec<(String, SessionConfig)>,
    /// Execution backend for every hosted session.
    pub backend: BackendKind,
    /// Engine-pool threads (`--backend-threads`): how many tails can
    /// execute concurrently on the XLA backend.
    pub backend_threads: usize,
    /// Cross-session micro-batching of tail executions
    /// (`--max-batch` / `--batch-window-ms`). `max_batch <= 1` (the
    /// default) keeps the per-frame path byte-identical to the unbatched
    /// server.
    pub batch: BatchConfig,
    /// Tee every received intermediate output (with its arrival stamp)
    /// into a replayable capture file (`--trace`); `None` = no capture.
    /// See [`crate::trace`].
    pub trace: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            port: 7321,
            variant: IntegrationKind::ConvK3,
            deadline: Duration::from_millis(200),
            policy: LossPolicy::ZeroFill,
            decode: DecodeParams::default(),
            max_frames: None,
            extra_sessions: Vec::new(),
            backend: BackendKind::default_kind(),
            backend_threads: 1,
            batch: BatchConfig::default(),
            trace: None,
        }
    }
}

impl ServerConfig {
    /// Every session this server hosts: the default one first, then the
    /// extras. Duplicate names are a configuration error — the registry
    /// would silently keep only the last one.
    pub fn session_specs(&self) -> Result<Vec<(String, SessionConfig)>> {
        let mut specs = vec![(
            DEFAULT_SESSION.to_string(),
            SessionConfig::new(self.variant)
                .deadline(self.deadline)
                .policy(self.policy)
                .decode(self.decode.clone()),
        )];
        specs.extend(self.extra_sessions.iter().cloned());
        let mut seen = std::collections::BTreeSet::new();
        for (name, _) in &specs {
            anyhow::ensure!(
                seen.insert(name.clone()),
                "duplicate session name {name:?} (the default session is named {DEFAULT_SESSION:?})"
            );
        }
        Ok(specs)
    }
}

/// Forwards completed frames to one subscriber connection. The stream is
/// shared behind a mutex so one connection subscribed to several
/// sessions gets whole frames, not interleaved writes from two sessions
/// delivering concurrently.
struct TcpSink {
    stream: Arc<Mutex<TcpStream>>,
}

impl ResultSink for TcpSink {
    fn deliver(&mut self, _session: &str, result: &FrameResult) -> Result<()> {
        let detections: Vec<WireDetection> = result
            .detections
            .iter()
            .map(|d| WireDetection {
                bbox: d.bbox.to_array(),
                score: d.score,
                class_id: d.class_id as u32,
            })
            .collect();
        // Never `unwrap()` this lock: the stream is shared by every sink
        // of one subscriber connection, and a panic while some other
        // deliver held it poisons the mutex. Propagating that panic from
        // here would take down the delivering connection thread (and,
        // before the session grew panic isolation, every later delivery
        // on the session). A poisoned stream means a writer died mid-
        // frame, so the bytes on it can't be trusted anyway — close it
        // and detach cleanly.
        let stream = match self.stream.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let stream = poisoned.into_inner();
                log::warn!("subscriber stream poisoned by an earlier panic; detaching sink");
                let _ = stream.shutdown(std::net::Shutdown::Both);
                anyhow::bail!("subscriber stream poisoned; sink detached");
            }
        };
        let mut writer = &*stream;
        let out = write_msg(
            &mut writer,
            &Msg::Result {
                frame_id: result.frame_id,
                detections,
                server_micros: (result.tail_secs * 1e6) as u64,
                capture_micros: result.capture_micros,
            },
        );
        if let Err(e) = &out {
            // A timed-out write may have left a torn frame on the socket;
            // the sink is about to be detached, so close the stream —
            // otherwise the subscriber would block forever on a partial
            // frame with no signal that delivery stopped.
            log::warn!("subscriber write failed, closing its stream: {e:#}");
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        out
    }
}

struct Shared {
    registry: Arc<SessionRegistry>,
    /// Shutdown flag: set internally when `max_frames` is reached, or
    /// externally by the holder of the [`run_server_until`] stop handle.
    done: Arc<AtomicBool>,
    frames_out: AtomicU64,
    max_frames: Option<u64>,
    /// Capture tee (`--trace`): every decoded intermediate output is
    /// re-framed and appended here before being routed to its session.
    trace: Option<Mutex<TraceSink>>,
}

impl Shared {
    /// Count completed frames toward the shutdown budget.
    fn note_events(&self, events: &[SessionEvent]) {
        let n = events
            .iter()
            .filter(|e| matches!(e, SessionEvent::Result(_)))
            .count() as u64;
        if n == 0 {
            return;
        }
        let done = self.frames_out.fetch_add(n, Ordering::SeqCst) + n;
        if let Some(max) = self.max_frames {
            if done >= max {
                self.done.store(true, Ordering::SeqCst);
            }
        }
    }

    fn poll_sessions(&self) {
        for (_, events) in self.registry.poll_all() {
            self.note_events(&events);
        }
    }
}

/// Run the edge server until `max_frames` results have been produced
/// across all sessions. Returns the registry so callers can inspect
/// per-session metrics.
pub fn run_server(paths: &Paths, cfg: &ServerConfig) -> Result<Arc<SessionRegistry>> {
    run_server_until(paths, cfg, Arc::new(AtomicBool::new(false)))
}

/// [`run_server`] with an external stop handle: the server also exits
/// when `stop` is set (within one accept-poll / read-timeout window).
/// The fleet scenario harness uses this to stop a `max_frames: None`
/// server once its device fleet has drained and stragglers flushed.
pub fn run_server_until(
    paths: &Paths,
    cfg: &ServerConfig,
    stop: Arc<AtomicBool>,
) -> Result<Arc<SessionRegistry>> {
    let meta = ModelMeta::load(&paths.model_meta())?;
    let specs = cfg.session_specs()?;

    // One backend serves every session; preload each distinct tail. On
    // the XLA backend this is a pool of `backend_threads` engine
    // threads, so different sessions' tails execute concurrently.
    let mut tails: Vec<String> = Vec::new();
    for (_, sc) in &specs {
        let tail = meta.variant(sc.variant)?.tail.clone();
        if !tails.contains(&tail) {
            tails.push(tail);
        }
    }
    let backend = build_backend(paths, &meta, cfg.backend, cfg.backend_threads, &tails)?;

    // Cross-session micro-batching: one planner shared by every session,
    // so compatible tail requests coalesce across sessions and frames
    // into stacked backend calls (`--max-batch`, `--batch-window-ms`).
    let planner = if cfg.batch.max_batch > 1 {
        Some(BatchPlanner::new(Arc::clone(&backend), cfg.batch))
    } else {
        None
    };

    let registry = Arc::new(SessionRegistry::new());
    for (name, sc) in specs {
        let mut session = DetectorSession::new(&name, meta.clone(), Arc::clone(&backend), sc)?;
        if let Some(planner) = &planner {
            session.set_batch_planner(Arc::clone(planner));
        }
        registry.insert(session);
    }
    let trace = match &cfg.trace {
        Some(path) => Some(Mutex::new(TraceSink::create(path)?)),
        None => None,
    };
    let shared = Arc::new(Shared {
        registry: Arc::clone(&registry),
        done: stop,
        frames_out: AtomicU64::new(0),
        max_frames: cfg.max_frames,
        trace,
    });

    let listener = TcpListener::bind(("127.0.0.1", cfg.port))
        .with_context(|| format!("bind port {}", cfg.port))?;
    listener.set_nonblocking(true)?;
    log::info!(
        "edge server on 127.0.0.1:{} sessions={:?} devices={} backend={} threads={} \
         max-batch={} resident={:?}",
        cfg.port,
        registry.names(),
        meta.num_devices,
        backend.backend_name(),
        cfg.backend_threads,
        cfg.batch.max_batch,
        backend.loaded_names()
    );

    let mut conn_threads = Vec::new();
    let deadline_poll = Duration::from_millis(20);
    loop {
        if shared.done.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, addr)) => {
                log::debug!("connection from {addr}");
                let shared = Arc::clone(&shared);
                conn_threads.push(thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, shared) {
                        // Clean disconnects return Ok; an Err here is a
                        // protocol violation (e.g. unknown session).
                        log::warn!("connection closed with error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Resolve expired frames while idle.
                shared.poll_sessions();
                thread::sleep(deadline_poll);
            }
            Err(e) => return Err(e.into()),
        }
    }
    for t in conn_threads {
        let _ = t.join();
    }
    if let Some(sink) = &shared.trace {
        let mut sink = lock_or_recover(sink);
        sink.flush()?;
        log::info!("trace capture: {} records", sink.records());
    }
    if let Some(planner) = &planner {
        let m = planner.metrics();
        log::info!(
            "batch planner: {} backend calls for {} frames ({} rejected)",
            m.counter("batch_backend_calls"),
            m.counter("batch_frames"),
            m.counter("batch_rejected"),
        );
    }
    Ok(registry)
}

/// One connection: decode messages, route them to the addressed session.
fn handle_conn(stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true)?;
    // Read timeout so the thread re-checks `done` even on idle
    // connections (e.g. a subscriber that only listens).
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = std::io::BufReader::new(stream.try_clone()?);
    // One write handle per connection, shared by every sink this
    // connection subscribes, so concurrent sessions cannot interleave
    // frames on the socket.
    let mut sink_stream: Option<Arc<Mutex<TcpStream>>> = None;
    loop {
        if shared.done.load(Ordering::SeqCst) {
            return Ok(());
        }
        let msg = match crate::net::read_msg(&mut reader) {
            Ok(m) => m,
            Err(e) => {
                // Timeout (no header byte yet): keep polling. Any other
                // error means the peer closed or the stream desynced.
                let timed_out = e.downcast_ref::<std::io::Error>().map_or(false, |io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out {
                    continue;
                }
                // Peer closed, or the stream desynced / failed to decode:
                // keep a trace, the other end may be wondering why its
                // frames stopped landing.
                log::debug!("connection read ended: {e:#}");
                return Ok(());
            }
        };
        // Capture tee: re-frame feature messages into the trace before
        // routing. A tee failure degrades the capture, never the serving
        // path — the frame is still submitted.
        if let Some(sink) = &shared.trace {
            if matches!(&msg, Msg::Features { .. } | Msg::FeaturesQ { .. }) {
                match crate::net::encode_frame(&msg) {
                    Ok(bytes) => {
                        let arrival = crate::utils::unix_micros();
                        if let Err(e) = lock_or_recover(sink).record(arrival, &bytes) {
                            log::warn!("trace tee write failed: {e:#}");
                        }
                    }
                    Err(e) => log::warn!("trace tee encode failed: {e:#}"),
                }
            }
        }
        match msg {
            Msg::Hello { device_id, session } => {
                // Unknown session: closing the connection is the only
                // signal the protocol can give the peer — silently
                // dropping its traffic would let a typoed `--session`
                // "succeed" while every frame is discarded.
                anyhow::ensure!(
                    shared.registry.get(&session).is_some(),
                    "device {device_id} greeted unknown session {session:?} (have {:?})",
                    shared.registry.names()
                );
                log::info!("device {device_id} connected to session {session:?}");
            }
            Msg::Subscribe { session } => match shared.registry.get(&session) {
                Some(s) => {
                    let shared_stream = match &sink_stream {
                        Some(st) => Arc::clone(st),
                        None => {
                            let st = stream.try_clone()?;
                            // Bound sink writes so one stalled subscriber
                            // cannot wedge result delivery for the whole
                            // session.
                            st.set_write_timeout(Some(Duration::from_secs(5)))?;
                            let st = Arc::new(Mutex::new(st));
                            sink_stream = Some(Arc::clone(&st));
                            st
                        }
                    };
                    s.attach_sink(Box::new(TcpSink { stream: shared_stream }));
                    log::info!("result subscriber attached to session {session:?}");
                }
                None => anyhow::bail!(
                    "subscribe to unknown session {session:?} (have {:?})",
                    shared.registry.names()
                ),
            },
            Msg::Features { frame_id, device_id, tensor, session, capture_micros } => {
                submit(
                    &shared,
                    &session,
                    frame_id,
                    device_id,
                    FeaturePayload::Raw(tensor),
                    capture_micros,
                )?;
            }
            Msg::FeaturesQ { frame_id, device_id, tensor, session, capture_micros } => {
                submit(
                    &shared,
                    &session,
                    frame_id,
                    device_id,
                    FeaturePayload::Quantized(tensor),
                    capture_micros,
                )?;
            }
            Msg::Bye => return Ok(()),
            Msg::Result { .. } => {
                log::warn!("unexpected Result from client");
            }
        }
    }
}

/// Route one intermediate output into its session; dequantization and
/// post-processing happen inside the session core. An unknown session is
/// an error (closes the connection); a bad payload is logged and
/// tolerated so one corrupt frame doesn't kill a healthy device link.
fn submit(
    shared: &Shared,
    session: &str,
    frame_id: u64,
    device_id: u32,
    payload: FeaturePayload,
    capture_micros: u64,
) -> Result<()> {
    let Some(sess) = shared.registry.get(session) else {
        anyhow::bail!(
            "features for unknown session {session:?} (have {:?})",
            shared.registry.names()
        );
    };
    // Addressing errors close the connection (a misconfigured worker
    // must not look like it is succeeding); a corrupt payload is logged
    // and tolerated so one bad frame doesn't kill a healthy link.
    anyhow::ensure!(
        (device_id as usize) < sess.meta().num_devices,
        "device {device_id} out of range for session {session:?} ({} devices)",
        sess.meta().num_devices
    );
    if shared.trace.is_some() {
        sess.metrics().incr("trace_recorded", 1);
    }
    // submit() already resolves this session's expirations; other
    // sessions are polled by the accept loop every 20 ms. Polling them
    // here too would make this connection thread run (and block on)
    // other sessions' work — breaking per-session isolation.
    match sess.submit_at(frame_id, device_id as usize, payload, capture_micros) {
        Ok(events) => shared.note_events(&events),
        Err(e) => log::warn!("submit to session {session:?} failed: {e:#}"),
    }
    Ok(())
}

/// Parse `--sessions name=variant[:deadline_ms],...` into extra session
/// configs; unset knobs inherit the default session's.
pub fn parse_session_specs(
    spec: &str,
    base: &ServerConfig,
) -> Result<Vec<(String, SessionConfig)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let (name, rest) = part
            .split_once('=')
            .with_context(|| format!("session spec {part:?} must be name=variant[:deadline_ms]"))?;
        anyhow::ensure!(!name.is_empty(), "empty session name in {part:?}");
        let (variant, deadline) = match rest.split_once(':') {
            Some((v, ms)) => {
                let ms: u64 = ms
                    .parse()
                    .with_context(|| format!("bad deadline {ms:?} in session spec {part:?}"))?;
                (IntegrationKind::parse(v)?, Duration::from_millis(ms))
            }
            None => (IntegrationKind::parse(rest)?, base.deadline),
        };
        out.push((
            name.to_string(),
            SessionConfig::new(variant)
                .deadline(deadline)
                .policy(base.policy)
                .decode(base.decode.clone()),
        ));
    }
    Ok(out)
}

/// Build the server configuration from `scmii serve` flags (separated
/// from `cmd_serve` so flag wiring is unit-testable).
pub fn server_config_from_args(args: &Args) -> Result<ServerConfig> {
    args.check_known(&[
        "artifacts",
        "port",
        "variant",
        "deadline-ms",
        "policy",
        "max-frames",
        "score-thresh",
        "nms-iou",
        "sessions",
        "backend",
        "backend-threads",
        "max-batch",
        "batch-window-ms",
        "trace",
    ])?;
    let mut cfg = ServerConfig::default();
    cfg.port = args.usize_or("port", cfg.port as usize)? as u16;
    cfg.variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    cfg.deadline = Duration::from_millis(args.u64_or("deadline-ms", 200)?);
    // One spelling authority: str_one_of rejects typos with the flag
    // name, LossPolicy::parse owns the string → variant mapping.
    cfg.policy =
        LossPolicy::parse(&args.str_one_of("policy", &["zero-fill", "drop"], "zero-fill")?)?;
    // Same flags, same defaults as the in-process pipeline — one parser.
    let be = super::pipeline::PipelineBackend::from_args(args)?;
    cfg.backend = be.kind;
    cfg.backend_threads = be.threads;
    cfg.decode.score_threshold = args.f32_or("score-thresh", cfg.decode.score_threshold)?;
    cfg.decode.nms_iou = args.f64_or("nms-iou", cfg.decode.nms_iou)?;
    cfg.batch.max_batch = args.usize_or("max-batch", cfg.batch.max_batch)?;
    cfg.batch.window = args.ms_or("batch-window-ms", cfg.batch.window.as_millis() as u64)?;
    let max = args.u64_or("max-frames", 0)?;
    cfg.max_frames = if max > 0 { Some(max) } else { None };
    cfg.trace = args.str_opt("trace").map(std::path::PathBuf::from);
    if let Some(spec) = args.str_opt("sessions") {
        cfg.extra_sessions = parse_session_specs(spec, &cfg)?;
    }
    Ok(cfg)
}

/// `scmii serve` CLI entry.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let paths = Paths::new(&args.str_or("artifacts", "artifacts"), "data");
    let cfg = server_config_from_args(args)?;
    let registry = run_server(&paths, &cfg)?;
    for name in registry.names() {
        if let Some(s) = registry.get(&name) {
            println!("--- session {name} ---");
            print!("{}", s.metrics().report());
        }
    }
    Ok(())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn serve_flags_thread_decode_params() {
        let cfg = server_config_from_args(&args(&[
            "--score-thresh",
            "0.4",
            "--nms-iou",
            "0.6",
            "--deadline-ms",
            "150",
            "--policy",
            "drop",
        ]))
        .unwrap();
        assert!((cfg.decode.score_threshold - 0.4).abs() < 1e-6);
        assert!((cfg.decode.nms_iou - 0.6).abs() < 1e-9);
        assert_eq!(cfg.deadline, Duration::from_millis(150));
        assert_eq!(cfg.policy, LossPolicy::Drop);
        // ... and the session spec inherits them.
        let specs = cfg.session_specs().unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].0, DEFAULT_SESSION);
        assert!((specs[0].1.decode.score_threshold - 0.4).abs() < 1e-6);
        assert_eq!(specs[0].1.policy, LossPolicy::Drop);
    }

    #[test]
    fn serve_flags_default_decode_unchanged() {
        let cfg = server_config_from_args(&args(&[])).unwrap();
        let d = DecodeParams::default();
        assert!((cfg.decode.score_threshold - d.score_threshold).abs() < 1e-9);
        assert!((cfg.decode.nms_iou - d.nms_iou).abs() < 1e-12);
    }

    #[test]
    fn unknown_serve_flag_rejected() {
        assert!(server_config_from_args(&args(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn serve_backend_flags_parse() {
        let cfg = server_config_from_args(&args(&[
            "--backend",
            "native",
            "--backend-threads",
            "4",
        ]))
        .unwrap();
        assert_eq!(cfg.backend, BackendKind::Native);
        assert_eq!(cfg.backend_threads, 4);
        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.backend, BackendKind::default_kind());
        assert_eq!(d.backend_threads, 1);
        assert!(server_config_from_args(&args(&["--backend", "gpu"])).is_err());
        // Satellite regression: a typoed policy used to silently mean
        // zero-fill; it must now be rejected.
        assert!(server_config_from_args(&args(&["--policy", "bogus"])).is_err());
    }

    #[test]
    fn serve_batch_flags_parse() {
        let cfg = server_config_from_args(&args(&[
            "--max-batch",
            "8",
            "--batch-window-ms",
            "5",
        ]))
        .unwrap();
        assert_eq!(cfg.batch.max_batch, 8);
        assert_eq!(cfg.batch.window, Duration::from_millis(5));
        // Defaults keep batching off — the per-frame path is untouched.
        let d = server_config_from_args(&args(&[])).unwrap();
        assert_eq!(d.batch.max_batch, 1);
        assert!(server_config_from_args(&args(&["--max-batch", "lots"])).is_err());
    }

    #[test]
    fn serve_trace_flag_parses() {
        let cfg = server_config_from_args(&args(&["--trace", "/tmp/cap.scmt"])).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("/tmp/cap.scmt")));
        let d = server_config_from_args(&args(&[])).unwrap();
        assert!(d.trace.is_none(), "capture is opt-in");
    }

    #[test]
    fn poisoned_tcp_sink_detaches_instead_of_panicking() {
        // Regression for the `stream.lock().unwrap()` panic: poison the
        // shared stream mutex the way a panicking writer would, then
        // deliver — the sink must return an error (detach), not unwind.
        let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let accepted = std::thread::spawn(move || listener.accept().unwrap().0);
        let client = std::net::TcpStream::connect(addr).unwrap();
        let _server_side = accepted.join().unwrap();

        let shared = Arc::new(std::sync::Mutex::new(client));
        let poisoner = Arc::clone(&shared);
        let _ = std::thread::spawn(move || {
            let _guard = poisoner.lock().unwrap();
            panic!("writer dies mid-send");
        })
        .join();
        assert!(shared.lock().is_err(), "mutex must be poisoned for the test to bite");

        let mut sink = TcpSink { stream: shared };
        let result = FrameResult {
            frame_id: 1,
            detections: Vec::new(),
            present: vec![true, true],
            tail_secs: 0.0,
            post_secs: 0.0,
            sync_wait_secs: 0.0,
            capture_micros: 0,
            tail_error: false,
        };
        let out = sink.deliver("default", &result);
        assert!(out.is_err(), "poisoned sink must detach via an error, not a panic");
    }

    #[test]
    fn session_spec_parsing() {
        let base = ServerConfig::default();
        let specs = parse_session_specs("north=max,south=conv_k1:150", &base).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].0, "north");
        assert_eq!(specs[0].1.variant, IntegrationKind::Max);
        assert_eq!(specs[0].1.deadline, base.deadline);
        assert_eq!(specs[1].0, "south");
        assert_eq!(specs[1].1.variant, IntegrationKind::ConvK1);
        assert_eq!(specs[1].1.deadline, Duration::from_millis(150));

        assert!(parse_session_specs("noequals", &base).is_err());
        assert!(parse_session_specs("x=notavariant", &base).is_err());
        assert!(parse_session_specs("x=max:notanumber", &base).is_err());
        assert!(parse_session_specs("=max", &base).is_err());
    }

    #[test]
    fn server_config_lists_all_sessions() {
        let mut cfg = ServerConfig::default();
        cfg.extra_sessions =
            vec![("aux".to_string(), SessionConfig::new(IntegrationKind::Max))];
        let specs = cfg.session_specs().unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].0, DEFAULT_SESSION);
        assert_eq!(specs[1].0, "aux");
    }

    #[test]
    fn duplicate_session_names_rejected() {
        let mut cfg = ServerConfig::default();
        cfg.extra_sessions = vec![
            ("north".to_string(), SessionConfig::new(IntegrationKind::Max)),
            ("north".to_string(), SessionConfig::new(IntegrationKind::ConvK1)),
        ];
        assert!(cfg.session_specs().is_err(), "repeated extra name must fail");

        let mut cfg = ServerConfig::default();
        cfg.extra_sessions =
            vec![(DEFAULT_SESSION.to_string(), SessionConfig::new(IntegrationKind::Max))];
        assert!(cfg.session_specs().is_err(), "shadowing the default must fail");
    }
}
