//! The transport-agnostic serving core: one `DetectorSession` per hosted
//! detector (paper Fig 2, right half — frame sync → integration + tail →
//! decode/NMS), shared by every frontend.
//!
//! Before this module existed the Fig-2 flow was implemented three times
//! — in `ScMiiPipeline::infer`, in the TCP server's ready-frame handler,
//! and again (implicitly) in the eval/latency harnesses. Each copy had
//! its own decode parameters and its own metrics, so a fix in one path
//! silently missed the others. Now:
//!
//! - [`ScMiiPipeline`](super::pipeline::ScMiiPipeline) is a thin
//!   synchronous driver: run heads, [`DetectorSession::submit`], read the
//!   [`SessionEvent`]s back.
//! - The TCP server is pure I/O: socket ⇄ [`Msg`](crate::net::Msg) ⇄
//!   session, with results fanned out through [`ResultSink`]s.
//! - The harnesses measure through the pipeline and therefore through
//!   this exact code path — benchmark numbers come from the code that
//!   serves traffic.
//!
//! A [`SessionRegistry`] lets one server process host N named sessions
//! (multiple intersections, or A/B integration variants) addressed by the
//! wire `session` field; each session keeps isolated state, config, and
//! [`Metrics`].

use super::scheduler::{BatchPlanner, FrameSync, LossPolicy, ReadyFrame, SyncStats};
use crate::config::{
    normalize_split, wire_channels, IntegrationKind, ModelMeta, SPLIT_DEEP, SPLIT_SHALLOW,
};
use crate::metrics::Metrics;
use crate::model::{postprocess, DecodeParams, Detection};
use crate::net::QuantTensor;
use crate::runtime::{ExecBackend, HostTensor};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::time::Instant;
use crate::sync::{lock_or_recover, Arc, Mutex};
use std::time::Duration;

/// An intermediate output arriving at the session, in whichever encoding
/// the transport used. Dequantization lives *here*, not in per-transport
/// match arms, so every frontend handles compressed payloads identically.
#[derive(Clone, Debug)]
pub enum FeaturePayload {
    /// Full-precision f32 feature map.
    Raw(HostTensor),
    /// u8-quantized feature map (paper §IV-E compressed intermediate
    /// outputs — 4× smaller on the wire).
    Quantized(QuantTensor),
}

impl FeaturePayload {
    /// Whether this payload arrived in the compressed (u8) encoding.
    pub fn is_quantized(&self) -> bool {
        matches!(self, FeaturePayload::Quantized(_))
    }

    /// Approximate wire size of this payload in bytes.
    pub fn wire_bytes(&self) -> usize {
        match self {
            FeaturePayload::Raw(t) => t.byte_len(),
            FeaturePayload::Quantized(q) => q.byte_len(),
        }
    }

    /// Decode to the full-precision tensor the tail model consumes.
    pub fn into_tensor(self) -> Result<HostTensor> {
        match self {
            FeaturePayload::Raw(t) => Ok(t),
            FeaturePayload::Quantized(q) => crate::net::dequantize(&q),
        }
    }
}

impl From<HostTensor> for FeaturePayload {
    fn from(t: HostTensor) -> FeaturePayload {
        FeaturePayload::Raw(t)
    }
}

impl From<QuantTensor> for FeaturePayload {
    fn from(q: QuantTensor) -> FeaturePayload {
        FeaturePayload::Quantized(q)
    }
}

/// Per-session configuration, built fluently:
///
/// ```ignore
/// SessionConfig::new(IntegrationKind::ConvK3)
///     .deadline(Duration::from_millis(150))
///     .policy(LossPolicy::Drop)
///     .decode(DecodeParams { score_threshold: 0.4, ..Default::default() })
/// ```
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Integration method (selects the tail model).
    pub variant: IntegrationKind,
    /// Frame-sync deadline: how long to wait for missing devices.
    pub deadline: Duration,
    /// What to do with frames still incomplete at the deadline.
    pub policy: LossPolicy,
    /// Decode/NMS parameters for this session's post-processing.
    pub decode: DecodeParams,
    /// Latest-wins frame replacement (see [`FrameSync::set_latest_wins`]):
    /// on for datagram-fed sessions, off (default) for the in-order TCP
    /// path.
    pub latest_wins: bool,
    /// Split depth this session's devices cut the model at (one of
    /// [`crate::config::SPLIT_DEPTHS`]; `""` = the default depth). Every
    /// device of a session must run the matching head — the server
    /// rejects a `Hello` declaring a different depth.
    pub split: String,
    /// Overload shedding watermark: when the shared batch planner's
    /// queue depth reaches this many pending requests, the session
    /// resolves frames through its cheaper shed tail and coarser decode
    /// parameters instead of rejecting them. `0` (default) disables
    /// shedding; below the watermark the serving path is byte-identical
    /// to a shedding-free session.
    pub shed_watermark: usize,
}

impl SessionConfig {
    /// Defaults for `variant`: 200 ms deadline, zero-fill policy,
    /// default decode parameters.
    pub fn new(variant: IntegrationKind) -> SessionConfig {
        SessionConfig {
            variant,
            deadline: Duration::from_millis(200),
            policy: LossPolicy::ZeroFill,
            decode: DecodeParams::default(),
            latest_wins: false,
            split: String::new(),
            shed_watermark: 0,
        }
    }

    /// Override the frame-sync deadline.
    pub fn deadline(mut self, deadline: Duration) -> SessionConfig {
        self.deadline = deadline;
        self
    }

    /// Override the incomplete-frame policy.
    pub fn policy(mut self, policy: LossPolicy) -> SessionConfig {
        self.policy = policy;
        self
    }

    /// Override the decode/NMS parameters.
    pub fn decode(mut self, decode: DecodeParams) -> SessionConfig {
        self.decode = decode;
        self
    }

    /// Enable/disable latest-wins frame replacement in the synchronizer.
    pub fn latest_wins(mut self, on: bool) -> SessionConfig {
        self.latest_wins = on;
        self
    }

    /// Select the split depth (`""` keeps the default depth; validated
    /// when the session is built).
    pub fn split(mut self, split: &str) -> SessionConfig {
        self.split = split.to_string();
        self
    }

    /// Set the overload shedding watermark (0 disables shedding).
    pub fn shed_watermark(mut self, watermark: usize) -> SessionConfig {
        self.shed_watermark = watermark;
        self
    }
}

/// Coarser decode parameters applied to shed frames: a higher score
/// floor and smaller candidate/output budgets make decode + NMS
/// markedly cheaper (NMS is quadratic in candidates) while keeping
/// high-confidence detections — degraded output, not dropped output.
pub fn shed_decode_params(d: &DecodeParams) -> DecodeParams {
    DecodeParams {
        score_threshold: d.score_threshold.max(0.4),
        pre_nms_top_k: (d.pre_nms_top_k / 4).max(32),
        nms_iou: d.nms_iou,
        max_detections: (d.max_detections / 2).max(16),
    }
}

/// A completed frame: decoded detections plus the timings the latency
/// model consumes.
#[derive(Clone, Debug)]
pub struct FrameResult {
    /// Frame id the devices stamped on their intermediate outputs.
    pub frame_id: u64,
    /// Decoded, NMS-filtered detections.
    pub detections: Vec<Detection>,
    /// Which devices actually contributed (false = zero-filled).
    pub present: Vec<bool>,
    /// Tail-stage latency: alignment + integration + backbone + heads
    /// execution, **plus** any micro-batching coalescing wait when a
    /// [`BatchPlanner`] is attached (up to the batch window) — i.e. the
    /// frame's server-side residence time in the tail stage, not pure
    /// kernel cost.
    pub tail_secs: f64,
    /// Decode + NMS time.
    pub post_secs: f64,
    /// First-arrival → tail-start wait (sync latency accounting).
    pub sync_wait_secs: f64,
    /// Earliest device capture stamp for this frame (wall-clock µs; 0 =
    /// no device stamped it). Echoed to subscribers so end-to-end
    /// latency — capture to decoded detections — can be accounted.
    pub capture_micros: u64,
    /// True when the tail failed and `detections` is empty for that
    /// reason (the frame still completes so frontends stay in lockstep).
    pub tail_error: bool,
}

/// What a [`DetectorSession`] hands back from [`submit`](DetectorSession::submit)
/// / [`poll`](DetectorSession::poll).
#[derive(Clone, Debug)]
pub enum SessionEvent {
    /// A frame completed (possibly with zero-filled devices).
    Result(FrameResult),
    /// A frame expired under [`LossPolicy::Drop`] and was discarded.
    Dropped {
        /// Id of the discarded frame.
        frame_id: u64,
    },
}

/// Delivery hook for completed frames. The TCP server attaches one per
/// subscriber connection; tests attach collectors. A sink returning an
/// error is detached.
///
/// `deliver` runs on whichever thread resolved the frame (a dispatch
/// worker, or the deadline sweep), while the session's sink list is
/// locked — so it must be **fast and non-blocking**: encode and enqueue,
/// never a socket write or an unbounded wait. A sink that blocks stalls
/// every other subscriber of the session behind the same lock. The
/// server's TCP sink satisfies this by pushing into a bounded
/// per-connection queue (overflow drops the oldest frame and counts it
/// as `sink_dropped`) that the event loop flushes on write-readiness.
pub trait ResultSink: Send {
    /// Deliver one completed frame of `session`. Returning an error (or
    /// panicking) detaches this sink.
    fn deliver(&mut self, session: &str, result: &FrameResult) -> Result<()>;
}

/// The serving core for one detector: owns the frame synchronizer, the
/// execution backend running the tail model, decode parameters, and
/// metrics. Thread-safe behind `&self`; share it across connection
/// threads in an `Arc`.
///
/// The backend is shared (`Arc<dyn ExecBackend>`): many sessions point
/// at one engine pool, and tails of different sessions execute
/// concurrently up to the pool size.
pub struct DetectorSession {
    name: String,
    cfg: SessionConfig,
    meta: ModelMeta,
    tail: String,
    /// Canonical split depth (one of [`crate::config::SPLIT_DEPTHS`]).
    split: &'static str,
    /// Static metric name counting frames completed at this depth
    /// (`split_shallow` / `split_mid` / `split_deep`).
    split_metric: &'static str,
    /// Cheaper tail the session degrades to under overload (the Max
    /// integration variant at the same split; falls back to the
    /// session's own tail when that variant is absent, leaving the
    /// coarser decode parameters as the degradation floor).
    shed_tail: String,
    /// Coarser decode/NMS parameters applied to shed frames.
    shed_decode: DecodeParams,
    backend: Arc<dyn ExecBackend>,
    /// When set, tail executions route through the shared cross-session
    /// batch planner instead of calling the backend directly.
    planner: Option<Arc<BatchPlanner>>,
    sync: Mutex<FrameSync>,
    sinks: Mutex<Vec<Box<dyn ResultSink>>>,
    metrics: Arc<Metrics>,
    frames_done: AtomicU64,
}

impl DetectorSession {
    /// Build a session for `cfg.variant`. The tail model must already be
    /// loaded (or loadable) in `backend`.
    pub fn new(
        name: &str,
        meta: ModelMeta,
        backend: Arc<dyn ExecBackend>,
        cfg: SessionConfig,
    ) -> Result<DetectorSession> {
        anyhow::ensure!(!name.is_empty(), "session name must be non-empty");
        anyhow::ensure!(
            name.len() <= crate::net::MAX_SESSION_NAME,
            "session name longer than {} bytes",
            crate::net::MAX_SESSION_NAME
        );
        let split = normalize_split(&cfg.split)
            .with_context(|| format!("session {name:?} split depth"))?;
        let split_metric = match split {
            SPLIT_SHALLOW => "split_shallow",
            SPLIT_DEEP => "split_deep",
            _ => "split_mid",
        };
        let tail = meta.variant(cfg.variant)?.tail_for(split)?;
        // Shed target: the Max-integration tail is the cheapest variant
        // (elementwise max, no learned integration conv). A session
        // already running it — or a model without it — sheds through its
        // own tail, with the coarser decode parameters as the floor.
        let shed_tail = match meta.variant(IntegrationKind::Max) {
            Ok(vm) => vm.tail_for(split)?,
            Err(_) => tail.clone(),
        };
        let shed_decode = shed_decode_params(&cfg.decode);
        let g = &meta.grid;
        let feat_shape = vec![g.dims[2], g.dims[1], g.dims[0], wire_channels(g, split)?];
        let mut sync = FrameSync::new(meta.num_devices, cfg.deadline, cfg.policy, feat_shape);
        sync.set_latest_wins(cfg.latest_wins);
        Ok(DetectorSession {
            name: name.to_string(),
            cfg,
            meta,
            tail,
            split,
            split_metric,
            shed_tail,
            shed_decode,
            backend,
            planner: None,
            sync: Mutex::new(sync),
            sinks: Mutex::new(Vec::new()),
            metrics: Arc::new(Metrics::new()),
            frames_done: AtomicU64::new(0),
        })
    }

    /// Name this session is addressed by on the wire.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Canonical split depth this session serves (devices must run the
    /// matching head).
    pub fn split(&self) -> &'static str {
        self.split
    }

    /// Executable name of the cheaper tail used for shed frames.
    pub fn shed_tail_name(&self) -> &str {
        &self.shed_tail
    }

    /// This session's configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Model geometry (grid, devices, anchors) the session serves.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Executable name of the tail model this session runs.
    pub fn tail_name(&self) -> &str {
        &self.tail
    }

    /// Route this session's tail executions through a shared
    /// [`BatchPlanner`], coalescing them with compatible requests from
    /// other sessions and frames (cross-session micro-batching). Call
    /// before the session starts serving; without a planner — or with a
    /// planner whose `max_batch` is 1 — tails run directly on the
    /// backend, byte-identical to the unbatched path.
    pub fn set_batch_planner(&mut self, planner: Arc<BatchPlanner>) {
        self.planner = Some(planner);
    }

    /// The batch planner attached to this session, if any.
    pub fn batch_planner(&self) -> Option<&Arc<BatchPlanner>> {
        self.planner.as_ref()
    }

    /// Execute this session's tail over one or more input sets: through
    /// the batch planner when one is attached (burst entries become each
    /// other's batch-mates), directly on the backend otherwise — the
    /// single dispatch site [`run_tail`](Self::run_tail) and the
    /// frame-completion path both funnel through.
    fn exec_tail_many(
        &self,
        tail: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        match &self.planner {
            Some(p) => p.exec_many(&self.name, tail, batch),
            None => batch.into_iter().map(|inputs| self.backend.exec(tail, inputs)).collect(),
        }
    }

    /// [`exec_tail_many`](Self::exec_tail_many) for a single input set.
    fn exec_tail(&self, features: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.exec_tail_many(&self.tail, vec![features])
            .pop()
            .expect("one result per input set")
    }

    /// Whether the next ready batch should resolve through the shed
    /// path. The signal is the shared batch planner's queue depth — the
    /// per-process measure of tail backlog — sampled at frame-resolution
    /// time; without a planner there is no queue to overflow, so
    /// shedding never triggers.
    fn should_shed(&self) -> bool {
        if self.cfg.shed_watermark == 0 {
            return false;
        }
        match &self.planner {
            Some(p) => p.queue_depth() >= self.cfg.shed_watermark,
            None => false,
        }
    }

    /// The execution backend this session runs its tail on.
    pub fn backend(&self) -> &Arc<dyn ExecBackend> {
        &self.backend
    }

    /// Shared handle to this session's metrics (isolated per session).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Frames this session has completed (including zero-filled ones).
    pub fn frames_done(&self) -> u64 {
        self.frames_done.load(Ordering::SeqCst)
    }

    /// Snapshot of the synchronizer counters.
    pub fn sync_stats(&self) -> SyncStats {
        lock_or_recover(&self.sync).stats
    }

    /// Mutable access to decode parameters (in-process tuning; the TCP
    /// deployment sets them up front via [`SessionConfig`]).
    pub fn decode_params_mut(&mut self) -> &mut DecodeParams {
        &mut self.cfg.decode
    }

    /// Attach a delivery sink; it receives every completed frame until it
    /// errors (then it is dropped).
    pub fn attach_sink(&self, sink: Box<dyn ResultSink>) {
        lock_or_recover(&self.sinks).push(sink);
    }

    /// Register one device's intermediate output for `frame_id`. Returns
    /// the events this submission resolved: the completed frame when this
    /// was the last missing device, plus any frames the deadline expired
    /// while we were at it.
    pub fn submit(
        &self,
        frame_id: u64,
        device_id: usize,
        payload: FeaturePayload,
    ) -> Result<Vec<SessionEvent>> {
        self.submit_at(frame_id, device_id, payload, 0)
    }

    /// [`submit`](Self::submit) with the device's frame-capture stamp
    /// (wall-clock µs; 0 = unstamped). When a frame resolves with a
    /// stamp, the session records capture → decoded-detections latency
    /// in its `e2e` metric series — the number `scmii scenario` reports.
    pub fn submit_at(
        &self,
        frame_id: u64,
        device_id: usize,
        payload: FeaturePayload,
        capture_micros: u64,
    ) -> Result<Vec<SessionEvent>> {
        self.metrics.incr("features_rx", 1);
        if payload.is_quantized() {
            self.metrics.incr("features_rx_quantized", 1);
        }
        if device_id >= self.meta.num_devices {
            self.metrics.incr("bad_device", 1);
            anyhow::bail!(
                "device {device_id} out of range for session {:?} ({} devices)",
                self.name,
                self.meta.num_devices
            );
        }
        let tensor = match payload.into_tensor() {
            Ok(t) => t,
            Err(e) => {
                self.metrics.incr("decode_errors", 1);
                return Err(e).context("decode feature payload");
            }
        };
        let ready = {
            let mut sync = lock_or_recover(&self.sync);
            sync.add_at(frame_id, device_id, tensor, capture_micros)
        };
        let mut events = Vec::new();
        if let Some(ready) = ready {
            events.push(self.process_ready(ready));
        }
        // Opportunistically resolve expirations on traffic too.
        events.extend(self.poll());
        if !events.is_empty() {
            self.publish_sync_stats();
        }
        Ok(events)
    }

    /// Abandon a frame mid-submission, releasing any tensors already
    /// buffered for it (the in-process frontend calls this when a later
    /// head fails, so partial frames don't pin memory until the
    /// deadline).
    pub fn abort_frame(&self, frame_id: u64) -> bool {
        lock_or_recover(&self.sync).abort(frame_id)
    }

    /// Resolve frames whose deadline expired. Frontends call this
    /// periodically (the TCP server does so between accepts).
    pub fn poll(&self) -> Vec<SessionEvent> {
        let (expired, dropped) = {
            let mut sync = lock_or_recover(&self.sync);
            let expired = sync.poll_expired();
            let dropped = sync.take_dropped();
            (expired, dropped)
        };
        let mut events: Vec<SessionEvent> = dropped
            .into_iter()
            .map(|frame_id| SessionEvent::Dropped { frame_id })
            .collect();
        // A deadline burst (e.g. a device going dark expires many frames
        // in one poll) resolves as one bulk tail execution: with a batch
        // planner attached the burst coalesces into stacked backend calls
        // sharing a single collection window, instead of paying one
        // window per frame.
        events.extend(self.process_ready_batch(expired));
        if !events.is_empty() {
            self.publish_sync_stats();
        }
        events
    }

    /// Decode + NMS with this session's parameters — the single
    /// post-processing call site every frontend funnels through.
    pub fn decode_detections(&self, cls: &[f32], boxes: &[f32]) -> Vec<Detection> {
        postprocess(cls, boxes, &self.meta, &self.cfg.decode)
    }

    /// Execute the tail on already-synchronized features and return the
    /// raw (cls, boxes) outputs (debug dumps and cross-check tests).
    pub fn run_tail(&self, features: Vec<HostTensor>) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.exec_tail(features)?;
        anyhow::ensure!(out.len() == 2, "tail returns (cls, boxes)");
        let mut it = out.into_iter();
        let cls = it.next().unwrap().data;
        let boxes = it.next().unwrap().data;
        Ok((cls, boxes))
    }

    /// Fig-2 right half for one synchronized frame: tail → decode/NMS →
    /// metrics → sinks.
    fn process_ready(&self, ready: ReadyFrame) -> SessionEvent {
        self.process_ready_batch(vec![ready]).pop().expect("one event per ready frame")
    }

    /// [`process_ready`](Self::process_ready) over a burst of frames.
    /// Tails execute in bulk — through [`BatchPlanner::exec_many`] when a
    /// planner is attached, so sibling frames of the burst become each
    /// other's batch-mates — then each frame decodes, records metrics,
    /// and delivers to the sinks individually. `tail_secs` is the burst's
    /// shared tail-stage residence time (there is no meaningful per-frame
    /// split of a stacked backend call).
    fn process_ready_batch(&self, ready: Vec<ReadyFrame>) -> Vec<SessionEvent> {
        if ready.is_empty() {
            return Vec::new();
        }
        let t0 = Instant::now();
        type FrameMeta = (u64, Vec<bool>, Instant, u64);
        let (frames, batch): (Vec<FrameMeta>, Vec<Vec<HostTensor>>) = ready
            .into_iter()
            .map(|r| ((r.frame_id, r.present, r.first_arrival, r.capture_micros), r.tensors))
            .unzip();
        // Overload degradation: past the watermark the whole burst
        // resolves through the cheaper shed tail and coarser decode
        // parameters — frames complete late-but-cheap instead of being
        // rejected. Below the watermark the path is byte-identical to a
        // shedding-free session.
        let shed = self.should_shed();
        let (tail, decode) = if shed {
            self.metrics.incr("shed_batches", 1);
            self.metrics.incr("shed_frames", batch.len() as u64);
            log::debug!(
                "session {:?} shedding {} frame(s) through {:?}",
                self.name,
                batch.len(),
                self.shed_tail
            );
            (self.shed_tail.as_str(), &self.shed_decode)
        } else {
            (self.tail.as_str(), &self.cfg.decode)
        };
        let results = self.exec_tail_many(tail, batch);
        let tail_secs = t0.elapsed().as_secs_f64();

        frames
            .into_iter()
            .zip(results)
            .map(|((frame_id, present, first_arrival, capture_micros), result)| {
                let sync_wait_secs = t0.duration_since(first_arrival).as_secs_f64();
                self.metrics.record("tail_exec", tail_secs);
                self.metrics.record("sync_wait", sync_wait_secs);

                let t1 = Instant::now();
                let (detections, tail_error) = match result {
                    Ok(out) if out.len() == 2 => {
                        (postprocess(&out[0].data, &out[1].data, &self.meta, decode), false)
                    }
                    Ok(out) => {
                        self.metrics.incr("tail_errors", 1);
                        log::warn!("tail returned {} outputs, expected 2", out.len());
                        (Vec::new(), true)
                    }
                    Err(e) => {
                        self.metrics.incr("tail_errors", 1);
                        log::warn!("tail execution failed: {e:#}");
                        (Vec::new(), true)
                    }
                };
                let post_secs = t1.elapsed().as_secs_f64();
                self.metrics.record("post", post_secs);
                self.metrics.incr("frames_done", 1);
                self.metrics.incr(self.split_metric, 1);
                self.frames_done.fetch_add(1, Ordering::SeqCst);
                // End-to-end latency at the paper's finish line: device
                // capture → decoded detections, about to be handed to the
                // ResultSinks.
                if capture_micros > 0 {
                    let now = crate::utils::unix_micros();
                    self.metrics
                        .record("e2e", now.saturating_sub(capture_micros) as f64 * 1e-6);
                }

                let result = FrameResult {
                    frame_id,
                    detections,
                    present,
                    tail_secs,
                    post_secs,
                    sync_wait_secs,
                    capture_micros,
                    tail_error,
                };
                let mut sinks = lock_or_recover(&self.sinks);
                // A sink that panics mid-deliver (e.g. a poisoned stream
                // mutex inside a TCP sink) must not unwind out of here
                // with the sinks lock held — that would poison it and
                // kill result delivery for every subscriber of this
                // session, forever. Treat a panic like a delivery error:
                // detach the sink, keep serving the rest.
                sinks.retain_mut(|s| {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        s.deliver(&self.name, &result)
                    }))
                    .map_or(false, |r| r.is_ok())
                });
                drop(sinks);
                SessionEvent::Result(result)
            })
            .collect()
    }

    /// Mirror the synchronizer counters into this session's metrics so
    /// one report shows the full picture. Holds the sync lock across the
    /// writes so a stale snapshot cannot overwrite a newer one (the
    /// gauges must never go backwards).
    fn publish_sync_stats(&self) {
        let sync = lock_or_recover(&self.sync);
        let stats = sync.stats;
        self.metrics.set("sync_complete", stats.complete);
        self.metrics.set("sync_timed_out", stats.timed_out);
        self.metrics.set("sync_dropped", stats.dropped_frames);
        self.metrics.set("sync_late", stats.late_arrivals);
        self.metrics.set("sync_dup", stats.duplicates);
        self.metrics.set("sync_stale", stats.stale);
        self.metrics.set("sync_superseded", stats.superseded);
    }
}

/// Named sessions hosted by one process. Lookups are by the wire
/// `session` field; state, config, and metrics are isolated per entry.
#[derive(Default)]
pub struct SessionRegistry {
    sessions: Mutex<BTreeMap<String, Arc<DetectorSession>>>,
}

impl SessionRegistry {
    /// An empty registry.
    pub fn new() -> SessionRegistry {
        SessionRegistry::default()
    }

    /// Register a session under its own name, replacing any previous
    /// holder of that name. Returns the shared handle.
    pub fn insert(&self, session: DetectorSession) -> Arc<DetectorSession> {
        let arc = Arc::new(session);
        lock_or_recover(&self.sessions).insert(arc.name().to_string(), Arc::clone(&arc));
        arc
    }

    /// Look up a session by its wire name.
    pub fn get(&self, name: &str) -> Option<Arc<DetectorSession>> {
        lock_or_recover(&self.sessions).get(name).cloned()
    }

    /// Names of every hosted session, sorted.
    pub fn names(&self) -> Vec<String> {
        lock_or_recover(&self.sessions).keys().cloned().collect()
    }

    /// Number of hosted sessions.
    pub fn len(&self) -> usize {
        lock_or_recover(&self.sessions).len()
    }

    /// Whether the registry hosts no sessions.
    pub fn is_empty(&self) -> bool {
        lock_or_recover(&self.sessions).is_empty()
    }

    /// Poll every session for expired frames. The engine runs outside
    /// the registry lock.
    pub fn poll_all(&self) -> Vec<(String, Vec<SessionEvent>)> {
        let sessions: Vec<Arc<DetectorSession>> =
            lock_or_recover(&self.sessions).values().cloned().collect();
        sessions
            .into_iter()
            .map(|s| {
                let events = s.poll();
                (s.name().to_string(), events)
            })
            .collect()
    }

    /// Total frames completed across all sessions.
    pub fn frames_done_total(&self) -> u64 {
        lock_or_recover(&self.sessions).values().map(|s| s.frames_done()).sum()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    /// Backend with no models: every exec errors — which exercises the
    /// session's tail-error path without PJRT, artifacts, or weights.
    struct EmptyBackend;

    impl ExecBackend for EmptyBackend {
        fn backend_name(&self) -> &str {
            "empty"
        }
        fn exec(&self, name: &str, _inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            anyhow::bail!("model {name:?} not loaded")
        }
        fn load(&self, name: &str) -> Result<()> {
            anyhow::bail!("model {name:?} not loadable")
        }
        fn loaded_names(&self) -> Vec<String> {
            Vec::new()
        }
    }

    fn empty_backend() -> Arc<dyn ExecBackend> {
        Arc::new(EmptyBackend)
    }

    fn feat() -> HostTensor {
        let g = crate::config::GridConfig::default();
        HostTensor::zeros(&[g.dims[2], g.dims[1], g.dims[0], g.c_head])
    }

    /// A feature map with the wire channel count of `split`.
    fn feat_at(split: &str) -> HostTensor {
        let g = crate::config::GridConfig::default();
        HostTensor::zeros(&[g.dims[2], g.dims[1], g.dims[0], wire_channels(&g, split).unwrap()])
    }

    struct CollectSink {
        got: Arc<Mutex<Vec<(String, u64)>>>,
    }

    impl ResultSink for CollectSink {
        fn deliver(&mut self, session: &str, result: &FrameResult) -> Result<()> {
            self.got.lock().unwrap().push((session.to_string(), result.frame_id));
            Ok(())
        }
    }

    #[test]
    fn config_builder_defaults_and_overrides() {
        let cfg = SessionConfig::new(IntegrationKind::Max);
        assert_eq!(cfg.deadline, Duration::from_millis(200));
        assert_eq!(cfg.policy, LossPolicy::ZeroFill);
        assert!((cfg.decode.score_threshold - 0.25).abs() < 1e-9);

        let cfg = SessionConfig::new(IntegrationKind::ConvK1)
            .deadline(Duration::from_millis(50))
            .policy(LossPolicy::Drop)
            .decode(DecodeParams { score_threshold: 0.5, ..Default::default() });
        assert_eq!(cfg.variant, IntegrationKind::ConvK1);
        assert_eq!(cfg.deadline, Duration::from_millis(50));
        assert_eq!(cfg.policy, LossPolicy::Drop);
        assert!((cfg.decode.score_threshold - 0.5).abs() < 1e-9);
    }

    #[test]
    fn payload_decodes_raw_and_quantized() {
        let t = HostTensor::new(vec![4], vec![0.0, 0.5, 1.0, 1.5]).unwrap();
        let raw = FeaturePayload::from(t.clone());
        assert!(!raw.is_quantized());
        assert_eq!(raw.into_tensor().unwrap(), t);

        let q = FeaturePayload::from(crate::net::quantize(&t));
        assert!(q.is_quantized());
        assert!(q.wire_bytes() < t.byte_len());
        let back = q.into_tensor().unwrap();
        assert_eq!(back.shape, t.shape);
        for (a, b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() <= 1.5 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn session_completes_frame_and_delivers_to_sinks() {
        let backend = empty_backend();
        let meta = ModelMeta::test_default();
        let session = DetectorSession::new(
            "test",
            meta,
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
        )
        .unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        session.attach_sink(Box::new(CollectSink { got: Arc::clone(&got) }));

        let events = session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        assert!(events.is_empty(), "frame incomplete after one device");
        let events = session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(events.len(), 1);
        match &events[0] {
            SessionEvent::Result(r) => {
                assert_eq!(r.frame_id, 1);
                assert_eq!(r.present, vec![true, true]);
                // No models behind the backend: tail errors, frame still
                // completes with empty detections.
                assert!(r.tail_error);
                assert!(r.detections.is_empty());
            }
            other => panic!("expected Result, got {other:?}"),
        }
        assert_eq!(session.frames_done(), 1);
        assert_eq!(session.metrics().counter("frames_done"), 1);
        assert_eq!(session.metrics().counter("tail_errors"), 1);
        assert_eq!(session.metrics().counter("features_rx"), 2);
        assert_eq!(session.metrics().counter("sync_complete"), 1);
        assert_eq!(got.lock().unwrap().as_slice(), &[("test".to_string(), 1u64)]);
    }

    #[test]
    fn quantized_submission_counted_and_decoded() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "q",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
        )
        .unwrap();
        let q = crate::net::quantize(&feat());
        session.submit(3, 0, FeaturePayload::Quantized(q)).unwrap();
        assert_eq!(session.metrics().counter("features_rx_quantized"), 1);
        assert_eq!(session.metrics().counter("features_rx"), 1);
    }

    #[test]
    fn stamped_submissions_record_e2e_latency() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "e2e",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
        )
        .unwrap();
        let capture = crate::utils::unix_micros();
        session.submit_at(1, 0, FeaturePayload::Raw(feat()), capture).unwrap();
        let events = session.submit_at(1, 1, FeaturePayload::Raw(feat()), capture).unwrap();
        assert_eq!(events.len(), 1);
        match &events[0] {
            SessionEvent::Result(r) => assert_eq!(r.capture_micros, capture),
            other => panic!("expected Result, got {other:?}"),
        }
        let e2e = session.metrics().samples("e2e");
        assert_eq!(e2e.len(), 1, "stamped frame must record an e2e sample");
        assert!(e2e[0] >= 0.0 && e2e[0] < 60.0, "implausible e2e {}", e2e[0]);

        // Unstamped frames (legacy clients) record nothing.
        session.submit(2, 0, FeaturePayload::Raw(feat())).unwrap();
        session.submit(2, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(session.metrics().samples("e2e").len(), 1);
    }

    #[test]
    fn out_of_range_device_rejected_not_panicking() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "r",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max),
        )
        .unwrap();
        assert!(session.submit(1, 99, FeaturePayload::Raw(feat())).is_err());
        assert_eq!(session.metrics().counter("bad_device"), 1);
    }

    #[test]
    fn drop_policy_emits_dropped_event() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "d",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max)
                .deadline(Duration::from_millis(10))
                .policy(LossPolicy::Drop),
        )
        .unwrap();
        session.submit(5, 0, FeaturePayload::Raw(feat())).unwrap();
        std::thread::sleep(Duration::from_millis(25));
        let events = session.poll();
        assert_eq!(events.len(), 1);
        assert!(matches!(events[0], SessionEvent::Dropped { frame_id: 5 }));
        assert_eq!(session.frames_done(), 0);
        assert_eq!(session.metrics().counter("sync_dropped"), 1);
    }

    #[test]
    fn zero_fill_policy_completes_partial_frame() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "z",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max)
                .deadline(Duration::from_millis(10))
                .policy(LossPolicy::ZeroFill),
        )
        .unwrap();
        session.submit(6, 1, FeaturePayload::Raw(feat())).unwrap();
        std::thread::sleep(Duration::from_millis(25));
        let events = session.poll();
        assert_eq!(events.len(), 1);
        match &events[0] {
            SessionEvent::Result(r) => {
                assert_eq!(r.frame_id, 6);
                assert_eq!(r.present, vec![false, true]);
            }
            other => panic!("expected Result, got {other:?}"),
        }
        assert_eq!(session.metrics().counter("sync_timed_out"), 1);
    }

    #[test]
    fn latest_wins_session_never_integrates_stale_frames() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "lw",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max)
                .deadline(Duration::from_secs(60))
                .latest_wins(true),
        )
        .unwrap();
        session.submit(2, 0, FeaturePayload::Raw(feat())).unwrap();
        let events = session.submit(2, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(events.len(), 1, "newest frame completes normally");
        // Frame 1 arriving after frame 2 is stale on both devices: it
        // must never become a result, only a counted drop.
        session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        let events = session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert!(events.is_empty(), "stale frame must not resolve");
        assert_eq!(session.sync_stats().stale, 2);
        assert_eq!(session.frames_done(), 1);
    }

    #[test]
    fn abort_frame_releases_partial_submission() {
        let backend = empty_backend();
        let session = DetectorSession::new(
            "ab",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_millis(10)),
        )
        .unwrap();
        session.submit(9, 0, FeaturePayload::Raw(feat())).unwrap();
        assert!(session.abort_frame(9));
        assert!(!session.abort_frame(9));
        // The aborted frame never resolves — not even after its deadline.
        std::thread::sleep(Duration::from_millis(25));
        assert!(session.poll().is_empty());
        assert_eq!(session.frames_done(), 0);
    }

    #[test]
    fn failing_sink_is_detached() {
        struct FailSink;
        impl ResultSink for FailSink {
            fn deliver(&mut self, _s: &str, _r: &FrameResult) -> Result<()> {
                anyhow::bail!("broken pipe")
            }
        }
        let backend = empty_backend();
        let session = DetectorSession::new(
            "f",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
        )
        .unwrap();
        session.attach_sink(Box::new(FailSink));
        session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(session.sinks.lock().unwrap().len(), 0, "failed sink must detach");
    }

    #[test]
    fn panicking_sink_is_detached_without_poisoning_delivery() {
        // Regression: a sink that panics mid-deliver used to unwind with
        // the sinks mutex held, poisoning it — every later frame of the
        // session then panicked on `lock().unwrap()`. Now the panic is
        // contained, the sink detached, and healthy sinks keep receiving.
        struct PanicSink;
        impl ResultSink for PanicSink {
            fn deliver(&mut self, _s: &str, _r: &FrameResult) -> Result<()> {
                panic!("subscriber blew up mid-send");
            }
        }
        let backend = empty_backend();
        let session = DetectorSession::new(
            "p",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
        )
        .unwrap();
        let got = Arc::new(Mutex::new(Vec::new()));
        session.attach_sink(Box::new(PanicSink));
        session.attach_sink(Box::new(CollectSink { got: Arc::clone(&got) }));

        session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        let events = session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(events.len(), 1, "the frame must still complete");
        assert_eq!(session.sinks.lock().unwrap().len(), 1, "panicking sink detached");

        // The next frame delivers normally — the mutex is not poisoned.
        session.submit(2, 0, FeaturePayload::Raw(feat())).unwrap();
        session.submit(2, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(
            got.lock().unwrap().as_slice(),
            &[("p".to_string(), 1u64), ("p".to_string(), 2u64)],
            "healthy sink must receive every frame"
        );
    }

    #[test]
    fn registry_isolates_sessions() {
        let backend = empty_backend();
        let registry = SessionRegistry::new();
        let a = registry.insert(
            DetectorSession::new(
                "a",
                ModelMeta::test_default(),
                backend.clone(),
                SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
            )
            .unwrap(),
        );
        let b = registry.insert(
            DetectorSession::new(
                "b",
                ModelMeta::test_default(),
                backend,
                SessionConfig::new(IntegrationKind::ConvK3).deadline(Duration::from_secs(60)),
            )
            .unwrap(),
        );
        assert_eq!(registry.names(), vec!["a".to_string(), "b".to_string()]);
        assert!(registry.get("missing").is_none());

        a.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        a.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(a.frames_done(), 1);
        assert_eq!(b.frames_done(), 0, "traffic must not leak across sessions");
        assert_eq!(a.metrics().counter("features_rx"), 2);
        assert_eq!(b.metrics().counter("features_rx"), 0);
        assert_eq!(registry.frames_done_total(), 1);

        // poll_all touches both without cross-talk.
        let polled = registry.poll_all();
        assert_eq!(polled.len(), 2);
        assert!(polled.iter().all(|(_, ev)| ev.is_empty()));
    }

    #[test]
    fn session_name_validation() {
        let backend = empty_backend();
        assert!(DetectorSession::new(
            "",
            ModelMeta::test_default(),
            backend.clone(),
            SessionConfig::new(IntegrationKind::Max),
        )
        .is_err());
        let long = "x".repeat(300);
        assert!(DetectorSession::new(
            &long,
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::Max),
        )
        .is_err());
    }

    #[test]
    fn split_selects_the_depth_specific_tail() {
        let backend = empty_backend();
        // Default (empty) split: the bare tail name — byte-identical to
        // a pre-split session.
        let s = DetectorSession::new(
            "d",
            ModelMeta::test_default(),
            backend.clone(),
            SessionConfig::new(IntegrationKind::Max),
        )
        .unwrap();
        assert_eq!(s.tail_name(), "tail_max");
        assert_eq!(s.split(), crate::config::SPLIT_MID);

        let s = DetectorSession::new(
            "deep",
            ModelMeta::test_default(),
            backend.clone(),
            SessionConfig::new(IntegrationKind::ConvK3).split(SPLIT_DEEP),
        )
        .unwrap();
        assert_eq!(s.tail_name(), "tail_conv_k3@split-deep");
        assert_eq!(s.split(), SPLIT_DEEP);
        assert_eq!(
            s.shed_tail_name(),
            "tail_max@split-deep",
            "shed tail is the Max variant at the *same* depth"
        );

        assert!(
            DetectorSession::new(
                "bogus",
                ModelMeta::test_default(),
                backend,
                SessionConfig::new(IntegrationKind::Max).split("split-nowhere"),
            )
            .is_err(),
            "unknown split depth must be rejected at build time"
        );
    }

    #[test]
    fn mixed_split_sessions_coexist_in_one_registry() {
        // One server process hosts sessions at different depths; each
        // synchronizes feature maps of its own wire channel count and
        // traffic never leaks across.
        let backend = empty_backend();
        let registry = SessionRegistry::new();
        let mid = registry.insert(
            DetectorSession::new(
                "mid",
                ModelMeta::test_default(),
                backend.clone(),
                SessionConfig::new(IntegrationKind::Max).deadline(Duration::from_secs(60)),
            )
            .unwrap(),
        );
        let deep = registry.insert(
            DetectorSession::new(
                "deep",
                ModelMeta::test_default(),
                backend.clone(),
                SessionConfig::new(IntegrationKind::Max)
                    .deadline(Duration::from_secs(60))
                    .split(SPLIT_DEEP),
            )
            .unwrap(),
        );
        let shallow = registry.insert(
            DetectorSession::new(
                "shallow",
                ModelMeta::test_default(),
                backend,
                SessionConfig::new(IntegrationKind::Max)
                    .deadline(Duration::from_secs(60))
                    .split(SPLIT_SHALLOW),
            )
            .unwrap(),
        );
        assert_ne!(
            feat_at(SPLIT_DEEP).shape,
            feat_at(SPLIT_SHALLOW).shape,
            "depths must differ in wire shape for this test to bite"
        );
        for (s, split) in [
            (&mid, crate::config::SPLIT_MID),
            (&deep, SPLIT_DEEP),
            (&shallow, SPLIT_SHALLOW),
        ] {
            s.submit(1, 0, FeaturePayload::Raw(feat_at(split))).unwrap();
            let events = s.submit(1, 1, FeaturePayload::Raw(feat_at(split))).unwrap();
            assert_eq!(events.len(), 1, "session at {split} must complete its frame");
            assert_eq!(s.frames_done(), 1);
        }
        assert_eq!(mid.metrics().counter("split_mid"), 1);
        assert_eq!(deep.metrics().counter("split_deep"), 1);
        assert_eq!(shallow.metrics().counter("split_shallow"), 1);
        assert_eq!(mid.metrics().counter("split_deep"), 0, "counters stay per-session");
        assert_eq!(registry.frames_done_total(), 3);
    }

    #[test]
    fn below_watermark_keeps_the_normal_path() {
        // A watermark-armed session with no pressure must behave
        // byte-identically to a shedding-free one: normal tail, normal
        // decode params, zero shed counters.
        let backend = empty_backend();
        let session = DetectorSession::new(
            "calm",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::ConvK3)
                .deadline(Duration::from_secs(60))
                .shed_watermark(4),
        )
        .unwrap();
        session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        let events = session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(session.metrics().counter("shed_frames"), 0);
        assert_eq!(session.metrics().counter("shed_batches"), 0);
        // Without a planner there is no queue to overflow: even an
        // armed watermark never sheds.
        assert!(!session.should_shed());
    }

    #[test]
    fn shed_decode_params_are_coarser_never_finer() {
        let d = DecodeParams::default();
        let s = shed_decode_params(&d);
        assert!(s.score_threshold >= d.score_threshold);
        assert!(s.pre_nms_top_k <= d.pre_nms_top_k);
        assert!(s.max_detections <= d.max_detections);
        // Already-coarse params are left alone, not made finer.
        let coarse = DecodeParams {
            score_threshold: 0.9,
            pre_nms_top_k: 8,
            nms_iou: 0.25,
            max_detections: 4,
        };
        let s = shed_decode_params(&coarse);
        assert!((s.score_threshold - 0.9).abs() < 1e-9);
        assert_eq!(s.pre_nms_top_k, 32, "floor keeps decode functional");
        assert_eq!(s.max_detections, 16);
    }

    #[test]
    fn watermark_shedding_fires_under_queue_pressure() {
        // Hold the shared planner's queue at depth 1 (a lone request
        // waiting out its collection window), then resolve a frame on a
        // watermark-1 session: it must shed — counted, degraded, never
        // rejected.
        let backend = empty_backend();
        let planner = BatchPlanner::new(
            Arc::clone(&backend),
            super::super::scheduler::BatchConfig {
                window: Duration::from_millis(600),
                max_batch: 4,
                max_pending: 64,
            },
        );
        let mut session = DetectorSession::new(
            "hot",
            ModelMeta::test_default(),
            backend,
            SessionConfig::new(IntegrationKind::ConvK3)
                .deadline(Duration::from_secs(60))
                .shed_watermark(1),
        )
        .unwrap();
        session.set_batch_planner(Arc::clone(&planner));
        let session = Arc::new(session);

        let p2 = Arc::clone(&planner);
        let occupant = std::thread::spawn(move || {
            // Errors (EmptyBackend has no models) still resolve the
            // request; only the queue residency matters here.
            let _ = p2.exec("other", "occupant", vec![HostTensor::zeros(&[1])]);
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while planner.queue_depth() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert!(planner.queue_depth() >= 1, "occupant never reached the queue");

        session.submit(1, 0, FeaturePayload::Raw(feat())).unwrap();
        let events = session.submit(1, 1, FeaturePayload::Raw(feat())).unwrap();
        occupant.join().unwrap();
        assert_eq!(events.len(), 1, "shed frames complete, they are not rejected");
        assert_eq!(session.metrics().counter("shed_frames"), 1);
        assert_eq!(session.metrics().counter("shed_batches"), 1);
        assert_eq!(session.metrics().counter("frames_done"), 1);
    }
}
