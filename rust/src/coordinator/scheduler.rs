//! Frame synchronizer and the cross-session batch planner.
//!
//! [`FrameSync`] pairs per-device intermediate outputs by frame id before
//! integration. The paper's inference flow assumes both devices' features
//! arrive for a frame; real links lose or delay messages, so the
//! synchronizer adds a deadline and a configurable policy for incomplete
//! frames — the robustness direction §IV-E calls out ("systems designed
//! to tolerate partial data loss without retransmission").
//!
//! [`BatchPlanner`] is the server-side throughput complement: it
//! coalesces **compatible tail executions** — same executable, same input
//! shapes — arriving within a configurable window across sessions and
//! frames into one stacked [`ExecBackend::exec_batch`] call, so the
//! steady-state backend cost per frame drops from one round-trip to
//! ~1/B of one under fleet load.

use crate::metrics::Metrics;
use crate::runtime::{ExecBackend, HostTensor};
use anyhow::Result;
use std::collections::{BTreeSet, HashMap};
use crate::sync::time::Instant;
use crate::sync::{lock_or_recover, wait_timeout_or_recover, Arc, Condvar, Mutex};
use std::time::Duration;

/// What to do when the deadline fires with devices missing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossPolicy {
    /// Drop the frame entirely.
    Drop,
    /// Run the tail with zero-filled features for missing devices
    /// (integration methods degrade gracefully: max treats zeros as
    /// "no evidence"; conv was trained with both inputs but remains
    /// usable — the Table-III-style ablation quantifies the hit).
    ZeroFill,
}

impl LossPolicy {
    /// Canonical CLI/JSON spelling (matches `scmii serve --policy`).
    pub fn name(&self) -> &'static str {
        match self {
            LossPolicy::Drop => "drop",
            LossPolicy::ZeroFill => "zero-fill",
        }
    }

    /// Parse the CLI/JSON spelling (`"zero-fill"` | `"drop"`).
    pub fn parse(s: &str) -> anyhow::Result<LossPolicy> {
        match s {
            "drop" => Ok(LossPolicy::Drop),
            "zero-fill" => Ok(LossPolicy::ZeroFill),
            other => anyhow::bail!("unknown loss policy {other:?} (expected zero-fill|drop)"),
        }
    }
}

/// A completed (or force-completed) frame ready for the tail model.
#[derive(Debug)]
pub struct ReadyFrame {
    /// Frame id the devices stamped on their intermediate outputs.
    pub frame_id: u64,
    /// Per-device features; `None` only under `ZeroFill` accounting
    /// (already replaced by zeros in `tensors`).
    pub tensors: Vec<HostTensor>,
    /// Devices that actually contributed.
    pub present: Vec<bool>,
    /// Arrival of the first device's features (latency accounting).
    pub first_arrival: Instant,
    /// Earliest device capture stamp (wall-clock µs; 0 = no device
    /// stamped this frame). End-to-end latency accounting rides on it.
    pub capture_micros: u64,
}

struct Pending {
    slots: Vec<Option<HostTensor>>,
    first_arrival: Instant,
    /// Earliest non-zero capture stamp seen for this frame.
    capture_micros: u64,
}

/// How long an emission record is kept to classify late arrivals.
const DEFAULT_EMITTED_HORIZON: Duration = Duration::from_secs(30);

/// The synchronizer. Not thread-safe by itself — wrap in a `Mutex`.
pub struct FrameSync {
    n_devices: usize,
    deadline: Duration,
    policy: LossPolicy,
    /// Shape used for zero-fill when a device never reported.
    feature_shape: Vec<usize>,
    pending: HashMap<u64, Pending>,
    /// Frames already emitted (late arrivals for these are dropped).
    emitted: HashMap<u64, Instant>,
    /// Retention window for `emitted` records.
    emitted_horizon: Duration,
    /// Latest-wins mode (datagram transport): a device's newer frame
    /// makes its older submissions stale, and a pending frame that every
    /// missing device has moved past is superseded — discarded without
    /// emitting. Off by default; the TCP path is untouched.
    latest_wins: bool,
    /// Per-device newest frame id accepted (latest-wins bookkeeping).
    newest: Vec<Option<u64>>,
    /// Frame ids discarded under [`LossPolicy::Drop`], awaiting collection.
    dropped_log: Vec<u64>,
    /// Running counters (reads are cheap; the session mirrors them into
    /// its metrics).
    pub stats: SyncStats,
}

/// Counters for observability / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncStats {
    /// Frames emitted with every device present.
    pub complete: u64,
    /// Frames resolved by deadline expiry (either policy).
    pub timed_out: u64,
    /// Frames discarded under [`LossPolicy::Drop`].
    pub dropped_frames: u64,
    /// Submissions for frames already emitted (ignored).
    pub late_arrivals: u64,
    /// Repeat submissions for a (frame, device) slot (ignored).
    pub duplicates: u64,
    /// Latest-wins only: submissions older than the device's newest
    /// accepted frame (counted and dropped, never integrated).
    pub stale: u64,
    /// Latest-wins only: pending frames discarded because every missing
    /// device had already reported a newer frame.
    pub superseded: u64,
}

impl FrameSync {
    /// Build a synchronizer for `n_devices` devices; incomplete frames
    /// resolve per `policy` once `deadline` has passed since their first
    /// arrival, zero-filling with `feature_shape` when no sibling tensor
    /// is available.
    pub fn new(
        n_devices: usize,
        deadline: Duration,
        policy: LossPolicy,
        feature_shape: Vec<usize>,
    ) -> FrameSync {
        FrameSync {
            n_devices,
            deadline,
            policy,
            feature_shape,
            pending: HashMap::new(),
            emitted: HashMap::new(),
            emitted_horizon: DEFAULT_EMITTED_HORIZON,
            latest_wins: false,
            newest: vec![None; n_devices],
            dropped_log: Vec::new(),
            stats: SyncStats::default(),
        }
    }

    /// Override the retention window for emission records (tests and
    /// high-frame-rate deployments).
    pub fn set_emitted_horizon(&mut self, horizon: Duration) {
        self.emitted_horizon = horizon;
    }

    /// Enable latest-wins replacement (the datagram transport's
    /// semantic): a submission older than its device's newest accepted
    /// frame is counted [`SyncStats::stale`] and dropped, and a pending
    /// frame is discarded ([`SyncStats::superseded`]) the moment every
    /// device still missing from it has reported a newer frame — fresher
    /// data replaced it, so it is *not* emitted, *not* logged as a
    /// deadline drop, and leaves no emission record. Off by default: the
    /// in-order TCP path keeps its exact historical behavior.
    pub fn set_latest_wins(&mut self, on: bool) {
        self.latest_wins = on;
    }

    /// Register features from a device. Returns the frame when complete.
    pub fn add(&mut self, frame_id: u64, device_id: usize, tensor: HostTensor) -> Option<ReadyFrame> {
        self.add_at(frame_id, device_id, tensor, 0)
    }

    /// [`add`](Self::add) with the device's capture stamp (wall-clock µs;
    /// 0 = unstamped). The emitted frame carries the *earliest* stamp —
    /// end-to-end latency is measured from the first capture.
    pub fn add_at(
        &mut self,
        frame_id: u64,
        device_id: usize,
        tensor: HostTensor,
        capture_micros: u64,
    ) -> Option<ReadyFrame> {
        assert!(device_id < self.n_devices, "device {device_id} out of range");
        if self.emitted.contains_key(&frame_id) {
            self.stats.late_arrivals += 1;
            return None;
        }
        if self.latest_wins {
            if self.newest[device_id].map_or(false, |n| frame_id < n) {
                self.stats.stale += 1;
                return None;
            }
            if self.newest[device_id].map_or(true, |n| frame_id > n) {
                self.newest[device_id] = Some(frame_id);
                self.gc_superseded();
            }
        }
        let pending = self.pending.entry(frame_id).or_insert_with(|| Pending {
            slots: vec![None; self.n_devices],
            first_arrival: Instant::now(),
            capture_micros: 0,
        });
        if pending.slots[device_id].is_some() {
            self.stats.duplicates += 1;
            return None;
        }
        pending.slots[device_id] = Some(tensor);
        if capture_micros > 0
            && (pending.capture_micros == 0 || capture_micros < pending.capture_micros)
        {
            pending.capture_micros = capture_micros;
        }
        if pending.slots.iter().all(|s| s.is_some()) {
            let pending = self.pending.remove(&frame_id).unwrap();
            self.emitted.insert(frame_id, Instant::now());
            self.gc_emitted();
            self.stats.complete += 1;
            return Some(ReadyFrame {
                frame_id,
                present: vec![true; self.n_devices],
                tensors: pending.slots.into_iter().map(|s| s.unwrap()).collect(),
                first_arrival: pending.first_arrival,
                capture_micros: pending.capture_micros,
            });
        }
        None
    }

    /// Collect frames whose deadline has expired, resolving them per the
    /// loss policy. Call periodically (the server does so between reads).
    pub fn poll_expired(&mut self) -> Vec<ReadyFrame> {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .pending
            .iter()
            .filter(|(_, p)| now.duration_since(p.first_arrival) >= self.deadline)
            .map(|(&id, _)| id)
            .collect();
        let mut out = Vec::new();
        for id in expired {
            let pending = self.pending.remove(&id).unwrap();
            self.emitted.insert(id, now);
            match self.policy {
                LossPolicy::Drop => {
                    self.stats.timed_out += 1;
                    self.stats.dropped_frames += 1;
                    self.dropped_log.push(id);
                }
                LossPolicy::ZeroFill => {
                    self.stats.timed_out += 1;
                    let present: Vec<bool> =
                        pending.slots.iter().map(|s| s.is_some()).collect();
                    // Zero-fill with the shape of a present sibling tensor
                    // when one exists: arrived payloads may legitimately
                    // differ from the configured shape (e.g. quantized→
                    // dequantized tensors with trimmed dims), and the tail
                    // needs every device input to agree.
                    let fill_shape: Vec<usize> = pending
                        .slots
                        .iter()
                        .find_map(|s| s.as_ref().map(|t| t.shape.clone()))
                        .unwrap_or_else(|| self.feature_shape.clone());
                    let tensors: Vec<HostTensor> = pending
                        .slots
                        .into_iter()
                        .map(|s| s.unwrap_or_else(|| HostTensor::zeros(&fill_shape)))
                        .collect();
                    out.push(ReadyFrame {
                        frame_id: id,
                        tensors,
                        present,
                        first_arrival: pending.first_arrival,
                        capture_micros: pending.capture_micros,
                    });
                }
            }
        }
        self.gc_emitted();
        out
    }

    /// Number of frames currently buffered awaiting devices.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Number of retained emission records (observability / tests).
    pub fn emitted_len(&self) -> usize {
        self.emitted.len()
    }

    /// Drain the frame ids discarded under [`LossPolicy::Drop`] since the
    /// last call (the session core turns these into `Dropped` events).
    pub fn take_dropped(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.dropped_log)
    }

    /// Discard a pending frame and its buffered tensors without emitting
    /// anything (a frontend abandoned the frame mid-submission). Returns
    /// whether the frame was pending.
    pub fn abort(&mut self, frame_id: u64) -> bool {
        self.pending.remove(&frame_id).is_some()
    }

    /// Latest-wins gc: discard pending frames no future input can
    /// complete — every device still missing from them has already
    /// reported a newer frame, so their remaining slots can only ever
    /// see stale submissions. Superseded frames are counted and
    /// dropped silently: no emission record (`emitted_len` must not
    /// grow) and no entry in the deadline drop log (`take_dropped`
    /// reports frames *lost* at a deadline, not frames replaced by
    /// fresher data).
    fn gc_superseded(&mut self) {
        let newest = &self.newest;
        let superseded: Vec<u64> = self
            .pending
            .iter()
            .filter(|(&id, p)| {
                p.slots
                    .iter()
                    .enumerate()
                    .all(|(d, s)| s.is_some() || newest[d].map_or(false, |n| n > id))
            })
            .map(|(&id, _)| id)
            .collect();
        for id in superseded {
            self.pending.remove(&id);
            self.stats.superseded += 1;
        }
    }

    fn gc_emitted(&mut self) {
        // Bound memory: forget emission records past the horizon. This must
        // run on time, not on size — a slow trickle of frames would
        // otherwise grow `emitted` unboundedly below any size threshold.
        if self.emitted.is_empty() {
            return;
        }
        if let Some(cutoff) = Instant::now().checked_sub(self.emitted_horizon) {
            self.emitted.retain(|_, t| *t > cutoff);
        }
    }
}

// ---------------------------------------------------------------------
// Cross-session micro-batching
// ---------------------------------------------------------------------

/// Tuning for the coordinator's cross-session micro-batching
/// (`scmii serve --batch-window-ms --max-batch`).
#[derive(Clone, Copy, Debug)]
pub struct BatchConfig {
    /// Collection window: how long the first request of a batch waits for
    /// compatible company before the batch executes. A lone request pays
    /// the **full window as added tail latency** — batching deliberately
    /// trades light-load latency for per-call efficiency under fleet
    /// load, so keep the window small relative to the frame period (a
    /// saturated bucket never waits: a full batch executes immediately).
    pub window: Duration,
    /// Upper bound on requests coalesced into one backend call. `<= 1`
    /// disables batching entirely: requests go straight to the backend on
    /// the caller's thread — byte-identical to the unbatched serving
    /// path.
    pub max_batch: usize,
    /// Admission control: maximum requests queued in the planner across
    /// all buckets. Requests beyond it are rejected (the frame completes
    /// with a tail error) instead of growing the queue without bound
    /// under overload.
    pub max_pending: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig { window: Duration::from_millis(2), max_batch: 1, max_pending: 256 }
    }
}

/// Requests are stackable when they run the same executable on
/// identically-shaped inputs.
type BatchKey = (String, Vec<Vec<usize>>);

/// Per-request reply slot: the batch leader fills it (under the planner
/// state lock), the owner polls it from the shared wait loop.
struct ReplySlot {
    result: Mutex<Option<Result<Vec<HostTensor>>>>,
}

/// One request waiting to be batched.
struct BatchReq {
    session: String,
    inputs: Vec<HostTensor>,
    slot: Arc<ReplySlot>,
}

/// Requests compatible with one executable+shape signature.
struct Bucket {
    queue: Vec<BatchReq>,
    /// Whether a leader thread is currently in this bucket's COLLECTION
    /// phase (at most one collector at a time; released at drain, so
    /// execution of one batch overlaps collection of the next).
    collecting: bool,
}

struct PlannerState {
    buckets: HashMap<BatchKey, Bucket>,
    /// Total queued requests across buckets (admission control).
    pending: usize,
}

/// Coalesces compatible tail executions arriving within a window across
/// sessions and frames into one stacked [`ExecBackend::exec_batch`]
/// call.
///
/// Leader/follower scheme, no dedicated thread: every caller parks in
/// one shared wait loop; a caller that finds its bucket unled takes
/// **leadership for exactly one batch** — wait out the window (or until
/// the bucket holds [`BatchConfig::max_batch`] requests, whichever comes
/// first), drain with per-session fairness, execute, distribute — and
/// releases leadership *at drain time*, so the next leader can collect
/// and launch a batch while this one executes (a hot bucket keeps the
/// whole backend busy; batching never caps in-flight frames at
/// `max_batch`). A caller returns as soon as its own result is ready
/// and is never held captive serving other sessions' backlogs (each
/// queued request has its own blocked caller thread to lead the batch
/// that serves it), while a saturated bucket batches continuously: the
/// moment it holds `max_batch` requests, the next leader's collection
/// phase is instant.
///
/// With `max_batch <= 1` the planner is a transparent pass-through to
/// [`ExecBackend::exec`] — outputs are byte-identical to the unbatched
/// path.
pub struct BatchPlanner {
    backend: Arc<dyn ExecBackend>,
    cfg: BatchConfig,
    state: Mutex<PlannerState>,
    /// Paired with `state`: wakes parked callers on enqueue (a gathering
    /// leader may now have a full batch) and after each batch (slots
    /// filled, leadership free).
    cv: Condvar,
    metrics: Arc<Metrics>,
}

impl BatchPlanner {
    /// Build a planner over `backend` (shared by every session routing
    /// tails through it).
    pub fn new(backend: Arc<dyn ExecBackend>, cfg: BatchConfig) -> Arc<BatchPlanner> {
        Arc::new(BatchPlanner {
            backend,
            cfg,
            state: Mutex::new(PlannerState { buckets: HashMap::new(), pending: 0 }),
            cv: Condvar::new(),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// The configuration this planner runs with.
    pub fn config(&self) -> &BatchConfig {
        &self.cfg
    }

    /// Whether batching is actually on (`max_batch > 1`).
    pub fn enabled(&self) -> bool {
        self.cfg.max_batch > 1
    }

    /// Planner observability: counters `batch_backend_calls`,
    /// `batch_frames`, `batch_rejected`, gauge `batch_pending`, series
    /// `batch_occupancy` (requests per backend call) and
    /// `batch_queue_depth` (queue depth sampled at each enqueue).
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Requests enqueued but not yet resolved, sampled now. Sessions use
    /// this as the overload signal for watermark shedding: the value is
    /// advisory (another thread may drain the queue between the read and
    /// the shed decision), which is fine — shedding is a pressure valve,
    /// not an admission-control invariant.
    pub fn queue_depth(&self) -> usize {
        lock_or_recover(&self.state).pending
    }

    /// Execute `inputs` on `name`, possibly coalesced with compatible
    /// requests from other sessions/frames. Blocks until this request's
    /// result is available — one collection window plus the batch
    /// execution in the common case; under sustained overload at most a
    /// few round-robin sweeps until the fairness drain reaches this
    /// request's session, never other sessions' entire backlog.
    ///
    /// `session` is the fairness key: when a bucket holds more requests
    /// than fit one batch, the drain round-robins across sessions so one
    /// chatty device fleet cannot starve the others.
    pub fn exec(
        &self,
        session: &str,
        name: &str,
        inputs: Vec<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        self.exec_many(session, name, vec![inputs])
            .pop()
            .expect("exec_many returns one result per entry")
    }

    /// [`exec`](Self::exec) over several input sets from **one caller** —
    /// one result per entry, order preserved. All entries are enqueued
    /// before any waiting happens, so they coalesce with *each other* as
    /// well as with concurrent traffic: a burst of K deadline-expired
    /// frames resolved by one polling thread becomes ceil(K/max_batch)
    /// stacked backend calls sharing one collection window, instead of K
    /// sequential batch-of-1 calls each paying the window (sequential
    /// `exec` calls from one thread can never be their own batch-mates).
    pub fn exec_many(
        &self,
        session: &str,
        name: &str,
        batch: Vec<Vec<HostTensor>>,
    ) -> Vec<Result<Vec<HostTensor>>> {
        if batch.is_empty() {
            return Vec::new();
        }
        if self.cfg.max_batch <= 1 {
            // Pass-through: same thread, same backend calls, bit-identical
            // outputs to the pre-batching server.
            return batch.into_iter().map(|inputs| self.backend.exec(name, inputs)).collect();
        }

        /// One entry's fate: rejected at admission, or parked in a bucket.
        enum Entry {
            Rejected(anyhow::Error),
            Pending { key: BatchKey, slot: Arc<ReplySlot> },
        }

        // Enqueue every entry under one lock acquisition so the whole
        // burst is visible to the first leader.
        let mut entries: Vec<Entry> = Vec::with_capacity(batch.len());
        {
            let mut st = lock_or_recover(&self.state);
            for inputs in batch {
                if st.pending >= self.cfg.max_pending {
                    self.metrics.incr("batch_rejected", 1);
                    entries.push(Entry::Rejected(anyhow::anyhow!(
                        "batch planner queue full ({} pending ≥ {} max); tail request for {name:?} rejected",
                        st.pending,
                        self.cfg.max_pending
                    )));
                    continue;
                }
                st.pending += 1;
                self.metrics.record("batch_queue_depth", st.pending as f64);
                self.metrics.set("batch_pending", st.pending as u64);
                let key: BatchKey =
                    (name.to_string(), inputs.iter().map(|t| t.shape.clone()).collect());
                let slot = Arc::new(ReplySlot { result: Mutex::new(None) });
                st.buckets
                    .entry(key.clone())
                    .or_insert_with(|| Bucket { queue: Vec::new(), collecting: false })
                    .queue
                    .push(BatchReq {
                        session: session.to_string(),
                        inputs,
                        slot: Arc::clone(&slot),
                    });
                entries.push(Entry::Pending { key, slot });
            }
            // A gathering leader may now have a full batch.
            self.cv.notify_all();
        }

        // Shared wait loop: return once every slot is filled; while any
        // isn't, take leadership (for one batch) of the first of our
        // unled buckets. Slots are filled under the state lock, so
        // checking under it cannot miss a wakeup.
        loop {
            let st = lock_or_recover(&self.state);
            let mut lead_key: Option<BatchKey> = None;
            let mut any_unfilled = false;
            for entry in &entries {
                if let Entry::Pending { key, slot } = entry {
                    if lock_or_recover(&slot.result).is_some() {
                        continue;
                    }
                    any_unfilled = true;
                    if lead_key.is_none()
                        && st
                            .buckets
                            .get(key)
                            .map_or(false, |b| !b.collecting && !b.queue.is_empty())
                    {
                        lead_key = Some(key.clone());
                    }
                }
            }
            if !any_unfilled {
                break;
            }
            if let Some(key) = lead_key {
                let mut st = st;
                st.buckets.get_mut(&key).expect("bucket checked above").collecting = true;
                drop(st);
                self.lead_one_batch(&key);
                continue;
            }
            // Timeout is a defensive backstop only — every state change
            // that matters notifies the condvar.
            let _ = wait_timeout_or_recover(&self.cv, st, Duration::from_millis(100));
        }

        entries
            .into_iter()
            .map(|entry| match entry {
                Entry::Rejected(err) => Err(err),
                Entry::Pending { slot, .. } => {
                    lock_or_recover(&slot.result).take().expect("slot filled before exit")
                }
            })
            .collect()
    }

    /// One leadership turn over a bucket: collect until the window
    /// expires or the bucket holds a full batch, drain fairly (releasing
    /// leadership at drain, so the next batch can collect while this one
    /// executes), execute, distribute. Never serves more than one batch
    /// — remaining requests are led by their own caller threads.
    fn lead_one_batch(&self, key: &BatchKey) {
        // Collect: wait out the window unless the bucket fills first.
        let deadline = Instant::now() + self.cfg.window;
        let mut st = lock_or_recover(&self.state);
        loop {
            let len = st.buckets.get(key).map_or(0, |b| b.queue.len());
            if len >= self.cfg.max_batch {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            st = wait_timeout_or_recover(&self.cv, st, deadline - now);
        }
        let taken = {
            let bucket = st.buckets.get_mut(key).expect("leader owns a live bucket");
            let taken = drain_fair(&mut bucket.queue, self.cfg.max_batch);
            // Leadership guards only the COLLECTION phase: release it at
            // drain time, before executing, so another caller can gather
            // and launch the next batch while this one runs on the
            // backend — a hot bucket keeps the whole backend busy instead
            // of capping in-flight frames at max_batch.
            bucket.collecting = false;
            if bucket.queue.is_empty() {
                // Drop empty buckets so shape churn doesn't grow the map.
                st.buckets.remove(key);
            }
            st.pending -= taken.len();
            self.metrics.set("batch_pending", st.pending as u64);
            taken
        };
        drop(st);
        // Wake waiters: the bucket is leaderless again (and may still
        // hold requests for the next leader).
        self.cv.notify_all();

        let mut filled = Vec::new();
        if !taken.is_empty() {
            self.metrics.incr("batch_backend_calls", 1);
            self.metrics.incr("batch_frames", taken.len() as u64);
            self.metrics.record("batch_occupancy", taken.len() as f64);
            let (slots, batch): (Vec<Arc<ReplySlot>>, Vec<Vec<HostTensor>>) =
                taken.into_iter().map(|r| (r.slot, r.inputs)).unzip();
            let name = &key.0;
            // A panicking backend must not strand the waiters on their
            // slots: convert the panic into per-entry errors.
            let mut results = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.backend.exec_batch(name, batch)
            }))
            .unwrap_or_else(|_| {
                (0..slots.len())
                    .map(|_| {
                        Err(anyhow::anyhow!(
                            "backend panicked executing a batch of {name:?}"
                        ))
                    })
                    .collect()
            });
            // Backend contract is one result per entry; guard anyway so a
            // short reply cannot hang a waiter forever.
            while results.len() < slots.len() {
                results.push(Err(anyhow::anyhow!(
                    "backend returned too few results for a batch of {name:?}"
                )));
            }
            filled = slots.into_iter().zip(results).collect();
        }

        // Distribute under the state lock, so waiters checking their
        // slots cannot miss the wakeup. (Leadership was already handed
        // back at drain time.)
        let _st = lock_or_recover(&self.state);
        for (slot, result) in filled {
            *lock_or_recover(&slot.result) = Some(result);
        }
        self.cv.notify_all();
    }
}

/// Take up to `max` requests from `queue`, round-robin across sessions
/// (FIFO within each session), so one chatty session cannot monopolize a
/// batch while others wait.
fn drain_fair(queue: &mut Vec<BatchReq>, max: usize) -> Vec<BatchReq> {
    if queue.len() <= max {
        return std::mem::take(queue);
    }
    let mut taken = Vec::with_capacity(max);
    while taken.len() < max {
        // One sweep: each distinct session's oldest remaining request.
        let mut served: BTreeSet<String> = BTreeSet::new();
        let mut i = 0;
        let before = taken.len();
        while i < queue.len() && taken.len() < max {
            if served.insert(queue[i].session.clone()) {
                taken.push(queue.remove(i));
            } else {
                i += 1;
            }
        }
        if taken.len() == before {
            break;
        }
    }
    taken
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn t() -> HostTensor {
        HostTensor::zeros(&[2, 2])
    }

    #[test]
    fn completes_when_all_devices_report() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        let ready = s.add(1, 1, t()).unwrap();
        assert_eq!(ready.frame_id, 1);
        assert_eq!(ready.tensors.len(), 2);
        assert_eq!(ready.present, vec![true, true]);
        assert_eq!(s.stats.complete, 1);
        assert_eq!(s.pending_len(), 0);
    }

    #[test]
    fn interleaved_frames() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        assert!(s.add(2, 0, t()).is_none());
        assert!(s.add(2, 1, t()).is_some());
        assert!(s.add(1, 1, t()).is_some());
    }

    #[test]
    fn duplicate_device_report_counted() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        assert!(s.add(1, 0, t()).is_none());
        assert_eq!(s.stats.duplicates, 1);
    }

    #[test]
    fn timeout_drop_policy() {
        let mut s = FrameSync::new(2, Duration::from_millis(10), LossPolicy::Drop, vec![2, 2]);
        s.add(5, 0, t());
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert!(ready.is_empty());
        assert_eq!(s.stats.dropped_frames, 1);
        // late arrival after emission is ignored
        assert!(s.add(5, 1, t()).is_none());
        assert_eq!(s.stats.late_arrivals, 1);
    }

    #[test]
    fn timeout_zero_fill_policy() {
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(5, 1, t());
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].present, vec![false, true]);
        assert_eq!(ready[0].tensors.len(), 2);
        assert!(ready[0].tensors[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn emitted_records_gc_on_time_basis() {
        // Regression: gc must fire below the old 4096-entry threshold —
        // emission records older than the horizon are forgotten on the
        // next add/poll even with only a handful of frames in flight.
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        s.set_emitted_horizon(Duration::from_millis(30));
        for id in 0..8u64 {
            s.add(id, 0, t());
            s.add(id, 1, t());
        }
        assert!(s.emitted_len() > 0);
        std::thread::sleep(Duration::from_millis(60));
        // Any synchronizer activity past the horizon triggers the gc.
        s.add(100, 0, t());
        s.add(100, 1, t());
        assert!(
            s.emitted_len() <= 1,
            "stale emission records must be collected, have {}",
            s.emitted_len()
        );
    }

    #[test]
    fn zero_fill_matches_present_sibling_shape() {
        // Regression: a frame whose arrived tensor has a different shape
        // than the configured feature_shape (e.g. a trimmed quantized
        // payload) must be zero-filled to the *sibling's* shape, not the
        // configured one — the tail needs agreeing device inputs.
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(9, 1, HostTensor::zeros(&[3, 5]));
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].present, vec![false, true]);
        assert_eq!(ready[0].tensors[0].shape, vec![3, 5], "fill from sibling");
        assert_eq!(ready[0].tensors[1].shape, vec![3, 5]);
    }

    #[test]
    fn abort_discards_pending_frame() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(3, 0, t());
        assert_eq!(s.pending_len(), 1);
        assert!(s.abort(3));
        assert_eq!(s.pending_len(), 0);
        assert!(!s.abort(3), "second abort is a no-op");
    }

    #[test]
    fn dropped_frames_are_reported_once() {
        let mut s = FrameSync::new(2, Duration::from_millis(10), LossPolicy::Drop, vec![2, 2]);
        s.add(7, 0, t());
        std::thread::sleep(Duration::from_millis(20));
        assert!(s.poll_expired().is_empty());
        assert_eq!(s.take_dropped(), vec![7]);
        assert!(s.take_dropped().is_empty(), "drain must be one-shot");
    }

    #[test]
    fn earliest_capture_stamp_wins() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add_at(1, 0, t(), 5000).is_none());
        let ready = s.add_at(1, 1, t(), 3000).unwrap();
        assert_eq!(ready.capture_micros, 3000, "earliest stamp must win");

        // An unstamped (0) device does not clobber a real stamp; a frame
        // with no stamps at all emits 0.
        assert!(s.add_at(2, 0, t(), 0).is_none());
        let ready = s.add_at(2, 1, t(), 7000).unwrap();
        assert_eq!(ready.capture_micros, 7000);
        assert!(s.add(3, 0, t()).is_none());
        let ready = s.add(3, 1, t()).unwrap();
        assert_eq!(ready.capture_micros, 0);
    }

    #[test]
    fn zero_fill_carries_capture_stamp_through_timeout() {
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add_at(4, 1, t(), 1234);
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].capture_micros, 1234);
    }

    #[test]
    fn latest_wins_drops_stale_and_supersedes_partials() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        s.set_latest_wins(true);
        // Frame 1 partially assembled (device 0 only).
        assert!(s.add(1, 0, t()).is_none());
        assert_eq!(s.pending_len(), 1);
        // Both devices move on to frame 2: frame 1's missing device (1)
        // reported newer, so the partial is superseded at that moment.
        assert!(s.add(2, 0, t()).is_none());
        assert_eq!(s.pending_len(), 2, "device 1 has not moved past frame 1 yet");
        let ready = s.add(2, 1, t()).unwrap();
        assert_eq!(ready.frame_id, 2);
        assert_eq!(s.pending_len(), 0, "frame-1 partial superseded");
        assert_eq!(s.stats.superseded, 1);
        // The older frame can never be delivered after the newer one:
        // device 1's late frame-1 features are stale, counted, dropped.
        assert!(s.add(1, 1, t()).is_none());
        assert_eq!(s.stats.stale, 1);
        assert_eq!(s.pending_len(), 0, "stale submission must not recreate the frame");
        assert_eq!(s.stats.complete, 1);
    }

    #[test]
    fn latest_wins_superseded_partials_do_not_leak_accounting() {
        // Regression (gc interaction): superseded partials are replaced
        // by fresher data, not lost at a deadline — they must not appear
        // in the emission records (`emitted_len`) nor in the Drop-policy
        // log (`take_dropped`), and must not linger in `pending_len`.
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        s.set_latest_wins(true);
        for id in 1..=4u64 {
            // Device 0 reports every frame; device 1 only frame 5 later:
            // each new report supersedes nothing yet (device 1 silent).
            assert!(s.add(id, 0, t()).is_none());
        }
        assert_eq!(s.pending_len(), 4, "a silent device keeps partials alive");
        // Device 1 jumps straight to frame 5. Frames 1–4 were only
        // missing device 1, so the one report supersedes all of them.
        assert!(s.add(5, 1, t()).is_none());
        assert_eq!(s.pending_len(), 1, "only frame 5 survives");
        assert_eq!(s.stats.superseded, 4);
        assert!(s.add(5, 0, t()).is_some());
        assert_eq!(s.pending_len(), 0);
        assert_eq!(s.emitted_len(), 1, "only the emitted frame leaves a record");
        assert!(s.take_dropped().is_empty(), "superseded ≠ deadline-dropped");
        assert_eq!(s.stats.dropped_frames, 0);
        assert_eq!(s.stats.timed_out, 0);
    }

    #[test]
    fn latest_wins_equal_frame_resubmission_is_a_duplicate_not_stale() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        s.set_latest_wins(true);
        assert!(s.add(3, 0, t()).is_none());
        assert!(s.add(3, 0, t()).is_none());
        assert_eq!(s.stats.duplicates, 1, "same-frame resend stays a duplicate");
        assert_eq!(s.stats.stale, 0);
    }

    #[test]
    fn latest_wins_off_keeps_out_of_order_assembly() {
        // The TCP path must keep its exact historical behavior: with
        // latest-wins off, an older frame still assembles and emits
        // after a newer one (devices legitimately interleave on TCP).
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(2, 0, t()).is_none());
        assert!(s.add(2, 1, t()).is_some());
        assert!(s.add(1, 0, t()).is_none());
        assert!(s.add(1, 1, t()).is_some(), "older frame completes when latest-wins is off");
        assert_eq!(s.stats.stale, 0);
        assert_eq!(s.stats.superseded, 0);
    }

    #[test]
    fn no_expiry_before_deadline() {
        let mut s = FrameSync::new(2, Duration::from_secs(5), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(1, 0, t());
        assert!(s.poll_expired().is_empty());
        assert_eq!(s.pending_len(), 1);
    }

    // --- BatchPlanner ---

    use std::sync::atomic::{AtomicU64, Ordering};

    /// Echo backend counting per-request and batched calls.
    struct CountingEcho {
        exec_calls: AtomicU64,
        batch_calls: AtomicU64,
        batch_sizes: Mutex<Vec<usize>>,
    }

    impl CountingEcho {
        fn new() -> Arc<CountingEcho> {
            Arc::new(CountingEcho {
                exec_calls: AtomicU64::new(0),
                batch_calls: AtomicU64::new(0),
                batch_sizes: Mutex::new(Vec::new()),
            })
        }
    }

    impl ExecBackend for CountingEcho {
        fn backend_name(&self) -> &str {
            "counting-echo"
        }
        fn exec(&self, _n: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
            self.exec_calls.fetch_add(1, Ordering::SeqCst);
            Ok(inputs)
        }
        fn load(&self, _n: &str) -> Result<()> {
            Ok(())
        }
        fn loaded_names(&self) -> Vec<String> {
            Vec::new()
        }
        fn exec_batch(
            &self,
            _n: &str,
            batch: Vec<Vec<HostTensor>>,
        ) -> Vec<Result<Vec<HostTensor>>> {
            self.batch_calls.fetch_add(1, Ordering::SeqCst);
            self.batch_sizes.lock().unwrap().push(batch.len());
            batch.into_iter().map(Ok).collect()
        }
    }

    #[test]
    fn max_batch_one_is_a_transparent_passthrough() {
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig { max_batch: 1, ..Default::default() },
        );
        assert!(!planner.enabled());
        let input = vec![HostTensor::new(vec![2], vec![1.0, 2.0]).unwrap()];
        let out = planner.exec("s", "m", input.clone()).unwrap();
        assert_eq!(out, input, "pass-through must return the backend's exact output");
        assert_eq!(backend.exec_calls.load(Ordering::SeqCst), 1, "direct exec, no batching");
        assert_eq!(backend.batch_calls.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn concurrent_compatible_requests_coalesce_into_one_call() {
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(400),
                max_batch: 8,
                max_pending: 64,
            },
        );
        let barrier = Arc::new(std::sync::Barrier::new(3));
        let handles: Vec<_> = (0..3)
            .map(|i| {
                let planner = Arc::clone(&planner);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    let input = vec![HostTensor::new(vec![2], vec![i as f32, 0.0]).unwrap()];
                    planner.exec(&format!("session-{i}"), "tail", input.clone()).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap();
            assert_eq!(out[0].data[0], i as f32, "each caller gets its own result back");
        }
        assert_eq!(
            backend.batch_calls.load(Ordering::SeqCst),
            1,
            "3 concurrent compatible requests must be one backend call"
        );
        assert_eq!(backend.batch_sizes.lock().unwrap().as_slice(), &[3]);
        let m = planner.metrics();
        assert_eq!(m.counter("batch_backend_calls"), 1);
        assert_eq!(m.counter("batch_frames"), 3);
    }

    #[test]
    fn incompatible_shapes_do_not_coalesce() {
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(150),
                max_batch: 8,
                max_pending: 64,
            },
        );
        let p2 = Arc::clone(&planner);
        let h = std::thread::spawn(move || {
            p2.exec("a", "tail", vec![HostTensor::zeros(&[4])]).unwrap()
        });
        let out = planner.exec("b", "tail", vec![HostTensor::zeros(&[2, 2])]).unwrap();
        assert_eq!(out[0].shape, vec![2, 2]);
        assert_eq!(h.join().unwrap()[0].shape, vec![4]);
        assert_eq!(
            backend.batch_calls.load(Ordering::SeqCst),
            2,
            "different shapes are different buckets"
        );
    }

    #[test]
    fn admission_control_rejects_when_queue_is_full() {
        let backend = CountingEcho::new();
        // max_pending 0: every batched request is over the bound.
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(10),
                max_batch: 4,
                max_pending: 0,
            },
        );
        let err = planner.exec("s", "m", vec![HostTensor::zeros(&[1])]).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err:#}");
        assert_eq!(planner.metrics().counter("batch_rejected"), 1);
        assert_eq!(backend.batch_calls.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn drain_fair_round_robins_across_sessions() {
        let slot = || Arc::new(ReplySlot { result: Mutex::new(None) });
        let req = |session: &str, tag: f32| BatchReq {
            session: session.to_string(),
            inputs: vec![HostTensor::new(vec![1], vec![tag]).unwrap()],
            slot: slot(),
        };
        // Chatty session A has 4 queued requests, B and C one each.
        let mut queue = vec![
            req("a", 0.0),
            req("a", 1.0),
            req("a", 2.0),
            req("b", 10.0),
            req("a", 3.0),
            req("c", 20.0),
        ];
        let taken = drain_fair(&mut queue, 3);
        let sessions: Vec<&str> = taken.iter().map(|r| r.session.as_str()).collect();
        assert_eq!(
            sessions,
            vec!["a", "b", "c"],
            "one per session before any session repeats"
        );
        // FIFO within a session: a's oldest went first, the rest remain in
        // arrival order.
        assert_eq!(taken[0].inputs[0].data[0], 0.0);
        let remaining: Vec<f32> = queue.iter().map(|r| r.inputs[0].data[0]).collect();
        assert_eq!(remaining, vec![1.0, 2.0, 3.0]);

        // Second drain sweeps a twice once b/c are gone.
        let taken = drain_fair(&mut queue, 8);
        let tags: Vec<f32> = taken.iter().map(|r| r.inputs[0].data[0]).collect();
        assert_eq!(tags, vec![1.0, 2.0, 3.0]);
        assert!(queue.is_empty());
    }

    #[test]
    fn exec_many_coalesces_a_single_caller_burst() {
        // Sequential exec() calls from one thread can never batch with
        // each other; exec_many must make burst entries batch-mates.
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(200),
                max_batch: 2,
                max_pending: 64,
            },
        );
        let batch: Vec<Vec<HostTensor>> = (0..5)
            .map(|i| vec![HostTensor::new(vec![2], vec![i as f32, 0.0]).unwrap()])
            .collect();
        let t0 = Instant::now();
        let results = planner.exec_many("s", "tail", batch);
        assert_eq!(results.len(), 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap()[0].data[0], i as f32, "order preserved");
        }
        assert_eq!(
            backend.batch_calls.load(Ordering::SeqCst),
            3,
            "5 entries at max_batch 2 must be ceil(5/2) = 3 calls"
        );
        // Only the final, unfilled batch may wait a window; the full ones
        // execute immediately — the burst must not pay 5 windows.
        assert!(
            t0.elapsed() < Duration::from_millis(600),
            "burst serialized through per-entry windows: {:?}",
            t0.elapsed()
        );
        assert_eq!(planner.metrics().counter("batch_frames"), 5);
    }

    #[test]
    fn split_variants_never_share_a_batch() {
        // Split depths surface as distinct executable names
        // (`tail_max` vs `tail_max@split-deep`), so the planner's
        // (name, shapes) bucket key must keep them in separate backend
        // calls even when shapes and timing line up exactly.
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(300),
                max_batch: 8,
                max_pending: 64,
            },
        );
        let barrier = Arc::new(std::sync::Barrier::new(4));
        let names =
            ["tail_max", "tail_max", "tail_max@split-deep", "tail_max@split-deep"];
        let handles: Vec<_> = names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let planner = Arc::clone(&planner);
                let barrier = Arc::clone(&barrier);
                let name = name.to_string();
                std::thread::spawn(move || {
                    barrier.wait();
                    let input = vec![HostTensor::new(vec![2], vec![i as f32, 0.0]).unwrap()];
                    planner.exec(&format!("session-{i}"), &name, input).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap()[0].data[0], i as f32);
        }
        assert_eq!(
            backend.batch_calls.load(Ordering::SeqCst),
            2,
            "same shapes, different split executables: one call per split, never mixed"
        );
        let mut sizes = backend.batch_sizes.lock().unwrap().clone();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![2, 2], "each split class still coalesces within itself");
    }

    #[test]
    fn queue_depth_reports_pending_requests() {
        let backend = CountingEcho::new();
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig {
                window: Duration::from_millis(100),
                max_batch: 4,
                max_pending: 16,
            },
        );
        assert_eq!(planner.queue_depth(), 0, "idle planner has an empty queue");
        // One lone request occupies the queue for the collection window;
        // sample the depth from a second thread mid-window.
        let p2 = Arc::clone(&planner);
        let h = std::thread::spawn(move || {
            p2.exec("s", "m", vec![HostTensor::zeros(&[1])]).unwrap()
        });
        let deadline = Instant::now() + Duration::from_secs(5);
        while planner.queue_depth() == 0 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(planner.queue_depth(), 1, "in-window request is visible as depth");
        h.join().unwrap();
        assert_eq!(planner.queue_depth(), 0, "resolved requests leave the queue");
    }

    #[test]
    fn lone_request_executes_after_the_window() {
        let backend = CountingEcho::new();
        let window = Duration::from_millis(40);
        let planner = BatchPlanner::new(
            backend.clone() as Arc<dyn ExecBackend>,
            BatchConfig { window, max_batch: 4, max_pending: 16 },
        );
        let t0 = Instant::now();
        let out = planner.exec("s", "m", vec![HostTensor::zeros(&[1])]).unwrap();
        assert_eq!(out[0].shape, vec![1]);
        let elapsed = t0.elapsed();
        assert!(elapsed >= window, "an unfilled batch waits out the window: {elapsed:?}");
        assert_eq!(backend.batch_calls.load(Ordering::SeqCst), 1);
        assert_eq!(backend.batch_sizes.lock().unwrap().as_slice(), &[1]);
    }
}
