//! Frame synchronizer: pairs per-device intermediate outputs by frame id
//! before integration.
//!
//! The paper's inference flow assumes both devices' features arrive for a
//! frame; real links lose or delay messages, so the synchronizer adds a
//! deadline and a configurable policy for incomplete frames — the
//! robustness direction §IV-E calls out ("systems designed to tolerate
//! partial data loss without retransmission").

use crate::runtime::HostTensor;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// What to do when the deadline fires with devices missing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossPolicy {
    /// Drop the frame entirely.
    Drop,
    /// Run the tail with zero-filled features for missing devices
    /// (integration methods degrade gracefully: max treats zeros as
    /// "no evidence"; conv was trained with both inputs but remains
    /// usable — the Table-III-style ablation quantifies the hit).
    ZeroFill,
}

impl LossPolicy {
    /// Canonical CLI/JSON spelling (matches `scmii serve --policy`).
    pub fn name(&self) -> &'static str {
        match self {
            LossPolicy::Drop => "drop",
            LossPolicy::ZeroFill => "zero-fill",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<LossPolicy> {
        match s {
            "drop" => Ok(LossPolicy::Drop),
            "zero-fill" => Ok(LossPolicy::ZeroFill),
            other => anyhow::bail!("unknown loss policy {other:?} (expected zero-fill|drop)"),
        }
    }
}

/// A completed (or force-completed) frame ready for the tail model.
#[derive(Debug)]
pub struct ReadyFrame {
    pub frame_id: u64,
    /// Per-device features; `None` only under `ZeroFill` accounting
    /// (already replaced by zeros in `tensors`).
    pub tensors: Vec<HostTensor>,
    /// Devices that actually contributed.
    pub present: Vec<bool>,
    /// Arrival of the first device's features (latency accounting).
    pub first_arrival: Instant,
    /// Earliest device capture stamp (wall-clock µs; 0 = no device
    /// stamped this frame). End-to-end latency accounting rides on it.
    pub capture_micros: u64,
}

struct Pending {
    slots: Vec<Option<HostTensor>>,
    first_arrival: Instant,
    /// Earliest non-zero capture stamp seen for this frame.
    capture_micros: u64,
}

/// How long an emission record is kept to classify late arrivals.
const DEFAULT_EMITTED_HORIZON: Duration = Duration::from_secs(30);

/// The synchronizer. Not thread-safe by itself — wrap in a `Mutex`.
pub struct FrameSync {
    n_devices: usize,
    deadline: Duration,
    policy: LossPolicy,
    /// Shape used for zero-fill when a device never reported.
    feature_shape: Vec<usize>,
    pending: HashMap<u64, Pending>,
    /// Frames already emitted (late arrivals for these are dropped).
    emitted: HashMap<u64, Instant>,
    /// Retention window for `emitted` records.
    emitted_horizon: Duration,
    /// Frame ids discarded under [`LossPolicy::Drop`], awaiting collection.
    dropped_log: Vec<u64>,
    pub stats: SyncStats,
}

/// Counters for observability / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncStats {
    pub complete: u64,
    pub timed_out: u64,
    pub dropped_frames: u64,
    pub late_arrivals: u64,
    pub duplicates: u64,
}

impl FrameSync {
    pub fn new(
        n_devices: usize,
        deadline: Duration,
        policy: LossPolicy,
        feature_shape: Vec<usize>,
    ) -> FrameSync {
        FrameSync {
            n_devices,
            deadline,
            policy,
            feature_shape,
            pending: HashMap::new(),
            emitted: HashMap::new(),
            emitted_horizon: DEFAULT_EMITTED_HORIZON,
            dropped_log: Vec::new(),
            stats: SyncStats::default(),
        }
    }

    /// Override the retention window for emission records (tests and
    /// high-frame-rate deployments).
    pub fn set_emitted_horizon(&mut self, horizon: Duration) {
        self.emitted_horizon = horizon;
    }

    /// Register features from a device. Returns the frame when complete.
    pub fn add(&mut self, frame_id: u64, device_id: usize, tensor: HostTensor) -> Option<ReadyFrame> {
        self.add_at(frame_id, device_id, tensor, 0)
    }

    /// [`add`](Self::add) with the device's capture stamp (wall-clock µs;
    /// 0 = unstamped). The emitted frame carries the *earliest* stamp —
    /// end-to-end latency is measured from the first capture.
    pub fn add_at(
        &mut self,
        frame_id: u64,
        device_id: usize,
        tensor: HostTensor,
        capture_micros: u64,
    ) -> Option<ReadyFrame> {
        assert!(device_id < self.n_devices, "device {device_id} out of range");
        if self.emitted.contains_key(&frame_id) {
            self.stats.late_arrivals += 1;
            return None;
        }
        let pending = self.pending.entry(frame_id).or_insert_with(|| Pending {
            slots: vec![None; self.n_devices],
            first_arrival: Instant::now(),
            capture_micros: 0,
        });
        if pending.slots[device_id].is_some() {
            self.stats.duplicates += 1;
            return None;
        }
        pending.slots[device_id] = Some(tensor);
        if capture_micros > 0
            && (pending.capture_micros == 0 || capture_micros < pending.capture_micros)
        {
            pending.capture_micros = capture_micros;
        }
        if pending.slots.iter().all(|s| s.is_some()) {
            let pending = self.pending.remove(&frame_id).unwrap();
            self.emitted.insert(frame_id, Instant::now());
            self.gc_emitted();
            self.stats.complete += 1;
            return Some(ReadyFrame {
                frame_id,
                present: vec![true; self.n_devices],
                tensors: pending.slots.into_iter().map(|s| s.unwrap()).collect(),
                first_arrival: pending.first_arrival,
                capture_micros: pending.capture_micros,
            });
        }
        None
    }

    /// Collect frames whose deadline has expired, resolving them per the
    /// loss policy. Call periodically (the server does so between reads).
    pub fn poll_expired(&mut self) -> Vec<ReadyFrame> {
        let now = Instant::now();
        let expired: Vec<u64> = self
            .pending
            .iter()
            .filter(|(_, p)| now.duration_since(p.first_arrival) >= self.deadline)
            .map(|(&id, _)| id)
            .collect();
        let mut out = Vec::new();
        for id in expired {
            let pending = self.pending.remove(&id).unwrap();
            self.emitted.insert(id, now);
            match self.policy {
                LossPolicy::Drop => {
                    self.stats.timed_out += 1;
                    self.stats.dropped_frames += 1;
                    self.dropped_log.push(id);
                }
                LossPolicy::ZeroFill => {
                    self.stats.timed_out += 1;
                    let present: Vec<bool> =
                        pending.slots.iter().map(|s| s.is_some()).collect();
                    // Zero-fill with the shape of a present sibling tensor
                    // when one exists: arrived payloads may legitimately
                    // differ from the configured shape (e.g. quantized→
                    // dequantized tensors with trimmed dims), and the tail
                    // needs every device input to agree.
                    let fill_shape: Vec<usize> = pending
                        .slots
                        .iter()
                        .find_map(|s| s.as_ref().map(|t| t.shape.clone()))
                        .unwrap_or_else(|| self.feature_shape.clone());
                    let tensors: Vec<HostTensor> = pending
                        .slots
                        .into_iter()
                        .map(|s| s.unwrap_or_else(|| HostTensor::zeros(&fill_shape)))
                        .collect();
                    out.push(ReadyFrame {
                        frame_id: id,
                        tensors,
                        present,
                        first_arrival: pending.first_arrival,
                        capture_micros: pending.capture_micros,
                    });
                }
            }
        }
        self.gc_emitted();
        out
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Number of retained emission records (observability / tests).
    pub fn emitted_len(&self) -> usize {
        self.emitted.len()
    }

    /// Drain the frame ids discarded under [`LossPolicy::Drop`] since the
    /// last call (the session core turns these into `Dropped` events).
    pub fn take_dropped(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.dropped_log)
    }

    /// Discard a pending frame and its buffered tensors without emitting
    /// anything (a frontend abandoned the frame mid-submission). Returns
    /// whether the frame was pending.
    pub fn abort(&mut self, frame_id: u64) -> bool {
        self.pending.remove(&frame_id).is_some()
    }

    fn gc_emitted(&mut self) {
        // Bound memory: forget emission records past the horizon. This must
        // run on time, not on size — a slow trickle of frames would
        // otherwise grow `emitted` unboundedly below any size threshold.
        if self.emitted.is_empty() {
            return;
        }
        if let Some(cutoff) = Instant::now().checked_sub(self.emitted_horizon) {
            self.emitted.retain(|_, t| *t > cutoff);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> HostTensor {
        HostTensor::zeros(&[2, 2])
    }

    #[test]
    fn completes_when_all_devices_report() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        let ready = s.add(1, 1, t()).unwrap();
        assert_eq!(ready.frame_id, 1);
        assert_eq!(ready.tensors.len(), 2);
        assert_eq!(ready.present, vec![true, true]);
        assert_eq!(s.stats.complete, 1);
        assert_eq!(s.pending_len(), 0);
    }

    #[test]
    fn interleaved_frames() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        assert!(s.add(2, 0, t()).is_none());
        assert!(s.add(2, 1, t()).is_some());
        assert!(s.add(1, 1, t()).is_some());
    }

    #[test]
    fn duplicate_device_report_counted() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add(1, 0, t()).is_none());
        assert!(s.add(1, 0, t()).is_none());
        assert_eq!(s.stats.duplicates, 1);
    }

    #[test]
    fn timeout_drop_policy() {
        let mut s = FrameSync::new(2, Duration::from_millis(10), LossPolicy::Drop, vec![2, 2]);
        s.add(5, 0, t());
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert!(ready.is_empty());
        assert_eq!(s.stats.dropped_frames, 1);
        // late arrival after emission is ignored
        assert!(s.add(5, 1, t()).is_none());
        assert_eq!(s.stats.late_arrivals, 1);
    }

    #[test]
    fn timeout_zero_fill_policy() {
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(5, 1, t());
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].present, vec![false, true]);
        assert_eq!(ready[0].tensors.len(), 2);
        assert!(ready[0].tensors[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn emitted_records_gc_on_time_basis() {
        // Regression: gc must fire below the old 4096-entry threshold —
        // emission records older than the horizon are forgotten on the
        // next add/poll even with only a handful of frames in flight.
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        s.set_emitted_horizon(Duration::from_millis(30));
        for id in 0..8u64 {
            s.add(id, 0, t());
            s.add(id, 1, t());
        }
        assert!(s.emitted_len() > 0);
        std::thread::sleep(Duration::from_millis(60));
        // Any synchronizer activity past the horizon triggers the gc.
        s.add(100, 0, t());
        s.add(100, 1, t());
        assert!(
            s.emitted_len() <= 1,
            "stale emission records must be collected, have {}",
            s.emitted_len()
        );
    }

    #[test]
    fn zero_fill_matches_present_sibling_shape() {
        // Regression: a frame whose arrived tensor has a different shape
        // than the configured feature_shape (e.g. a trimmed quantized
        // payload) must be zero-filled to the *sibling's* shape, not the
        // configured one — the tail needs agreeing device inputs.
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(9, 1, HostTensor::zeros(&[3, 5]));
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].present, vec![false, true]);
        assert_eq!(ready[0].tensors[0].shape, vec![3, 5], "fill from sibling");
        assert_eq!(ready[0].tensors[1].shape, vec![3, 5]);
    }

    #[test]
    fn abort_discards_pending_frame() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(3, 0, t());
        assert_eq!(s.pending_len(), 1);
        assert!(s.abort(3));
        assert_eq!(s.pending_len(), 0);
        assert!(!s.abort(3), "second abort is a no-op");
    }

    #[test]
    fn dropped_frames_are_reported_once() {
        let mut s = FrameSync::new(2, Duration::from_millis(10), LossPolicy::Drop, vec![2, 2]);
        s.add(7, 0, t());
        std::thread::sleep(Duration::from_millis(20));
        assert!(s.poll_expired().is_empty());
        assert_eq!(s.take_dropped(), vec![7]);
        assert!(s.take_dropped().is_empty(), "drain must be one-shot");
    }

    #[test]
    fn earliest_capture_stamp_wins() {
        let mut s = FrameSync::new(2, Duration::from_secs(10), LossPolicy::Drop, vec![2, 2]);
        assert!(s.add_at(1, 0, t(), 5000).is_none());
        let ready = s.add_at(1, 1, t(), 3000).unwrap();
        assert_eq!(ready.capture_micros, 3000, "earliest stamp must win");

        // An unstamped (0) device does not clobber a real stamp; a frame
        // with no stamps at all emits 0.
        assert!(s.add_at(2, 0, t(), 0).is_none());
        let ready = s.add_at(2, 1, t(), 7000).unwrap();
        assert_eq!(ready.capture_micros, 7000);
        assert!(s.add(3, 0, t()).is_none());
        let ready = s.add(3, 1, t()).unwrap();
        assert_eq!(ready.capture_micros, 0);
    }

    #[test]
    fn zero_fill_carries_capture_stamp_through_timeout() {
        let mut s =
            FrameSync::new(2, Duration::from_millis(10), LossPolicy::ZeroFill, vec![2, 2]);
        s.add_at(4, 1, t(), 1234);
        std::thread::sleep(Duration::from_millis(20));
        let ready = s.poll_expired();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].capture_micros, 1234);
    }

    #[test]
    fn no_expiry_before_deadline() {
        let mut s = FrameSync::new(2, Duration::from_secs(5), LossPolicy::ZeroFill, vec![2, 2]);
        s.add(1, 0, t());
        assert!(s.poll_expired().is_empty());
        assert_eq!(s.pending_len(), 1);
    }
}
