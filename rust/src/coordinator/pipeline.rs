//! In-process SC-MII frontend: the full inference flow of Fig 2 on one
//! machine, deterministic and instrumented. The accuracy evaluation
//! (Table III) and the execution-time model (Fig 5) both drive this.
//!
//! This is a *thin driver* over the
//! [`DetectorSession`](super::session::DetectorSession) serving core: it
//! runs the head models locally, submits the intermediate outputs to the
//! session, and reads the completed frame back — exactly the code path
//! the TCP server exercises, minus the sockets. Post-processing and
//! decode parameters live in the session, so eval numbers measure what
//! serving returns.
//!
//! Spatial alignment executes *inside the tail* as a static gather —
//! baked into the compiled graph by `python/compile/aot.py` on the XLA
//! backend, built from the same `calib.json` poses by the native
//! backend — i.e. the edge server performs the coordinate
//! transformation, as in the paper, whichever substrate runs the math.

use super::session::{DetectorSession, FeaturePayload, FrameResult, SessionConfig, SessionEvent};
use crate::cli::Args;
use crate::config::{artifacts_present, IntegrationKind, ModelMeta, Paths};
use crate::geom::Pose;
use crate::model::{DecodeParams, Detection};
use crate::runtime::{build_backend, BackendKind, ExecBackend, HostTensor};
use crate::voxel::{merge_clouds, points_to_tensor, Point};
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-frame timing breakdown (seconds measured on this machine; the
/// latency model scales them to the paper's testbed).
#[derive(Clone, Debug, Default)]
pub struct FrameTiming {
    /// Head execution per device.
    pub head_secs: Vec<f64>,
    /// Intermediate-output payload per device, bytes.
    pub payload_bytes: Vec<usize>,
    /// Tail execution (alignment + integration + backbone + heads).
    pub tail_secs: f64,
    /// Post-processing (decode + NMS).
    pub post_secs: f64,
}

/// Load the calibration transforms written by `scmii setup`.
pub use crate::config::load_calib;

/// Which backend the in-process pipeline executes on (CLI `--backend` /
/// `--backend-threads`).
#[derive(Clone, Copy, Debug)]
pub struct PipelineBackend {
    /// Which [`ExecBackend`] implementation to run on.
    pub kind: BackendKind,
    /// Engine-pool threads (XLA backend; the native backend runs on the
    /// caller thread and ignores this).
    pub threads: usize,
}

impl Default for PipelineBackend {
    fn default() -> Self {
        PipelineBackend { kind: BackendKind::default_kind(), threads: 1 }
    }
}

impl PipelineBackend {
    /// Parse `--backend` / `--backend-threads` flags.
    pub fn from_args(args: &Args) -> Result<PipelineBackend> {
        let d = PipelineBackend::default();
        Ok(PipelineBackend {
            kind: BackendKind::parse(&args.str_or("backend", d.kind.name()))?,
            threads: args.usize_or("backend-threads", d.threads)?,
        })
    }
}

/// The in-process frontend for one integration variant: heads + a
/// [`DetectorSession`] sharing one execution backend.
pub struct ScMiiPipeline {
    /// Model geometry loaded from `model_meta.json`.
    pub meta: ModelMeta,
    /// Integration method this pipeline runs.
    pub variant: IntegrationKind,
    backend: Arc<dyn ExecBackend>,
    session: DetectorSession,
    head_names: Vec<String>,
    calib: Vec<Pose>,
    /// Monotone frame ids so the session's synchronizer never sees a
    /// frame id reused across `infer` calls.
    next_frame: AtomicU64,
}

impl ScMiiPipeline {
    /// Load models for `variant` (heads + tail) plus calibration on the
    /// build's default backend.
    pub fn load(paths: &Paths, variant: IntegrationKind) -> Result<ScMiiPipeline> {
        Self::load_with(paths, variant, &PipelineBackend::default())
    }

    /// Load on an explicit backend choice.
    pub fn load_with(
        paths: &Paths,
        variant: IntegrationKind,
        be: &PipelineBackend,
    ) -> Result<ScMiiPipeline> {
        anyhow::ensure!(
            artifacts_present(paths),
            "artifacts missing under {} — run `make artifacts`",
            paths.artifacts.display()
        );
        let meta = ModelMeta::load(&paths.model_meta())?;
        let vm = meta.variant(variant)?.clone();
        let mut names = vm.heads.clone();
        names.push(vm.tail.clone());
        let backend = build_backend(paths, &meta, be.kind, be.threads, &names)?;
        let calib = load_calib(paths).context("load calib.json (run `scmii setup`)")?;
        // In-process frames complete synchronously: a generous deadline +
        // Drop policy means the session never zero-fills mid-`infer`.
        let cfg = SessionConfig::new(variant)
            .deadline(Duration::from_secs(3600))
            .policy(super::scheduler::LossPolicy::Drop);
        let session =
            DetectorSession::new("pipeline", meta.clone(), Arc::clone(&backend), cfg)?;
        Ok(ScMiiPipeline {
            meta,
            variant,
            backend,
            session,
            head_names: vm.heads,
            calib,
            next_frame: AtomicU64::new(0),
        })
    }

    /// Also load baseline models (single-LiDAR fulls + input
    /// integration) into the same backend for the eval harness.
    pub fn load_baselines(&mut self, _paths: &Paths) -> Result<()> {
        for name in &self.meta.single_full {
            self.backend.load(name)?;
        }
        self.backend.load(&self.meta.input_integration_full)?;
        Ok(())
    }

    /// The execution backend this pipeline runs on.
    pub fn backend(&self) -> &Arc<dyn ExecBackend> {
        &self.backend
    }

    /// The serving core this pipeline drives (metrics, sync stats).
    pub fn session(&self) -> &DetectorSession {
        &self.session
    }

    /// Mutable access to the session's decode/NMS parameters.
    pub fn decode_params(&mut self) -> &mut DecodeParams {
        self.session.decode_params_mut()
    }

    /// Run one device's head model on its local point cloud.
    pub fn run_head(&self, device: usize, points: &[Point]) -> Result<HostTensor> {
        let input = HostTensor::new(
            vec![self.meta.grid.max_points, 4],
            points_to_tensor(points, self.meta.grid.max_points),
        )?;
        let mut out = self.backend.exec(&self.head_names[device], vec![input])?;
        anyhow::ensure!(out.len() == 1, "head returns one tensor");
        Ok(out.remove(0))
    }

    /// Run the tail on per-device features (alignment happens inside).
    /// Clones `features` to hand the backend ownership; callers that
    /// can give up ownership should prefer driving [`Self::infer`],
    /// which moves tensors into the session without copying.
    pub fn run_tail(&self, features: &[HostTensor]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.session.run_tail(features.to_vec())
    }

    /// Full SC-MII inference over one frame (all devices' local clouds):
    /// heads here, everything downstream in the [`DetectorSession`].
    pub fn infer(&self, clouds: &[Vec<Point>]) -> Result<(Vec<Detection>, FrameTiming)> {
        anyhow::ensure!(clouds.len() == self.meta.num_devices, "cloud count mismatch");
        let frame_id = self.next_frame.fetch_add(1, Ordering::SeqCst);
        let mut timing = FrameTiming::default();
        let drive = |timing: &mut FrameTiming| -> Result<Option<FrameResult>> {
            let mut completed = None;
            for (dev, cloud) in clouds.iter().enumerate() {
                let t0 = Instant::now();
                let feat = self.run_head(dev, cloud)?;
                timing.head_secs.push(t0.elapsed().as_secs_f64());
                timing.payload_bytes.push(feat.data.len() * 4);
                for event in self.session.submit(frame_id, dev, FeaturePayload::Raw(feat))? {
                    if let SessionEvent::Result(r) = event {
                        if r.frame_id == frame_id {
                            completed = Some(r);
                        }
                    }
                }
            }
            Ok(completed)
        };
        let completed = match drive(&mut timing) {
            Ok(c) => c,
            Err(e) => {
                // Release any tensors already buffered for this frame so a
                // failed head doesn't pin memory until the deadline.
                self.session.abort_frame(frame_id);
                return Err(e);
            }
        };
        let Some(r) = completed else {
            self.session.abort_frame(frame_id);
            anyhow::bail!("session did not complete a fully-submitted frame");
        };
        anyhow::ensure!(!r.tail_error, "tail execution failed for frame {frame_id}");
        timing.tail_secs = r.tail_secs;
        timing.post_secs = r.post_secs;
        Ok((r.detections, timing))
    }

    /// Baseline: single-LiDAR full model on one device's cloud.
    pub fn infer_single(&self, device: usize, cloud: &[Point]) -> Result<(Vec<Detection>, f64)> {
        let name = self.meta.single_full[device].clone();
        let input = HostTensor::new(
            vec![self.meta.grid.max_points, 4],
            points_to_tensor(cloud, self.meta.grid.max_points),
        )?;
        let t0 = Instant::now();
        let out = self.backend.exec(&name, vec![input])?;
        let secs = t0.elapsed().as_secs_f64();
        anyhow::ensure!(out.len() == 2, "full model returns (cls, boxes)");
        Ok((self.session.decode_detections(&out[0].data, &out[1].data), secs))
    }

    /// Baseline: input point-cloud integration — transform device clouds
    /// into the common frame with the calibration transforms, merge, run
    /// the full model (paper Table III row "Input point clouds"; also the
    /// compute graph of the edge-only Fig-5 baseline).
    pub fn infer_input_integration(
        &self,
        clouds: &[Vec<Point>],
    ) -> Result<(Vec<Detection>, f64)> {
        let merged = self.merge_to_common(clouds);
        let input = HostTensor::new(
            vec![self.meta.grid.max_points, 4],
            points_to_tensor(&merged, self.meta.grid.max_points),
        )?;
        let name = self.meta.input_integration_full.clone();
        let t0 = Instant::now();
        let out = self.backend.exec(&name, vec![input])?;
        let secs = t0.elapsed().as_secs_f64();
        anyhow::ensure!(out.len() == 2, "full model returns (cls, boxes)");
        Ok((self.session.decode_detections(&out[0].data, &out[1].data), secs))
    }

    /// Transform per-device clouds into the common frame and interleave.
    pub fn merge_to_common(&self, clouds: &[Vec<Point>]) -> Vec<Point> {
        let transformed: Vec<Vec<Point>> = clouds
            .iter()
            .enumerate()
            .map(|(dev, cloud)| {
                let t = self.calib.get(dev).copied().unwrap_or(Pose::IDENTITY);
                cloud
                    .iter()
                    .filter(|p| !p.is_pad())
                    .map(|p| {
                        let v = t.apply(crate::geom::Vec3::new(
                            p.x as f64, p.y as f64, p.z as f64,
                        ));
                        Point::new(v.x as f32, v.y as f32, v.z as f32, p.intensity)
                    })
                    .collect()
            })
            .collect();
        merge_clouds(&transformed, self.meta.grid.max_points)
    }

    /// The calibration poses loaded for this rig (index = device id).
    pub fn calib(&self) -> &[Pose] {
        &self.calib
    }

    /// Post-process raw tail outputs with this pipeline's session
    /// parameters (ablation benches).
    pub fn postprocess_raw(&self, cls: &[f32], boxes: &[f32]) -> Vec<Detection> {
        self.session.decode_detections(cls, boxes)
    }
}

/// `scmii infer` — run the pipeline over validation frames and report.
pub fn cmd_infer(args: &Args) -> Result<()> {
    args.check_known(&[
        "artifacts",
        "data",
        "variant",
        "frames",
        "split",
        "dump",
        "backend",
        "backend-threads",
    ])?;
    let paths = Paths::new(
        &args.str_or("artifacts", "artifacts"),
        &args.str_or("data", "data"),
    );
    let variant = IntegrationKind::parse(&args.str_or("variant", "conv_k3"))?;
    let split = args.str_or("split", "val");
    let n = args.usize_or("frames", 8)?;
    let be = PipelineBackend::from_args(args)?;

    let pipeline = ScMiiPipeline::load_with(&paths, variant, &be)?;
    log::info!("pipeline backend: {}", pipeline.backend().backend_name());
    let frames = crate::sim::dataset::load_split(&paths.data.join(&split))?;

    // Debug hook: dump the raw tail outputs of frame 0 for cross-checking
    // against the python reference path.
    if let Some(dir) = args.str_opt("dump") {
        let f = &frames[0];
        let feats: Vec<_> = (0..pipeline.meta.num_devices)
            .map(|d| pipeline.run_head(d, &f.clouds[d]).unwrap())
            .collect();
        let (cls, boxes) = pipeline.run_tail(&feats)?;
        let dir = std::path::Path::new(dir);
        crate::utils::npy::write(
            &dir.join("rust_cls.npy"),
            &crate::utils::npy::NpyArray::from_f32(&[cls.len()], &cls),
        )?;
        crate::utils::npy::write(
            &dir.join("rust_box.npy"),
            &crate::utils::npy::NpyArray::from_f32(&[boxes.len()], &boxes),
        )?;
        for (d, feat) in feats.iter().enumerate() {
            crate::utils::npy::write(
                &dir.join(format!("rust_feat{d}.npy")),
                &crate::utils::npy::NpyArray::from_f32(&feat.shape, &feat.data),
            )?;
        }
        log::info!("dumped rust tail outputs to {}", dir.display());
    }

    let metrics = crate::metrics::Metrics::new();
    for (i, frame) in frames.iter().take(n).enumerate() {
        let t0 = Instant::now();
        let (dets, timing) = pipeline.infer(&frame.clouds)?;
        metrics.record("e2e", t0.elapsed().as_secs_f64());
        metrics.record("tail", timing.tail_secs);
        for (d, &h) in timing.head_secs.iter().enumerate() {
            metrics.record(&format!("head_dev{d}"), h);
        }
        println!(
            "frame {i}: {} detections ({} gt), heads {:?} ms, tail {:.1} ms",
            dets.len(),
            frame.labels.len(),
            timing.head_secs.iter().map(|s| (s * 1e3 * 10.0).round() / 10.0).collect::<Vec<_>>(),
            timing.tail_secs * 1e3
        );
    }
    print!("{}", metrics.report());
    print!("{}", pipeline.session().metrics().report());
    Ok(())
}
