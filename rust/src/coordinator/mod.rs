//! The SC-MII coordinator — the paper's system contribution at layer 3.
//!
//! Three deployment shapes share the same compute:
//! - [`pipeline`] — in-process split pipeline (deterministic; eval/bench).
//! - [`server`] + [`device`] — the distributed deployment: one edge
//!   server (tail model) and one worker per LiDAR (head model), talking
//!   the `net` protocol over TCP with bandwidth shaping.
//! - [`scheduler`] — the server-side frame synchronizer pairing
//!   intermediate outputs by frame id, with timeout and partial-loss
//!   policies (paper §IV-E future work, implemented here).

pub mod device;
pub mod pipeline;
pub mod scheduler;
pub mod server;
