//! The SC-MII coordinator — the paper's system contribution at layer 3.
//!
//! One serving core, three frontends:
//! - [`session`] — the transport-agnostic `DetectorSession` core (frame
//!   sync → integration + tail → decode/NMS) plus the `SessionRegistry`
//!   that lets one process host many named sessions.
//! - [`pipeline`] — in-process driver over the session core
//!   (deterministic; eval/bench).
//! - [`server`] + [`device`] — the distributed deployment: one edge
//!   server (pure I/O over the session core) and one worker per LiDAR
//!   (head model), talking the `net` protocol over TCP with bandwidth
//!   shaping. The device worker is pipelined: head execution of frame
//!   t+1 overlaps transmission of frame t behind a writer thread, so the
//!   device cycle is max(head, tx), not head + tx. Fleet-scale workloads
//!   over this deployment live in [`crate::scenario`].
//! - [`scheduler`] — the frame synchronizer pairing intermediate outputs
//!   by frame id, with timeout and partial-loss policies (paper §IV-E
//!   future work, implemented here), plus the cross-session
//!   [`scheduler::BatchPlanner`] that coalesces compatible tail
//!   executions into stacked backend calls. Both owned by the session
//!   core.

pub mod device;
pub mod pipeline;
pub mod scheduler;
pub mod server;
pub mod session;
