//! The SC-MII coordinator — the paper's system contribution at layer 3.
//!
//! One serving core, three frontends:
//! - [`session`] — the transport-agnostic `DetectorSession` core (frame
//!   sync → integration + tail → decode/NMS) plus the `SessionRegistry`
//!   that lets one process host many named sessions.
//! - [`pipeline`] — in-process driver over the session core
//!   (deterministic; eval/bench).
//! - [`server`] + [`device`] — the distributed deployment: one edge
//!   server (pure I/O over the session core) and one worker per LiDAR
//!   (head model), talking the `net` protocol over TCP with bandwidth
//!   shaping.
//! - [`scheduler`] — the frame synchronizer pairing intermediate outputs
//!   by frame id, with timeout and partial-loss policies (paper §IV-E
//!   future work, implemented here). Owned by the session core.

pub mod device;
pub mod pipeline;
pub mod scheduler;
pub mod server;
pub mod session;
