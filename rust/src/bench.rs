//! `scmii bench` — machine-readable micro-benchmarks of the serving hot
//! path, emitted as `BENCH_decode.json`, `BENCH_integrate.json`,
//! `BENCH_tail.json`, `BENCH_dgram.json` and `BENCH_batch.json` so the
//! performance trajectory
//! is tracked from one PR to the next (each entry: op, p50/p95 seconds,
//! backend, samples; batch entries add batch size and backend-calls vs
//! frames accounting). The system-level counterpart is `BENCH_e2e.json`
//! — per-frame end-to-end latency under a multi-device fleet — emitted
//! by [`scmii scenario`](crate::scenario). Schemas and provenance of
//! every file are documented in `docs/BENCHMARKS.md`.
//!
//! Everything here runs on synthetic inputs at fixed shapes and needs no
//! artifacts, so the numbers are comparable across machines-with-caveats
//! and, more importantly, across commits on the same machine / CI runner.

use crate::cli::Args;
use crate::config::ModelMeta;
use crate::model::{decode_raw, postprocess, DecodeParams};
use crate::utils::bench::Bench;
use crate::utils::json::Json;
use crate::utils::rng::Pcg64;
use crate::utils::stats;
use crate::voxel::FeatureMap;
use anyhow::{Context, Result};
use std::path::Path;

/// One benchmark row destined for a `BENCH_*.json` file.
struct Entry {
    op: String,
    backend: String,
    p50_secs: f64,
    p95_secs: f64,
    samples: usize,
}

impl Entry {
    fn from_sample(sample: &crate::utils::bench::Sample, backend: &str) -> Entry {
        Entry {
            op: sample.name.clone(),
            backend: backend.to_string(),
            p50_secs: sample.p50(),
            p95_secs: stats::percentile(&sample.times, 95.0),
            samples: sample.times.len(),
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("op", Json::Str(self.op.clone()))
            .set("backend", Json::Str(self.backend.clone()))
            .set("p50_secs", Json::Num(self.p50_secs))
            .set("p95_secs", Json::Num(self.p95_secs))
            .set("samples", Json::Num(self.samples as f64));
        j
    }
}

fn write_entries(path: &Path, entries: &[Entry]) -> Result<()> {
    let json = Json::Arr(entries.iter().map(|e| e.to_json()).collect());
    crate::utils::json::write_file(path, &json)
        .with_context(|| format!("write {}", path.display()))?;
    println!("wrote {} ({} ops)", path.display(), entries.len());
    Ok(())
}

/// Synthetic head outputs at the production decode shape.
fn synthetic_logits(meta: &ModelMeta, rng: &mut Pcg64) -> (Vec<f32>, Vec<f32>) {
    let [hb, wb] = meta.bev_dims;
    let a = meta.anchors.len();
    let n = hb * wb * a;
    // Logits mostly negative so a realistic minority clears the score
    // threshold (dense all-pass decodes would overstate NMS cost).
    let cls: Vec<f32> = (0..n).map(|_| rng.uniform_f32() * 8.0 - 6.0).collect();
    let boxes: Vec<f32> = (0..n * 8).map(|_| rng.uniform_f32() - 0.5).collect();
    (cls, boxes)
}

fn bench_decode(bench: &mut Bench) -> Vec<Entry> {
    let meta = ModelMeta::test_default();
    let mut rng = Pcg64::new(41);
    let (cls, boxes) = synthetic_logits(&meta, &mut rng);
    let params = DecodeParams::default();
    let s = bench.run("decode_raw", || {
        let d = decode_raw(&cls, &boxes, &meta, &params);
        std::hint::black_box(d.len());
    });
    let mut out = vec![Entry::from_sample(s, "host")];
    let s = bench.run("postprocess", || {
        let d = postprocess(&cls, &boxes, &meta, &params);
        std::hint::black_box(d.len());
    });
    out.push(Entry::from_sample(s, "host"));
    out
}

fn bench_integrate(bench: &mut Bench) -> Vec<Entry> {
    // Fixed bench shape (quarter-resolution grid): big enough to be
    // representative, small enough for conv k3 in debug builds. Shape is
    // part of the contract — changing it breaks cross-commit comparison.
    let (d, h, w, c) = (4usize, 16usize, 16usize, 8usize);
    let mut rng = Pcg64::new(42);
    let mut maps = Vec::new();
    for _ in 0..2 {
        let mut m = FeatureMap::zeros(d, h, w, c);
        for v in m.data.iter_mut() {
            // ~90% empty voxels, mirroring infrastructure-LiDAR sparsity.
            *v = if rng.uniform_f32() < 0.1 { rng.uniform_f32() } else { 0.0 };
        }
        maps.push(m);
    }
    let c_in = 2 * c;
    let mut conv_w = |k: usize| -> Vec<f32> {
        (0..k * k * k * c_in * c).map(|_| (rng.uniform_f32() - 0.5) * 0.2).collect()
    };
    let w1 = conv_w(1);
    let w3 = conv_w(3);
    let bias = vec![0.01f32; c];

    let mut out = Vec::new();
    let s = bench.run("max_integrate", || {
        std::hint::black_box(crate::integrate::max_integrate(&maps).len());
    });
    out.push(Entry::from_sample(s, "host"));
    let s = bench.run("conv_integrate_k1", || {
        std::hint::black_box(crate::integrate::conv_integrate(&maps, &w1, &bias, 1).len());
    });
    out.push(Entry::from_sample(s, "host"));
    let s = bench.run("conv_integrate_k3", || {
        std::hint::black_box(crate::integrate::conv_integrate(&maps, &w3, &bias, 3).len());
    });
    out.push(Entry::from_sample(s, "host"));
    out
}

#[cfg(feature = "native")]
fn bench_tail(bench: &mut Bench) -> Result<Vec<Entry>> {
    use crate::config::IntegrationKind;
    use crate::geom::Pose;
    use crate::runtime::{native::NativeBackend, ExecBackend, HostTensor};

    // Half-resolution meta so the bench stays fast in debug builds; the
    // shape is fixed, so numbers remain comparable across commits.
    let mut meta = ModelMeta::test_default();
    meta.grid.dims = [32, 32, 4];
    meta.grid.max_points = 1024;
    meta.bev_dims = [16, 16];
    let backend = NativeBackend::new(
        meta.clone(),
        vec![Pose::IDENTITY; meta.num_devices],
        None,
    )?;

    let g = &meta.grid;
    let shape = [g.dims[2], g.dims[1], g.dims[0], g.c_head];
    let mut rng = Pcg64::new(43);
    let mut feature = || {
        let mut t = HostTensor::zeros(&shape);
        for v in t.data.iter_mut() {
            *v = if rng.uniform_f32() < 0.1 { rng.uniform_f32() } else { 0.0 };
        }
        t
    };
    let inputs = vec![feature(), feature()];

    let mut out = Vec::new();
    for kind in IntegrationKind::all() {
        let tail = meta.variant(kind)?.tail.clone();
        backend.load(&tail)?;
        let s = bench.run(&format!("native_tail_{}", kind.name()), || {
            let r = backend.exec(&tail, inputs.clone()).expect("native tail exec");
            std::hint::black_box(r.len());
        });
        out.push(Entry::from_sample(s, "native"));
    }

    // Depth-specific tails of the Max variant, each fed at its own wire
    // channel count: the server-side cost of moving the split point sits
    // next to the default-depth rows above (split-mid duplicates
    // native_tail_max under its depth label, anchoring the comparison).
    use crate::config::{wire_channels, SPLIT_DEPTHS};
    for split in SPLIT_DEPTHS {
        let tail = meta.variant(IntegrationKind::Max)?.tail_for(split)?;
        backend.load(&tail)?;
        let split_shape = [g.dims[2], g.dims[1], g.dims[0], wire_channels(g, split)?];
        let mut split_feature = || {
            let mut t = HostTensor::zeros(&split_shape);
            for v in t.data.iter_mut() {
                *v = if rng.uniform_f32() < 0.1 { rng.uniform_f32() } else { 0.0 };
            }
            t
        };
        let split_inputs = vec![split_feature(), split_feature()];
        let s = bench.run(&format!("native_tail_max_{split}"), || {
            let r = backend.exec(&tail, split_inputs.clone()).expect("split tail exec");
            std::hint::black_box(r.len());
        });
        out.push(Entry::from_sample(s, "native"));
    }
    Ok(out)
}

#[cfg(not(feature = "native"))]
fn bench_tail(_bench: &mut Bench) -> Result<Vec<Entry>> {
    log::warn!("built without the `native` feature — BENCH_tail.json will be empty");
    Ok(Vec::new())
}

/// Micro-batched tail execution (`ExecBackend::exec_batch`) at batch
/// sizes 1/2/4/8: per-batch p50/p95, plus backend-calls vs frames
/// accounting — the number the cross-session `BatchPlanner` moves.
#[cfg(feature = "native")]
fn bench_batch(bench: &mut Bench) -> Result<Vec<Json>> {
    use crate::config::IntegrationKind;
    use crate::geom::Pose;
    use crate::runtime::{native::NativeBackend, ExecBackend, HostTensor};

    // Same fixed half-resolution shape as bench_tail, so per-frame
    // numbers are directly comparable between the two files.
    let mut meta = ModelMeta::test_default();
    meta.grid.dims = [32, 32, 4];
    meta.grid.max_points = 1024;
    meta.bev_dims = [16, 16];
    let backend =
        NativeBackend::new(meta.clone(), vec![Pose::IDENTITY; meta.num_devices], None)?;
    let tail = meta.variant(IntegrationKind::Max)?.tail.clone();
    backend.load(&tail)?;

    let g = &meta.grid;
    let shape = [g.dims[2], g.dims[1], g.dims[0], g.c_head];
    let mut rng = Pcg64::new(44);
    let mut feature = || {
        let mut t = HostTensor::zeros(&shape);
        for v in t.data.iter_mut() {
            *v = if rng.uniform_f32() < 0.1 { rng.uniform_f32() } else { 0.0 };
        }
        t
    };

    let mut out = Vec::new();
    for batch_size in [1usize, 2, 4, 8] {
        let batch: Vec<Vec<HostTensor>> =
            (0..batch_size).map(|_| vec![feature(), feature()]).collect();
        let s = bench.run(&format!("native_tail_exec_batch_{batch_size}"), || {
            let results = backend.exec_batch(&tail, batch.clone());
            for r in &results {
                assert!(r.is_ok(), "bench batch exec failed");
            }
            std::hint::black_box(results.len());
        });
        let backend_calls = s.times.len();
        let mut j = Json::obj();
        j.set("op", Json::Str("native_tail_exec_batch".into()))
            .set("backend", Json::Str("native".into()))
            .set("batch", Json::Num(batch_size as f64))
            .set("p50_secs", Json::Num(s.p50()))
            .set("p95_secs", Json::Num(stats::percentile(&s.times, 95.0)))
            .set("per_frame_p50_secs", Json::Num(s.p50() / batch_size as f64))
            .set("samples", Json::Num(backend_calls as f64))
            .set("backend_calls", Json::Num(backend_calls as f64))
            .set("frames", Json::Num((backend_calls * batch_size) as f64));
        out.push(j);
    }
    Ok(out)
}

#[cfg(not(feature = "native"))]
fn bench_batch(_bench: &mut Bench) -> Result<Vec<Json>> {
    log::warn!("built without the `native` feature — BENCH_batch.json will be empty");
    Ok(Vec::new())
}

/// Datagram chunking, in-order reassembly, and XOR-parity recovery for
/// the UDP feature uplink (`BENCH_dgram.json`). The payload is one
/// framed full-precision `Features` message at the quarter-resolution
/// bench shape (~32 KiB → ~30 data chunks), so the numbers track the
/// per-frame cost a device and the server pay on top of the TCP path.
fn bench_dgram(bench: &mut Bench) -> Result<Vec<Entry>> {
    use crate::net::{chunk_frame, encode_frame, DgramAssembler, Msg, CHUNK_PAYLOAD,
                     DEFAULT_SESSION};
    use crate::runtime::HostTensor;

    let mut rng = Pcg64::new(45);
    let mut tensor = HostTensor::zeros(&[4, 16, 16, 8]);
    for v in tensor.data.iter_mut() {
        *v = rng.uniform_f32();
    }
    let msg = Msg::Features {
        frame_id: 1,
        device_id: 0,
        tensor,
        session: DEFAULT_SESSION.into(),
        capture_micros: 0,
    };
    let framed = encode_frame(&msg)?;
    const FEC_K: u32 = 4;
    let dgrams = chunk_frame(&framed, DEFAULT_SESSION, 0, 1, FEC_K)?;
    let n_data = framed.len().div_ceil(CHUNK_PAYLOAD).max(1);
    let (data, parity) = dgrams.split_at(n_data);

    let mut out = Vec::new();
    let s = bench.run("dgram_chunk", || {
        let d = chunk_frame(&framed, DEFAULT_SESSION, 0, 1, FEC_K).expect("chunk");
        std::hint::black_box(d.len());
    });
    out.push(Entry::from_sample(s, "host"));
    let s = bench.run("dgram_assemble", || {
        let mut asm = DgramAssembler::new();
        let mut done = None;
        for d in data {
            done = asm.feed(d);
        }
        let done = done.expect("in-order assembly must complete on the last chunk");
        std::hint::black_box(done.frame.len());
    });
    out.push(Entry::from_sample(s, "host"));
    let s = bench.run("dgram_fec_recover", || {
        let mut asm = DgramAssembler::new();
        let mut done = None;
        for (i, d) in data.iter().enumerate() {
            if i % FEC_K as usize == 0 {
                continue; // one loss per parity group
            }
            if let Some(f) = asm.feed(d) {
                done = Some(f);
            }
        }
        for p in parity {
            if let Some(f) = asm.feed(p) {
                done = Some(f);
            }
        }
        let done = done.expect("parity must recover every single-loss group");
        assert_eq!(done.frame.len(), framed.len());
        std::hint::black_box(done.frame.len());
    });
    out.push(Entry::from_sample(s, "host"));
    Ok(out)
}

/// `scmii bench` CLI entry.
pub fn cmd_bench(args: &Args) -> Result<()> {
    args.check_known(&["out", "budget-ms", "warmup"])?;
    let out_dir = args.str_or("out", ".");
    let out_dir = Path::new(&out_dir);
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("create bench output dir {}", out_dir.display()))?;
    let budget = std::time::Duration::from_millis(args.u64_or("budget-ms", 1000)?);

    // Inputs for every case are constructed (and reused) outside the
    // timed closures; warmup runs N untimed iterations first (default 3,
    // `SCMII_BENCH_FAST` 1) so steady-state p50s aren't polluted by cold
    // caches or an empty allocator/arena.
    let mut bench = Bench::auto().with_budget(budget).with_iters(3, 500);
    if args.str_opt("warmup").is_some() {
        bench = bench.with_warmup(args.usize_or("warmup", 3)?);
    }
    write_entries(&out_dir.join("BENCH_decode.json"), &bench_decode(&mut bench))?;
    write_entries(&out_dir.join("BENCH_integrate.json"), &bench_integrate(&mut bench))?;
    write_entries(&out_dir.join("BENCH_tail.json"), &bench_tail(&mut bench)?)?;
    write_entries(&out_dir.join("BENCH_dgram.json"), &bench_dgram(&mut bench)?)?;
    let batch_rows = bench_batch(&mut bench)?;
    let batch_path = out_dir.join("BENCH_batch.json");
    crate::utils::json::write_file(&batch_path, &Json::Arr(batch_rows))
        .with_context(|| format!("write {}", batch_path.display()))?;
    println!("wrote {}", batch_path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_all_json_files() {
        let dir = std::env::temp_dir().join("scmii_bench_cmd_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = Args::parse(
            ["--out", dir.to_str().unwrap(), "--budget-ms", "1"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        cmd_bench(&args).unwrap();
        let native_only = ["BENCH_tail.json", "BENCH_batch.json"];
        for f in [
            "BENCH_decode.json",
            "BENCH_integrate.json",
            "BENCH_tail.json",
            "BENCH_dgram.json",
            "BENCH_batch.json",
        ] {
            let j = crate::utils::json::read_file(&dir.join(f)).unwrap();
            let arr = j.as_arr().unwrap();
            if !native_only.contains(&f) || cfg!(feature = "native") {
                assert!(!arr.is_empty(), "{f} must have entries");
            }
            for e in arr {
                assert!(e.req("op").unwrap().as_str().is_ok());
                assert!(e.req("backend").unwrap().as_str().is_ok());
                assert!(e.req("p50_secs").unwrap().as_f64().unwrap() >= 0.0);
                assert!(
                    e.req("p95_secs").unwrap().as_f64().unwrap()
                        >= e.req("p50_secs").unwrap().as_f64().unwrap()
                );
            }
        }
        // The batch file additionally accounts backend calls vs frames.
        if cfg!(feature = "native") {
            let j = crate::utils::json::read_file(&dir.join("BENCH_batch.json")).unwrap();
            let arr = j.as_arr().unwrap();
            assert_eq!(arr.len(), 4, "batch sizes 1/2/4/8");
            for e in arr {
                let batch = e.req("batch").unwrap().as_usize().unwrap();
                let calls = e.req("backend_calls").unwrap().as_usize().unwrap();
                let frames = e.req("frames").unwrap().as_usize().unwrap();
                assert!(batch >= 1);
                assert_eq!(frames, calls * batch, "frames must be calls × batch size");
                assert!(
                    e.req("per_frame_p50_secs").unwrap().as_f64().unwrap()
                        <= e.req("p50_secs").unwrap().as_f64().unwrap() + 1e-12
                );
            }
        }
    }
}
