//! # SC-MII
//!
//! Reproduction of *SC-MII: Infrastructure LiDAR-based 3D Object Detection
//! on Edge Devices for Split Computing with Multiple Intermediate Outputs
//! Integration* as a three-layer rust + JAX + Pallas serving stack.
//!
//! Layer 3 (this crate) is the runtime coordinator: edge-device head
//! workers, the edge-server frame synchronizer + integration + tail
//! execution, and every substrate the paper depends on (LiDAR simulator,
//! NDT scan matching, evaluation, networking). Layers 2/1 (JAX model and
//! Pallas kernels, under `python/`) run only at build time; the artifacts
//! they emit (`artifacts/*.hlo.txt`) are loaded here through PJRT.
//!
//! Entry points:
//! - [`coordinator::pipeline::ScMiiPipeline`] — in-process split-computing
//!   pipeline (deterministic; used by evaluation and benchmarks).
//! - [`coordinator::server`] / [`coordinator::device`] — the distributed
//!   TCP deployment (edge server + one worker per LiDAR).
//! - [`sim::dataset`] — synthetic intersection dataset generator standing
//!   in for V2X-Real.
//! - [`ndt`] — setup-phase extrinsic calibration via NDT scan matching.

pub mod align;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod geom;
pub mod integrate;
pub mod latency;
pub mod metrics;
pub mod model;
pub mod ndt;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod utils;
pub mod voxel;
