//! # SC-MII
//!
//! Reproduction of *SC-MII: Infrastructure LiDAR-based 3D Object Detection
//! on Edge Devices for Split Computing with Multiple Intermediate Outputs
//! Integration* as a three-layer rust + JAX + Pallas serving stack.
//!
//! Layer 3 (this crate) is the runtime coordinator plus every substrate
//! the paper depends on (LiDAR simulator, NDT scan matching, evaluation,
//! networking). Layers 2/1 (JAX model and Pallas kernels, under
//! `python/`) run only at build time; the artifacts they emit
//! (`artifacts/*.hlo.txt`) are loaded here through PJRT.
//!
//! ## Execution backends
//!
//! All model execution goes through [`runtime::ExecBackend`]
//! (`Arc<dyn ExecBackend>` everywhere above the runtime layer):
//! per-request `exec`, and `exec_batch` over a micro-batch of
//! independent input sets. Implementations:
//!
//! - `runtime::XlaBackend` (feature `xla`, default) runs the AOT HLO
//!   artifacts through PJRT on a **pool of N engine threads** with
//!   shared-queue work stealing — independent sessions and frames
//!   execute tails concurrently (`scmii serve --backend-threads N`).
//!   The engine is *not* single-threaded anymore; one serialized actor
//!   thread was the pre-backend design.
//! - `runtime::native::NativeBackend` (feature `native`) is a
//!   pure-Rust head/tail implementation (voxelize → linear head; gather
//!   alignment → integration → BEV conv → detection heads) requiring no
//!   HLO artifacts or native libraries: `cargo test --no-default-features
//!   --features native` exercises the full serving stack.
//!
//! Select per process with `scmii serve/infer/device --backend
//! xla|native`.
//!
//! ## The serving core
//!
//! The paper's Fig-2 flow — per-device heads → frame sync → integration +
//! tail → decode/NMS — is implemented **once**, in
//! [`coordinator::session::DetectorSession`]. Every frontend is a thin
//! adapter over it:
//!
//! - [`coordinator::pipeline::ScMiiPipeline`] — in-process driver (runs
//!   the heads locally, submits to the session synchronously); the
//!   Table-III accuracy harness ([`eval`]) and Fig-5 latency harness
//!   ([`latency`]) measure through it, so published numbers come from
//!   the code path that serves traffic.
//! - [`coordinator::server`] — the distributed TCP deployment, reduced to
//!   pure I/O: socket ⇄ [`net::Msg`] ⇄ session, multiplexed on a
//!   readiness-driven event loop ([`net::poll`]: `poll(2)`, self-pipe
//!   wake, timer wheel — no thread per connection) with decode/dispatch
//!   on a fixed worker pool and bounded per-subscriber result queues.
//!   One process hosts many
//!   named sessions (multiple intersections, A/B integration variants)
//!   via [`coordinator::session::SessionRegistry`]; wire messages carry a
//!   `session` field, with pre-session clients routed to the default
//!   session. Results fan out through
//!   [`coordinator::session::ResultSink`]s. Under fleet load the server
//!   micro-batches: a
//!   [`coordinator::scheduler::BatchPlanner`] coalesces compatible tail
//!   requests — same executable, same shapes — arriving within
//!   `--batch-window-ms` across sessions and frames into one stacked
//!   `exec_batch` call (`--max-batch`), cutting backend round-trips per
//!   frame to ~1/B.
//! - [`coordinator::device`] — one worker per LiDAR (head model),
//!   streaming raw or u8-quantized intermediate outputs.
//!
//! ## Fleet scenarios and the pipelined device runtime
//!
//! [`coordinator::device::run_device`] is a two-stage pipeline: head
//! execution of frame *t+1* overlaps transmission of frame *t* behind a
//! one-slot writer-thread channel, so the device cycle is
//! `max(head, tx)` rather than `head + tx` — the latency hiding the
//! paper's multi-device numbers rely on. [`scenario`] scales that up
//! declaratively: N devices × M sessions against a real TCP server, with
//! per-link bandwidth shaping and fault injection
//! ([`net::ImpairedLink`]: loss, delay/jitter, reorder), device dropout
//! and late join, reported as per-frame end-to-end latency
//! (`BENCH_e2e.json` via `scmii scenario`).
//!
//! ## Supporting layers
//!
//! - [`sim::dataset`] — synthetic intersection dataset generator standing
//!   in for V2X-Real.
//! - [`ndt`] — setup-phase extrinsic calibration via NDT scan matching.
//! - [`net`] — length-prefixed wire protocol with bandwidth shaping,
//!   quantized payloads, and message-level fault injection.
//!
//! See `docs/ARCHITECTURE.md` for the full design write-up,
//! `docs/WIRE_PROTOCOL.md` for the byte-level protocol spec, and
//! `docs/BENCHMARKS.md` for the `BENCH_*.json` schemas.

// The serving tiers (coordinator, runtime, net, scenario, bench) are
// fully documented and CI gates `cargo doc` on it (RUSTDOCFLAGS
// -D warnings). The simulation/eval substrates below are grandfathered
// with per-module allows until their pass lands — remove an `allow` to
// opt a module into the gate.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod align;
pub mod bench;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod config;
pub mod coordinator;
#[allow(missing_docs)]
pub mod eval;
#[allow(missing_docs)]
pub mod geom;
#[allow(missing_docs)]
pub mod integrate;
#[allow(missing_docs)]
pub mod latency;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod model;
#[allow(missing_docs)]
pub mod ndt;
pub mod net;
pub mod runtime;
pub mod scenario;
#[allow(missing_docs)]
pub mod sim;
pub mod sync;
pub mod trace;
#[allow(missing_docs)]
pub mod utils;
#[allow(missing_docs)]
pub mod voxel;
