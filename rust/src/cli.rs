//! Hand-rolled command-line parsing (clap is not in the offline image).
//!
//! Supports `scmii <subcommand> --flag value --switch` style invocations
//! with typed accessors, defaults and a generated usage string.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Declared option for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `--key value` / `--key=value` / `--switch` / positionals.
    pub fn parse<I: Iterator<Item = String>>(mut iter: I) -> Result<Args> {
        let mut args = Args::default();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.values.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: a following token not starting with -- is the value.
                    match iter.next() {
                        Some(next) if !next.starts_with("--") => {
                            args.values.insert(stripped.to_string(), next);
                        }
                        Some(next) => {
                            args.switches.push(stripped.to_string());
                            // `next` is another flag; recurse manually.
                            if let Some(s2) = next.strip_prefix("--") {
                                if let Some((k, v)) = s2.split_once('=') {
                                    args.values.insert(k.to_string(), v.to_string());
                                } else {
                                    match iter.next() {
                                        Some(v) if !v.starts_with("--") => {
                                            args.values.insert(s2.to_string(), v);
                                        }
                                        Some(v) => {
                                            args.switches.push(s2.to_string());
                                            bail!(
                                                "cannot parse flag sequence near --{s2} {v}; \
                                                 use --key=value for flag-like values"
                                            );
                                        }
                                        None => args.switches.push(s2.to_string()),
                                    }
                                }
                            }
                        }
                        None => args.switches.push(stripped.to_string()),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn str_req(&self, key: &str) -> Result<String> {
        self.values.get(key).cloned().with_context(|| format!("missing required --{key}"))
    }

    /// Enumerated flag: the value (or `default` when absent) must be one
    /// of `allowed` — a typo errors instead of silently meaning the
    /// default.
    pub fn str_one_of(&self, key: &str, allowed: &[&str], default: &str) -> Result<String> {
        let v = self.str_or(key, default);
        if !allowed.contains(&v.as_str()) {
            bail!("--{key} expects one of {}, got {v:?}", allowed.join("|"));
        }
        Ok(v)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
            None => Ok(default),
        }
    }

    /// Millisecond flag parsed into a [`Duration`](std::time::Duration)
    /// (`--foo-ms 250` → 250 ms; absent → `default_ms`).
    pub fn ms_or(&self, key: &str, default_ms: u64) -> Result<std::time::Duration> {
        Ok(std::time::Duration::from_millis(self.u64_or(key, default_ms)?))
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.values.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Unknown-flag guard: every provided key must appear in `known`.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.values.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; known flags: {}", known.join(", "));
            }
        }
        Ok(())
    }
}

/// Render usage text for a subcommand table.
pub fn usage(prog: &str, subcommands: &[(&str, &str)]) -> String {
    let mut s = format!("usage: {prog} <command> [--flags]\n\ncommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<16} {help}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--out", "data", "--seed=42", "--verbose"]);
        assert_eq!(a.str_opt("out"), Some("data"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 42);
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn defaults_and_required() {
        let a = parse(&["--x", "1"]);
        assert_eq!(a.usize_or("x", 9).unwrap(), 1);
        assert_eq!(a.usize_or("y", 9).unwrap(), 9);
        assert!(a.str_req("missing").is_err());
    }

    #[test]
    fn positionals() {
        let a = parse(&["infer", "--n", "5", "frame.npy"]);
        assert_eq!(a.positional(), &["infer".to_string(), "frame.npy".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["--bogus", "1"]);
        assert!(a.check_known(&["out", "seed"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["--fast"]);
        assert!(a.switch("fast"));
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn str_one_of_validates() {
        let a = parse(&["--policy", "drop"]);
        assert_eq!(a.str_one_of("policy", &["zero-fill", "drop"], "zero-fill").unwrap(), "drop");
        assert_eq!(
            a.str_one_of("missing", &["x", "y"], "x").unwrap(),
            "x",
            "absent flag takes the default"
        );
        let bad = parse(&["--policy", "bogus"]);
        assert!(bad.str_one_of("policy", &["zero-fill", "drop"], "zero-fill").is_err());
    }

    #[test]
    fn ms_accessor_builds_durations() {
        let a = parse(&["--batch-window-ms", "7"]);
        assert_eq!(a.ms_or("batch-window-ms", 2).unwrap(), std::time::Duration::from_millis(7));
        assert_eq!(a.ms_or("missing-ms", 2).unwrap(), std::time::Duration::from_millis(2));
        assert!(parse(&["--w-ms", "soon"]).ms_or("w-ms", 0).is_err());
    }

    #[test]
    fn f32_accessor() {
        let a = parse(&["--score-thresh", "0.4"]);
        assert!((a.f32_or("score-thresh", 0.25).unwrap() - 0.4).abs() < 1e-6);
        assert!((a.f32_or("missing", 0.25).unwrap() - 0.25).abs() < 1e-6);
        let bad = parse(&["--score-thresh", "abc"]);
        assert!(bad.f32_or("score-thresh", 0.25).is_err());
    }
}
