//! `scmii` — leader CLI for the SC-MII reproduction.
//!
//! Subcommands cover the paper's full lifecycle: dataset generation
//! (V2X-Real substitute), setup-phase NDT calibration, the distributed
//! TCP deployment (server + device workers), and the Table-III / Fig-5
//! evaluation harnesses.

use anyhow::{bail, Result};
use scmii::cli::{usage, Args};
use scmii::config::GridConfig;
use scmii::utils::logging;

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("datagen", "generate the synthetic two-LiDAR intersection dataset"),
    ("setup", "setup phase: NDT calibration -> artifacts/calib.json"),
    ("serve", "run the edge server (tail model) on a TCP port"),
    ("device", "run one edge-device worker (head model) against a server"),
    ("infer", "run the in-process pipeline on dataset frames"),
    ("eval-accuracy", "reproduce Table III (mAP per integration method)"),
    ("exec-time", "reproduce Fig 5 (execution-time comparison)"),
    ("bench", "hot-path micro-benchmarks -> BENCH_*.json"),
    ("scenario", "run a fleet scenario (devices x sessions, lossy links) -> BENCH_e2e.json"),
    ("trace", "record/replay wire traces (record|replay|bench) -> BENCH_replay.json"),
    ("version", "print version info"),
];

fn main() {
    logging::init();
    let mut argv = std::env::args().skip(1);
    let Some(cmd) = argv.next() else {
        eprint!("{}", usage("scmii", SUBCOMMANDS));
        std::process::exit(2);
    };
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match cmd.as_str() {
        "datagen" => cmd_datagen(&args),
        "setup" => cmd_setup(&args),
        "serve" => scmii::coordinator::server::cmd_serve(&args),
        "device" => scmii::coordinator::device::cmd_device(&args),
        "infer" => scmii::coordinator::pipeline::cmd_infer(&args),
        "eval-accuracy" => scmii::eval::harness::cmd_eval_accuracy(&args),
        "exec-time" => scmii::latency::harness::cmd_exec_time(&args),
        "bench" => scmii::bench::cmd_bench(&args),
        "scenario" => scmii::scenario::cmd_scenario(&args),
        "trace" => scmii::trace::cmd_trace(&args),
        #[cfg(feature = "xla")]
        "run-hlo" => cmd_run_hlo(&args),
        #[cfg(not(feature = "xla"))]
        "run-hlo" => Err(anyhow::anyhow!(
            "run-hlo executes HLO artifacts and needs the `xla` feature (this build has only {:?})",
            scmii::runtime::BackendKind::default_kind().name()
        )),
        "version" => {
            println!("scmii {} (SC-MII reproduction)", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "--help" | "help" => {
            print!("{}", usage("scmii", SUBCOMMANDS));
            Ok(())
        }
        other => {
            eprint!("{}", usage("scmii", SUBCOMMANDS));
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_datagen(args: &Args) -> Result<()> {
    args.check_known(&[
        "out",
        "seed",
        "train-frames",
        "val-frames",
        "cars",
        "peds",
        "max-points",
    ])?;
    let out = args.str_or("out", "data");
    let mut cfg = scmii::sim::SimConfig::default();
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.train_frames = args.usize_or("train-frames", cfg.train_frames)?;
    cfg.val_frames = args.usize_or("val-frames", cfg.val_frames)?;
    cfg.n_cars = args.usize_or("cars", cfg.n_cars)?;
    cfg.n_peds = args.usize_or("peds", cfg.n_peds)?;
    cfg.max_points = args.usize_or("max-points", cfg.max_points)?;
    let grid = GridConfig::default();
    scmii::sim::generate_dataset(&cfg, &grid, std::path::Path::new(&out))
}

/// Debug utility: execute any artifact on npy inputs, dump npy outputs.
/// Used to cross-check individual lowered ops against the python path.
#[cfg(feature = "xla")]
fn cmd_run_hlo(args: &Args) -> Result<()> {
    args.check_known(&["artifacts", "name", "inputs", "out"])?;
    let paths = scmii::config::Paths::new(&args.str_or("artifacts", "artifacts"), "data");
    let name = args.str_req("name")?;
    let out_dir = args.str_or("out", "/tmp/scmii_hlo_out");
    let mut engine = scmii::runtime::Engine::cpu()?;
    engine.load(&paths, &name)?;
    let mut inputs = Vec::new();
    if let Some(spec) = args.str_opt("inputs") {
        for p in spec.split(',') {
            let arr = scmii::utils::npy::read(std::path::Path::new(p))?;
            inputs.push(scmii::runtime::HostTensor::new(arr.shape.clone(), arr.as_f32()?)?);
        }
    }
    let outputs = engine.exec(&name, &inputs)?;
    std::fs::create_dir_all(&out_dir)?;
    for (i, t) in outputs.iter().enumerate() {
        let path = std::path::Path::new(&out_dir).join(format!("out{i}.npy"));
        scmii::utils::npy::write(
            &path,
            &scmii::utils::npy::NpyArray::from_f32(&t.shape, &t.data),
        )?;
        println!("wrote {} shape {:?}", path.display(), t.shape);
    }
    Ok(())
}

fn cmd_setup(args: &Args) -> Result<()> {
    args.check_known(&["data", "out", "max-iters"])?;
    let data = args.str_or("data", "data");
    let out = args.str_or("out", "artifacts/calib.json");
    let data = std::path::Path::new(&data);

    // Load calibration scans written by datagen.
    let mut clouds = Vec::new();
    let mut dev = 0;
    loop {
        let p = data.join("calib").join(format!("calib_dev{dev}.npy"));
        if !p.exists() {
            break;
        }
        let arr = scmii::utils::npy::read(&p)?;
        clouds.push(scmii::voxel::tensor_to_points(&arr.as_f32()?));
        dev += 1;
    }
    if clouds.len() < 2 {
        bail!("need at least two calibration scans under {}/calib", data.display());
    }

    let mut params = scmii::ndt::NdtParams::default();
    params.max_iters = args.usize_or("max-iters", params.max_iters)?;

    use scmii::utils::json::Json;
    let mut transforms = vec![scmii::geom::Pose::IDENTITY];
    let mut diagnostics = Vec::new();
    for (i, cloud) in clouds.iter().enumerate().skip(1) {
        log::info!("NDT: registering device {i} onto device 0 ...");
        let t0 = std::time::Instant::now();
        let result = scmii::ndt::calibrate(&clouds[0], cloud, &params);
        let secs = t0.elapsed().as_secs_f64();
        log::info!(
            "NDT device {i}: score {:.3}, {} iters, {:.2}s",
            result.score,
            result.iterations,
            secs
        );
        let mut d = Json::obj();
        d.set("device", Json::Num(i as f64))
            .set("score", Json::Num(result.score))
            .set("iterations", Json::Num(result.iterations as f64))
            .set("seconds", Json::Num(secs));
        // Validate against the simulator's true rig if meta.json is present.
        if let Ok(meta) = scmii::utils::json::read_file(&data.join("meta.json")) {
            if let Ok(sensors) = meta.req("sensors").map(|s| s.as_arr().unwrap_or(&[]).to_vec()) {
                let pose_of = |j: &Json| -> Result<scmii::geom::Pose> {
                    let v = j.req("true_pose_world")?.as_f64_vec()?;
                    anyhow::ensure!(v.len() == 16, "pose must be 4x4");
                    let mut arr = [0.0; 16];
                    arr.copy_from_slice(&v);
                    Ok(scmii::geom::Pose::from_mat4(&arr))
                };
                if sensors.len() > i {
                    if let (Ok(p0), Ok(pi)) = (pose_of(&sensors[0]), pose_of(&sensors[i])) {
                        let truth = p0.inverse().compose(&pi);
                        let (ang, trans) = result.pose.error_to(&truth);
                        log::info!(
                            "NDT device {i} vs truth: rot {:.4} rad, trans {:.3} m",
                            ang,
                            trans
                        );
                        d.set("rot_error_rad", Json::Num(ang))
                            .set("trans_error_m", Json::Num(trans));
                    }
                }
            }
        }
        diagnostics.push(d);
        transforms.push(result.pose);
    }

    let mut calib = Json::obj();
    calib.set(
        "transforms",
        Json::Arr(transforms.iter().map(|t| Json::from_f64_slice(&t.to_mat4())).collect()),
    );
    calib.set("diagnostics", Json::Arr(diagnostics));
    scmii::utils::json::write_file(std::path::Path::new(&out), &calib)?;
    log::info!("wrote {}", out);
    Ok(())
}
