//! Serving metrics: latency histograms, throughput counters, breakdowns.

use crate::utils::stats;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// A named collection of latency samples (seconds), thread-safe.
#[derive(Default)]
pub struct Metrics {
    series: Mutex<BTreeMap<String, Vec<f64>>>,
    counters: Mutex<BTreeMap<String, u64>>,
    start: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { series: Mutex::default(), counters: Mutex::default(), start: Some(Instant::now()) }
    }

    pub fn record(&self, name: &str, seconds: f64) {
        self.series.lock().unwrap().entry(name.to_string()).or_default().push(seconds);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_default() += by;
    }

    /// Overwrite a counter with an absolute value (gauge semantics; used
    /// to mirror externally-accumulated stats like `SyncStats`).
    pub fn set(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Snapshot one series.
    pub fn samples(&self, name: &str) -> Vec<f64> {
        self.series.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    /// Summary over one series: (count, mean, p50, p99, max).
    pub fn summary(&self, name: &str) -> (usize, f64, f64, f64, f64) {
        let xs = self.samples(name);
        let (_, max) = stats::min_max(&xs);
        (
            xs.len(),
            stats::mean(&xs),
            stats::percentile(&xs, 50.0),
            stats::percentile(&xs, 99.0),
            if xs.is_empty() { 0.0 } else { max },
        )
    }

    /// Events/second for a counter since construction.
    pub fn rate(&self, name: &str) -> f64 {
        let elapsed = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        if elapsed <= 0.0 {
            0.0
        } else {
            self.counter(name) as f64 / elapsed
        }
    }

    /// Human-readable report of every series and counter.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let series = self.series.lock().unwrap();
        for (name, xs) in series.iter() {
            out.push_str(&format!(
                "{name:<32} n={:<6} mean={:>9.3}ms p50={:>9.3}ms p99={:>9.3}ms\n",
                xs.len(),
                stats::mean(xs) * 1e3,
                stats::percentile(xs, 50.0) * 1e3,
                stats::percentile(xs, 99.0) * 1e3,
            ));
        }
        let counters = self.counters.lock().unwrap();
        for (name, v) in counters.iter() {
            out.push_str(&format!("{name:<32} count={v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record("lat", i as f64 * 1e-3);
        }
        let (n, mean, p50, p99, max) = m.summary("lat");
        assert_eq!(n, 100);
        assert!((mean - 0.0505).abs() < 1e-9);
        assert!((p50 - 0.0505).abs() < 1e-3);
        assert!(p99 > 0.098 && p99 <= 0.1);
        assert!((max - 0.1).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("frames", 3);
        m.incr("frames", 4);
        assert_eq!(m.counter("frames"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_overwrites_counter() {
        let m = Metrics::new();
        m.incr("sync_complete", 2);
        m.set("sync_complete", 9);
        assert_eq!(m.counter("sync_complete"), 9);
        m.set("sync_complete", 3);
        assert_eq!(m.counter("sync_complete"), 3);
    }

    #[test]
    fn time_records_a_sample() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.samples("op").len(), 1);
    }

    #[test]
    fn report_contains_series() {
        let m = Metrics::new();
        m.record("x", 0.001);
        m.incr("c", 1);
        let r = m.report();
        assert!(r.contains("x") && r.contains("c"));
    }
}
