//! Serving metrics: latency histograms, throughput counters, breakdowns.
//!
//! Metric names are a closed set: every name the production serving
//! path records must be listed in [`REGISTERED_METRICS`], and
//! `cargo run -p xtask -- lint` cross-checks every metric-name string
//! literal in `rust/src` against that list. One registry means one
//! place to discover what a server exports, and renaming a metric is an
//! explicit, reviewable event instead of a silent dashboard breakage.

use crate::sync::time::Instant;
use crate::sync::{lock_or_recover, Mutex};
use crate::utils::stats;
use std::collections::BTreeMap;

/// Every metric name the production serving path records, in
/// alphabetical order. `xtask lint` parses this list (the string
/// literals between the `registry-begin`/`registry-end` markers) and
/// rejects any `Metrics` call in non-test `rust/src` code whose name
/// literal is missing here — add the name and its doc row together.
pub const REGISTERED_METRICS: &[&str] = &[
    // registry-begin
    "arena_hits",          // gauge: scratch-arena checkouts served without allocating
    "arena_misses",        // gauge: scratch-arena checkouts that allocated fresh
    "bad_device",          // counter: features addressed to an out-of-range device slot
    "batch_backend_calls", // counter: stacked exec_batch calls issued by the planner
    "batch_frames",        // counter: frames executed through the planner
    "batch_occupancy",     // series: frames per stacked backend call
    "batch_pending",       // series: planner queue depth after enqueue/drain
    "batch_queue_depth",   // series: planner queue depth at enqueue time
    "batch_rejected",      // counter: requests refused because the planner queue was full
    "conn_accepted",       // counter: TCP connections accepted by the event loop
    "conn_active",         // gauge: currently open connections
    "conn_closed",         // counter: connections closed (any reason)
    "conn_peak",           // gauge: high-water mark of simultaneously open connections
    "decode_errors",       // counter: quantized payloads that failed to dequantize
    "dgram_dup",           // gauge: duplicate datagrams ignored by the assembler
    "dgram_malformed",     // gauge: unparseable/inconsistent datagrams dropped
    "dgram_rx",            // gauge: datagrams received on the UDP feature socket
    "dgram_stale_dropped", // gauge: stale datagrams + superseded partial frames dropped
    "e2e",                 // series: capture → delivery end-to-end seconds
    "features_rx",         // counter: feature payloads received
    "features_rx_quantized", // counter: quantized feature payloads received
    "fec_recovered",       // gauge: chunks reconstructed from XOR parity
    "frames_done",         // counter: frames fully resolved (delivered or expired)
    "head_exec",           // series: device-side head execution seconds
    "post",                // series: decode + NMS post-processing seconds
    "shed_batches",        // counter: ready bursts resolved through the shed tail under overload
    "shed_frames",         // counter: frames degraded (cheaper tail + coarser decode), not rejected
    "sink_dropped",        // counter: result frames dropped on a slow subscriber's full queue
    "split_deep",          // counter: frames completed by a split-deep session
    "split_mid",           // counter: frames completed by a split-mid (default depth) session
    "split_shallow",       // counter: frames completed by a split-shallow session
    "sync_complete",       // gauge: frames that gathered every device before deadline
    "sync_dropped",        // gauge: frames dropped by the loss policy
    "sync_dup",            // gauge: duplicate (frame, device) submissions ignored
    "sync_late",           // gauge: arrivals for frames already emitted
    "sync_stale",          // gauge: latest-wins submissions older than the device's newest
    "sync_superseded",     // gauge: latest-wins partials discarded for fresher frames
    "sync_timed_out",      // gauge: frames resolved incomplete at deadline
    "sync_wait",           // series: first-arrival → sync-resolution seconds
    "tail",                // series: in-process pipeline tail seconds
    "tail_errors",         // counter: tail executions that returned an error
    "tail_exec",           // series: tail execution seconds
    "trace_recorded",      // counter: intermediate outputs teed into a trace capture
    "trace_replayed",      // counter: trace records submitted by `scmii trace replay`
    "tx",                  // series: device-side transmission seconds
    // registry-end
];

/// A named collection of latency samples (seconds), thread-safe.
pub struct Metrics {
    series: Mutex<BTreeMap<String, Vec<f64>>>,
    counters: Mutex<BTreeMap<String, u64>>,
    start: Option<Instant>,
}

impl Default for Metrics {
    /// Like [`Metrics::new`] but without a start instant, so [`rate`]
    /// (which needs a wall-clock origin) reports 0.
    ///
    /// [`rate`]: Metrics::rate
    fn default() -> Metrics {
        Metrics {
            series: Mutex::new(BTreeMap::new()),
            counters: Mutex::new(BTreeMap::new()),
            start: None,
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { start: Some(Instant::now()), ..Metrics::default() }
    }

    pub fn record(&self, name: &str, seconds: f64) {
        lock_or_recover(&self.series).entry(name.to_string()).or_default().push(seconds);
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn incr(&self, name: &str, by: u64) {
        *lock_or_recover(&self.counters).entry(name.to_string()).or_default() += by;
    }

    /// Overwrite a counter with an absolute value (gauge semantics; used
    /// to mirror externally-accumulated stats like `SyncStats`).
    pub fn set(&self, name: &str, value: u64) {
        lock_or_recover(&self.counters).insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        lock_or_recover(&self.counters).get(name).copied().unwrap_or(0)
    }

    /// Snapshot one series.
    pub fn samples(&self, name: &str) -> Vec<f64> {
        lock_or_recover(&self.series).get(name).cloned().unwrap_or_default()
    }

    /// Summary over one series: (count, mean, p50, p99, max).
    pub fn summary(&self, name: &str) -> (usize, f64, f64, f64, f64) {
        let xs = self.samples(name);
        let (_, max) = stats::min_max(&xs);
        (
            xs.len(),
            stats::mean(&xs),
            stats::percentile(&xs, 50.0),
            stats::percentile(&xs, 99.0),
            if xs.is_empty() { 0.0 } else { max },
        )
    }

    /// Events/second for a counter since construction.
    pub fn rate(&self, name: &str) -> f64 {
        let elapsed = self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        if elapsed <= 0.0 {
            0.0
        } else {
            self.counter(name) as f64 / elapsed
        }
    }

    /// Human-readable report of every series and counter.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let series = lock_or_recover(&self.series);
        for (name, xs) in series.iter() {
            out.push_str(&format!(
                "{name:<32} n={:<6} mean={:>9.3}ms p50={:>9.3}ms p99={:>9.3}ms\n",
                xs.len(),
                stats::mean(xs) * 1e3,
                stats::percentile(xs, 50.0) * 1e3,
                stats::percentile(xs, 99.0) * 1e3,
            ));
        }
        let counters = lock_or_recover(&self.counters);
        for (name, v) in counters.iter() {
            out.push_str(&format!("{name:<32} count={v}\n"));
        }
        out
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record("lat", i as f64 * 1e-3);
        }
        let (n, mean, p50, p99, max) = m.summary("lat");
        assert_eq!(n, 100);
        assert!((mean - 0.0505).abs() < 1e-9);
        assert!((p50 - 0.0505).abs() < 1e-3);
        assert!(p99 > 0.098 && p99 <= 0.1);
        assert!((max - 0.1).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("frames", 3);
        m.incr("frames", 4);
        assert_eq!(m.counter("frames"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn set_overwrites_counter() {
        let m = Metrics::new();
        m.incr("sync_complete", 2);
        m.set("sync_complete", 9);
        assert_eq!(m.counter("sync_complete"), 9);
        m.set("sync_complete", 3);
        assert_eq!(m.counter("sync_complete"), 3);
    }

    #[test]
    fn time_records_a_sample() {
        let m = Metrics::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.samples("op").len(), 1);
    }

    #[test]
    fn report_contains_series() {
        let m = Metrics::new();
        m.record("x", 0.001);
        m.incr("c", 1);
        let r = m.report();
        assert!(r.contains("x") && r.contains("c"));
    }

    #[test]
    fn registry_is_sorted_and_duplicate_free() {
        let mut sorted = REGISTERED_METRICS.to_vec();
        sorted.sort_unstable();
        assert_eq!(REGISTERED_METRICS, &sorted[..], "keep the registry alphabetical");
        sorted.dedup();
        assert_eq!(REGISTERED_METRICS.len(), sorted.len(), "duplicate registry entry");
    }

    #[test]
    fn poisoned_metrics_keep_recording() {
        use crate::sync::Arc;
        let m = Arc::new(Metrics::new());
        m.record("lat", 0.5);
        let poisoner = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = poisoner.series.lock().unwrap();
            panic!("die holding the series lock");
        })
        .join();
        // The panic above poisoned the mutex; every accessor must keep
        // working (a metrics sink must never take down the serving path).
        m.record("lat", 0.7);
        assert_eq!(m.samples("lat"), vec![0.5, 0.7]);
        assert_eq!(m.summary("lat").0, 2);
        assert!(m.report().contains("lat"));
    }
}
